//! Hot-path microbenchmark: native collapsed-Gibbs sampling throughput
//! (tokens/sec, ns/token) as a function of K, for the serial kernel and
//! the partitioned engine — the L3 perf deliverable's primary meter.
//! Also runs the dense-vs-sparse-vs-alias sampling-kernel comparison
//! (see `docs/kernels.md`), emitting a `BENCH_JSON kernel_compare` line
//! and asserting the sparse kernel beats dense per-token at K=256.

use std::collections::HashMap;

use pplda::bench::{Bench, BenchConfig};
use pplda::corpus::bow::BagOfWords;
use pplda::corpus::synthetic::{generate, Profile};
use pplda::gibbs::serial::SerialLda;
use pplda::kernel::KernelKind;
use pplda::partition::{partition, Algorithm};
use pplda::scheduler::exec::{ExecMode, ParallelLda};
use pplda::util::json::Json;
use pplda::util::tsv::Table;

fn main() {
    let fast = std::env::var("PPLDA_BENCH_FAST").as_deref() == Ok("1");
    let scale = if fast { 40 } else { 10 };
    let seed = 42;
    let bow = generate(&Profile::nips_like().scaled(scale), seed);
    let n = bow.num_tokens() as f64;
    println!(
        "bench_gibbs_hotpath: D={} W={} N={}",
        bow.num_docs(),
        bow.num_words(),
        bow.num_tokens()
    );

    let ks: &[usize] = if fast { &[16, 64] } else { &[16, 64, 256] };
    let mut bench = Bench::new(BenchConfig::heavy());
    for &k in ks {
        let mut lda = SerialLda::init(&bow, k, 0.5, 0.1, seed);
        lda.sweep(); // warm caches
        bench.run_with_items(&format!("serial sweep K={k}"), Some(n), || {
            lda.sweep();
        });
    }

    // Partitioned engine overhead (sequential mode isolates scheduling
    // cost from thread spawn): should stay within a few % of serial.
    let k = 64;
    let plan = partition(&bow, 8, Algorithm::A3 { restarts: 10 }, seed);
    let mut par = ParallelLda::init(&bow, &plan, k, 0.5, 0.1, seed);
    par.sweep(ExecMode::Sequential);
    bench.run_with_items(&format!("partitioned P=8 K={k} (seq)"), Some(n), || {
        par.sweep(ExecMode::Sequential);
    });
    let mut par2 = ParallelLda::init(&bow, &plan, k, 0.5, 0.1, seed);
    par2.sweep(ExecMode::Threaded);
    bench.run_with_items(&format!("partitioned P=8 K={k} (threads)"), Some(n), || {
        par2.sweep(ExecMode::Threaded);
    });
    // Persistent pool: same parallelism as (threads) with the per-epoch
    // spawn/alloc overhead amortized away by long-lived workers.
    let mut par3 = ParallelLda::init(&bow, &plan, k, 0.5, 0.1, seed);
    par3.sweep(ExecMode::Pooled);
    bench.run_with_items(&format!("partitioned P=8 K={k} (pooled)"), Some(n), || {
        par3.sweep(ExecMode::Pooled);
    });

    println!("{}", bench.table().to_aligned());
    for m in bench.results() {
        let ns_per_token = m.per_iter.mean * 1e9 / n;
        println!("{:35} {:8.1} ns/token", m.name, ns_per_token);
    }

    // The partitioned engine (sequential) must be within 2× of serial at
    // the same K — the scheduler must not dominate the kernel.
    let serial_k64 = bench
        .results()
        .iter()
        .find(|m| m.name.contains("serial sweep K=64"))
        .unwrap()
        .per_iter
        .median;
    let part_k64 = bench
        .results()
        .iter()
        .find(|m| m.name.contains("(seq)"))
        .unwrap()
        .per_iter
        .median;
    println!(
        "partitioned/serial overhead: {:.2}x",
        part_k64 / serial_k64
    );
    assert!(
        part_k64 < serial_k64 * 2.0,
        "partitioned engine overhead too high: {part_k64} vs {serial_k64}"
    );

    kernel_compare(&bow, seed, fast);
}

/// Head-to-head sampling-kernel comparison at K ∈ {64, 256} on the
/// nips-like corpus: per-sweep wall time and ns/token for the dense,
/// sparse, and alias kernels under the same plan (sequential mode, so
/// the measurement isolates kernel cost from thread scheduling). Each
/// kernel gets its own burn-in so the measurement reflects its
/// steady-state sparsity (doc rows concentrate over the first sweeps;
/// the sparse/alias kernels' O(k_doc + k_word) advantage only exists
/// after that). Emits a `BENCH_JSON kernel_compare` line and asserts
/// the acceptance bar: sparse beats dense per-token at K=256.
fn kernel_compare(bow: &BagOfWords, seed: u64, fast: bool) {
    let ks = [64usize, 256];
    let p = 8;
    let burnin = if fast { 10 } else { 20 };
    let n = bow.num_tokens() as f64;
    let plan = partition(bow, p, Algorithm::A3 { restarts: 10 }, seed);
    println!("\nkernel comparison: P={p} burn-in={burnin} sweeps (sequential mode)");

    let mut bench = Bench::new(BenchConfig::heavy());
    let mut table = Table::new(["kernel", "K", "median_s", "ns/token"]);
    let mut results = Vec::new();
    let mut ns_token: HashMap<(KernelKind, usize), f64> = HashMap::new();
    for &k in &ks {
        for kind in KernelKind::all() {
            let mut lda = ParallelLda::init(bow, &plan, k, 0.5, 0.1, seed);
            lda.set_kernel(kind);
            for _ in 0..burnin {
                lda.sweep(ExecMode::Sequential);
            }
            let m = bench.run_with_items(&format!("{} K={k}", kind.name()), Some(n), || {
                lda.sweep(ExecMode::Sequential);
            });
            let per_token = m.per_iter.median * 1e9 / n;
            table.row([
                kind.name().to_string(),
                k.to_string(),
                format!("{:.6}", m.per_iter.median),
                format!("{per_token:.1}"),
            ]);
            let mut j = Json::obj();
            j.set("kernel", kind.name())
                .set("k", k)
                .set("median_sweep_secs", m.per_iter.median)
                .set("ns_per_token", per_token);
            results.push(j);
            ns_token.insert((kind, k), per_token);
        }
    }
    println!("{}", table.to_aligned());

    let mut summary = Json::obj();
    summary
        .set("bench", "kernel_compare")
        .set("corpus", "nips-like")
        .set("tokens", bow.num_tokens())
        .set("p", p)
        .set("burnin", burnin)
        .set("results", results);
    println!("BENCH_JSON {}", summary.to_string());

    let dense = ns_token[&(KernelKind::Dense, 256)];
    let sparse = ns_token[&(KernelKind::Sparse, 256)];
    let alias = ns_token[&(KernelKind::Alias, 256)];
    println!(
        "K=256 ns/token: dense {dense:.1}, sparse {sparse:.1} ({:.2}x), alias {alias:.1} ({:.2}x)",
        dense / sparse,
        dense / alias
    );
    // Acceptance: the sparse decomposition must beat the dense scan per
    // token at K=256 once burned in. The expected margin is several-fold,
    // but the 1–2-rep FAST (CI smoke) measurements are noise-prone on
    // shared runners, so there the bound carries slack — loose enough to
    // ride out a scheduler hiccup, tight enough that sparse actually
    // losing its advantage still fails (cf. bench_speedup's FAST policy).
    let bound = if fast { dense * 1.5 } else { dense };
    assert!(
        sparse < bound,
        "sparse must beat dense per-token at K=256: sparse {sparse:.1} vs dense {dense:.1} ns"
    );
}
