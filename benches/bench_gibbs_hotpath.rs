//! Hot-path microbenchmark: native collapsed-Gibbs sampling throughput
//! (tokens/sec, ns/token) as a function of K, for the serial kernel and
//! the partitioned engine — the L3 perf deliverable's primary meter.

use pplda::bench::{Bench, BenchConfig};
use pplda::corpus::synthetic::{generate, Profile};
use pplda::gibbs::serial::SerialLda;
use pplda::partition::{partition, Algorithm};
use pplda::scheduler::exec::{ExecMode, ParallelLda};

fn main() {
    let fast = std::env::var("PPLDA_BENCH_FAST").as_deref() == Ok("1");
    let scale = if fast { 40 } else { 10 };
    let seed = 42;
    let bow = generate(&Profile::nips_like().scaled(scale), seed);
    let n = bow.num_tokens() as f64;
    println!(
        "bench_gibbs_hotpath: D={} W={} N={}",
        bow.num_docs(),
        bow.num_words(),
        bow.num_tokens()
    );

    let ks: &[usize] = if fast { &[16, 64] } else { &[16, 64, 256] };
    let mut bench = Bench::new(BenchConfig::heavy());
    for &k in ks {
        let mut lda = SerialLda::init(&bow, k, 0.5, 0.1, seed);
        lda.sweep(); // warm caches
        bench.run_with_items(&format!("serial sweep K={k}"), Some(n), || {
            lda.sweep();
        });
    }

    // Partitioned engine overhead (sequential mode isolates scheduling
    // cost from thread spawn): should stay within a few % of serial.
    let k = 64;
    let plan = partition(&bow, 8, Algorithm::A3 { restarts: 10 }, seed);
    let mut par = ParallelLda::init(&bow, &plan, k, 0.5, 0.1, seed);
    par.sweep(ExecMode::Sequential);
    bench.run_with_items(&format!("partitioned P=8 K={k} (seq)"), Some(n), || {
        par.sweep(ExecMode::Sequential);
    });
    let mut par2 = ParallelLda::init(&bow, &plan, k, 0.5, 0.1, seed);
    par2.sweep(ExecMode::Threaded);
    bench.run_with_items(&format!("partitioned P=8 K={k} (threads)"), Some(n), || {
        par2.sweep(ExecMode::Threaded);
    });
    // Persistent pool: same parallelism as (threads) with the per-epoch
    // spawn/alloc overhead amortized away by long-lived workers.
    let mut par3 = ParallelLda::init(&bow, &plan, k, 0.5, 0.1, seed);
    par3.sweep(ExecMode::Pooled);
    bench.run_with_items(&format!("partitioned P=8 K={k} (pooled)"), Some(n), || {
        par3.sweep(ExecMode::Pooled);
    });

    println!("{}", bench.table().to_aligned());
    for m in bench.results() {
        let ns_per_token = m.per_iter.mean * 1e9 / n;
        println!("{:35} {:8.1} ns/token", m.name, ns_per_token);
    }

    // The partitioned engine (sequential) must be within 2× of serial at
    // the same K — the scheduler must not dominate the kernel.
    let serial_k64 = bench
        .results()
        .iter()
        .find(|m| m.name.contains("serial sweep K=64"))
        .unwrap()
        .per_iter
        .median;
    let part_k64 = bench
        .results()
        .iter()
        .find(|m| m.name.contains("(seq)"))
        .unwrap()
        .per_iter
        .median;
    println!(
        "partitioned/serial overhead: {:.2}x",
        part_k64 / serial_k64
    );
    assert!(
        part_k64 < serial_k64 * 2.0,
        "partitioned engine overhead too high: {part_k64} vs {serial_k64}"
    );
}
