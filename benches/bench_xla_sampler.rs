//! XLA backend vs native kernel: sampling and perplexity throughput of
//! the AOT-compiled JAX/Pallas path (gather → PJRT execute → scatter)
//! against the pure-rust hot path, plus a numerical cross-check.
//!
//! Requires `make artifacts`. The XLA path is expected to lose on CPU —
//! it pays dense [B,K] gathers and PJRT dispatch to reach a kernel that
//! interpret-mode lowering keeps un-fused — but it proves the three-layer
//! bridge and gives the TPU-bound batching structure a measured baseline.

use pplda::bench::{Bench, BenchConfig};
use pplda::corpus::synthetic::{generate, Profile};
use pplda::gibbs::counts::LdaCounts;
use pplda::gibbs::perplexity as native_perplexity;
use pplda::gibbs::sampler::Hyper;
use pplda::gibbs::serial::SerialLda;
use pplda::gibbs::tokens::TokenBlock;
use pplda::runtime::executor::Artifacts;
use pplda::runtime::sampler_xla::{XlaPerplexity, XlaSampler};
use pplda::util::rng::Rng;

fn main() {
    let dir = Artifacts::default_dir();
    if !Artifacts::available(&dir) {
        println!("bench_xla_sampler: SKIPPED (no artifacts; run `make artifacts`)");
        return;
    }
    let arts = Artifacts::discover(dir).unwrap();
    let (batch, k) = arts
        .variants("sampler")
        .into_iter()
        .min_by_key(|&(_, k)| k)
        .expect("at least one sampler artifact");

    let fast = std::env::var("PPLDA_BENCH_FAST").as_deref() == Ok("1");
    let scale = if fast { 80 } else { 20 };
    let seed = 42;
    let bow = generate(&Profile::nips_like().scaled(scale), seed);
    let n = bow.num_tokens() as f64;
    println!(
        "bench_xla_sampler: D={} W={} N={} | artifact batch={batch} K={k}",
        bow.num_docs(),
        bow.num_words(),
        bow.num_tokens()
    );

    // Shared model state.
    let mut rng = Rng::new(seed);
    let mut block = TokenBlock::from_corpus(&bow, k, &mut rng);
    let mut counts = LdaCounts::zeros(bow.num_docs(), bow.num_words(), k);
    counts.absorb(&block);
    let h = Hyper::new(k, 0.5, 0.1, bow.num_words());

    let mut bench = Bench::new(BenchConfig::heavy());

    // Native serial sweep.
    let mut native = SerialLda::init(&bow, k, 0.5, 0.1, seed);
    native.sweep();
    bench.run_with_items(&format!("native sweep K={k}"), Some(n), || {
        native.sweep();
    });

    // XLA batched sweep.
    let mut xla = XlaSampler::new(arts.sampler(batch, k).unwrap());
    xla.sweep(&mut block, &mut counts, &h, &mut rng).unwrap();
    bench.run_with_items(&format!("xla sweep K={k} B={batch}"), Some(n), || {
        xla.sweep(&mut block, &mut counts, &h, &mut rng).unwrap();
    });

    // Perplexity: native vs XLA.
    bench.run_with_items("native perplexity", Some(n), || {
        pplda::bench::black_box(native_perplexity::perplexity(&bow, &counts, &h));
    });
    let mut xp = XlaPerplexity::new(arts.loglik(batch, k).unwrap());
    bench.run_with_items("xla perplexity", Some(n), || {
        pplda::bench::black_box(xp.perplexity(&bow, &counts, &h).unwrap());
    });

    println!("{}", bench.table().to_aligned());

    // Numerical cross-check.
    let p_native = native_perplexity::perplexity(&bow, &counts, &h);
    let p_xla = xp.perplexity(&bow, &counts, &h).unwrap();
    let rel = (p_native - p_xla).abs() / p_native;
    println!("perplexity: native {p_native:.4} vs xla {p_xla:.4} (rel {rel:.2e})");
    assert!(rel < 1e-3);

    let native_tp = bench.results()[0].throughput().unwrap();
    let xla_tp = bench.results()[1].throughput().unwrap();
    println!(
        "sampling: native {} vs xla {} tokens/s ({}x)",
        pplda::util::human_rate(native_tp),
        pplda::util::human_rate(xla_tp),
        format!("{:.1}", native_tp / xla_tp)
    );
}
