//! Table II — load-balancing ratio η on NIPS, P ∈ {1, 10, 30, 60}.
//!
//! Paper reference rows:
//! ```text
//! P                   1    10      30      60
//! Baseline          1.0  0.9500  0.7800  0.5700
//! A1                1.0  0.9613  0.8657  0.7126
//! A2                1.0  0.9633  0.8568  0.7097
//! A3                1.0  0.9800  0.8929  0.7553
//! ```
//! Expected shape on the synthetic NIPS-like corpus: A3 ≥ A1 ≈ A2 >
//! baseline at every P > 1, gaps widening with P. Set PPLDA_BENCH_FAST=1
//! for a reduced-restart run.

use pplda::corpus::synthetic::{generate, Profile};
use pplda::partition::{partition, Algorithm};
use pplda::util::tsv::{f, Table};

fn main() {
    let fast = std::env::var("PPLDA_BENCH_FAST").as_deref() == Ok("1");
    let restarts = if fast { 10 } else { 100 };
    let seed = 42;

    let bow = generate(&Profile::nips_like(), seed);
    println!(
        "bench_table2_nips: D={} W={} N={} (restarts={restarts})",
        bow.num_docs(),
        bow.num_words(),
        bow.num_tokens()
    );

    let procs = [1usize, 10, 30, 60];
    let paper: [(&str, [f64; 4]); 4] = [
        ("baseline", [1.0, 0.9500, 0.7800, 0.5700]),
        ("A1", [1.0, 0.9613, 0.8657, 0.7126]),
        ("A2", [1.0, 0.9633, 0.8568, 0.7097]),
        ("A3", [1.0, 0.9800, 0.8929, 0.7553]),
    ];

    let mut table = Table::new(["algorithm", "P=1", "P=10", "P=30", "P=60", "source"]);
    let mut measured = std::collections::BTreeMap::new();
    for (name, algo) in [
        ("baseline", Algorithm::Baseline { restarts }),
        ("A1", Algorithm::A1),
        ("A2", Algorithm::A2),
        ("A3", Algorithm::A3 { restarts }),
    ] {
        let etas: Vec<f64> = procs
            .iter()
            .map(|&p| partition(&bow, p, algo, seed).eta)
            .collect();
        let mut row = vec![name.to_string()];
        row.extend(etas.iter().map(|&e| f(e, 4)));
        row.push("measured".into());
        table.row(row);
        measured.insert(name, etas);
    }
    for (name, vals) in paper {
        let mut row = vec![name.to_string()];
        row.extend(vals.iter().map(|&e| f(e, 4)));
        row.push("paper".into());
        table.row(row);
    }
    println!("{}", table.to_aligned());

    // Shape assertions (who wins, monotonicity).
    for pi in 1..procs.len() {
        let b = measured["baseline"][pi];
        let a1 = measured["A1"][pi];
        let a2 = measured["A2"][pi];
        let a3 = measured["A3"][pi];
        assert!(
            a3 > b && a1 > b && a2 > b,
            "P={}: proposed algorithms must beat baseline (b={b:.4} a1={a1:.4} a2={a2:.4} a3={a3:.4})",
            procs[pi]
        );
        assert!(a3 + 0.02 >= a1 && a3 + 0.02 >= a2, "A3 should lead at P={}", procs[pi]);
    }
    // Baseline degrades fastest toward P=60 (paper: 0.57).
    assert!(measured["baseline"][3] < 0.75);
    println!("shape checks passed: A3 > A1~A2 > baseline; baseline degrades fastest");
}
