//! Serve-path throughput/latency benchmark: an in-process
//! [`QueryServer`] driven by concurrent clients under a uniform and a
//! skewed (head-heavy) word mix, plus a forced-degradation row. Emits
//! one `BENCH_JSON serve_qps` line per mix with client-side p50/p99 and
//! QPS — the latency meter for the robustness deliverable (see
//! `docs/serving.md`).

use std::sync::Arc;
use std::time::Instant;

use pplda::corpus::synthetic::{generate, Profile};
use pplda::gibbs::serial::SerialLda;
use pplda::serve::server::{QueryServer, ServeConfig};
use pplda::serve::snapshot::ModelSnapshot;
use pplda::util::json::Json;
use pplda::util::rng::Rng;

const SEED: u64 = 42;
const K: usize = 16;

struct MixResult {
    ok: u64,
    degraded: u64,
    errors: u64,
    qps: f64,
    p50_ms: f64,
    p99_ms: f64,
}

fn main() {
    let fast = std::env::var("PPLDA_BENCH_FAST").as_deref() == Ok("1");
    let (requests, clients) = if fast { (400usize, 4usize) } else { (4000, 8) };

    // A real (briefly trained) model, frozen into the serve snapshot.
    let bow = generate(&Profile::tiny(), SEED);
    let mut lda = SerialLda::init(&bow, K, 0.5, 0.1, SEED);
    for _ in 0..5 {
        lda.sweep();
    }
    let make_snap = || ModelSnapshot::from_counts(&lda.counts, 0.5, 0.1, SEED);
    let v = bow.num_words();
    println!(
        "bench_serve_qps: V={v} K={K} | {requests} requests x {clients} clients per mix"
    );

    let normal = ServeConfig::default();
    // Forced degradation: tiny queue, ramp over its whole range, one
    // worker so concurrent clients keep depth > 0 at dequeue.
    let degraded_cfg = ServeConfig {
        workers: 1,
        queue_capacity: 8,
        max_batch: 4,
        degrade_at: 0.0,
        ..ServeConfig::default()
    };

    let mut rows = Vec::new();
    for (mix, skewed, cfg) in [
        ("uniform", false, normal),
        ("skewed", true, normal),
        ("degraded", false, degraded_cfg),
    ] {
        let r = run_mix(make_snap(), cfg, mix, skewed, v, requests, clients);
        println!(
            "{mix:9} {:5} ok ({:8.1} qps) | p50 {:7.3}ms p99 {:7.3}ms | degraded {} errors {}",
            r.ok, r.qps, r.p50_ms, r.p99_ms, r.degraded, r.errors
        );
        let mut row = Json::obj();
        row.set("bench", "serve_qps")
            .set("mix", mix)
            .set("v", v)
            .set("k", K)
            .set("requests", requests)
            .set("clients", clients)
            .set("ok", r.ok)
            .set("degraded", r.degraded)
            .set("errors", r.errors)
            .set("qps", r.qps)
            .set("p50_ms", r.p50_ms)
            .set("p99_ms", r.p99_ms);
        println!("BENCH_JSON {}", row.to_string());
        rows.push((mix, r));
    }

    // Acceptance: the normal mixes never degrade and lose nothing; the
    // forced-degradation config actually sheds iterations.
    for (mix, r) in &rows {
        assert_eq!(r.errors, 0, "{mix}: queries failed");
        assert_eq!(r.ok, requests as u64, "{mix}: lost replies");
        assert!(r.qps > 0.0 && r.p99_ms > 0.0, "{mix}: empty measurement");
    }
    assert_eq!(rows[0].1.degraded, 0, "uniform mix must not degrade");
    assert_eq!(rows[1].1.degraded, 0, "skewed mix must not degrade");
    assert!(
        rows[2].1.degraded > 0,
        "forced-degradation mix produced no degraded replies"
    );
}

fn run_mix(
    snap: ModelSnapshot,
    cfg: ServeConfig,
    mix: &str,
    skewed: bool,
    v: usize,
    requests: usize,
    clients: usize,
) -> MixResult {
    let server = Arc::new(QueryServer::start(snap, cfg));
    let per_client = requests / clients;
    let started = Instant::now();
    let threads: Vec<_> = (0..clients)
        .map(|c| {
            let server = Arc::clone(&server);
            let mix = mix.to_string();
            std::thread::spawn(move || {
                let mut rng = Rng::stream(SEED ^ mix.len() as u64, c as u64);
                let mut lat_ms = Vec::with_capacity(per_client);
                let (mut ok, mut degraded, mut errors) = (0u64, 0u64, 0u64);
                for i in 0..per_client {
                    let id = (c * per_client + i) as u64;
                    let words: Vec<u32> = (0..16)
                        .map(|_| {
                            if skewed {
                                let u = rng.f64();
                                ((u * u * u * v as f64) as usize).min(v - 1) as u32
                            } else {
                                rng.gen_range(v) as u32
                            }
                        })
                        .collect();
                    let t = Instant::now();
                    match server.query(id, words, None) {
                        Ok(reply) => {
                            ok += 1;
                            degraded += u64::from(reply.degraded);
                            lat_ms.push(t.elapsed().as_secs_f64() * 1e3);
                        }
                        Err(_) => errors += 1,
                    }
                }
                (lat_ms, ok, degraded, errors)
            })
        })
        .collect();
    let (mut lat_ms, mut ok, mut degraded, mut errors) = (Vec::new(), 0, 0, 0);
    for t in threads {
        let (l, o, d, e) = t.join().unwrap();
        lat_ms.extend(l);
        ok += o;
        degraded += d;
        errors += e;
    }
    let elapsed = started.elapsed().as_secs_f64().max(1e-9);
    server.drain();
    lat_ms.sort_by(|a, b| a.total_cmp(b));
    let pct = |p: f64| -> f64 {
        if lat_ms.is_empty() {
            return 0.0;
        }
        lat_ms[((lat_ms.len() as f64 - 1.0) * p).round() as usize]
    };
    MixResult {
        ok,
        degraded,
        errors,
        qps: ok as f64 / elapsed,
        p50_ms: pct(0.50),
        p99_ms: pct(0.99),
    }
}
