//! Distributed execution overhead: the same sweep loop driven through
//! an in-process executor vs a [`DistExec`] coordinator shipping every
//! task over real localhost TCP to two `serve_on` workers.
//!
//! The distributed path pays serialization (block encode/decode, row
//! gather/scatter), kernel-state rebuilds on the worker (no resident
//! model), and socket round-trips — none of which exist in-process.
//! This bench quantifies that tax so the trajectory can watch it, and
//! asserts the contract that justifies the whole design: distributed
//! counts are bit-identical to Sequential, so the overhead buys fault
//! tolerance without buying drift.
//!
//! Emits a `BENCH_JSON dist_overhead` line with per-path sweep
//! wallclock. No wallclock bound is asserted even in slow mode: the
//! distributed path's cost is dominated by loopback latency and
//! per-task re-initialization, both of which are environment-dependent
//! in ways an in-tree bound would flake on.

use std::net::{SocketAddr, TcpListener};
use std::thread::{self, JoinHandle};

use pplda::corpus::synthetic::{generate, Profile};
use pplda::dist::{DistExec, DistOptions, WorkerOptions};
use pplda::partition::{partition, Algorithm};
use pplda::scheduler::exec::{CommitMode, ExecMode, ParallelLda};
use pplda::util::json::Json;
use pplda::util::tsv::Table;

fn spawn_workers(n: usize) -> (Vec<SocketAddr>, Vec<JoinHandle<()>>) {
    let mut addrs = Vec::new();
    let mut handles = Vec::new();
    for _ in 0..n {
        let listener = TcpListener::bind("127.0.0.1:0").expect("bind worker");
        addrs.push(listener.local_addr().expect("local addr"));
        handles.push(thread::spawn(move || {
            let opts = WorkerOptions {
                once: true,
                ..WorkerOptions::default()
            };
            let _ = pplda::dist::serve_on(listener, &opts);
        }));
    }
    (addrs, handles)
}

fn main() {
    let fast = std::env::var("PPLDA_BENCH_FAST").as_deref() == Ok("1");
    let scale = if fast { 30 } else { 6 };
    let topics = if fast { 8 } else { 32 };
    let sweeps = if fast { 3 } else { 8 };
    let restarts = if fast { 10 } else { 50 };
    let p = 4usize;
    let seed = 42;

    let bow = generate(&Profile::nips_like().scaled(scale), seed);
    let plan = partition(&bow, p, Algorithm::A3 { restarts }, seed);
    println!(
        "bench_dist_overhead: D={} W={} N={} K={topics} P={p} workers=2 \
         ({sweeps} sweeps/path, ticketed)",
        bow.num_docs(),
        bow.num_words(),
        bow.num_tokens()
    );

    let mut table = Table::new(["path", "sweep_ms", "reassigns"]);
    let mut rows = Vec::new();
    let mut wall = Vec::new();
    let mut topic_counts: Vec<Vec<u32>> = Vec::new();

    // In-process oracle: the single-process Sequential executor.
    {
        let mut lda = ParallelLda::init(&bow, &plan, topics, 0.5, 0.1, seed);
        lda.set_commit(CommitMode::Ticketed);
        lda.sweep(ExecMode::Sequential); // warm: scratch, snapshot
        let t = std::time::Instant::now();
        for _ in 0..sweeps {
            lda.sweep(ExecMode::Sequential);
        }
        let per_sweep = t.elapsed().as_secs_f64() / sweeps as f64;
        table.row(["sequential".to_string(), format!("{:.3}", per_sweep * 1e3), "0".to_string()]);
        let mut j = Json::obj();
        j.set("path", "sequential").set("sweep_secs", per_sweep);
        rows.push(j);
        wall.push(per_sweep);
        topic_counts.push(lda.counts.topic.clone());
    }

    // Distributed: two localhost workers behind a DistExec coordinator.
    {
        let (addrs, handles) = spawn_workers(2);
        let mut exec =
            DistExec::connect(&addrs, DistOptions::default()).expect("connect workers");
        let mut lda = ParallelLda::init(&bow, &plan, topics, 0.5, 0.1, seed);
        lda.set_commit(CommitMode::Ticketed);
        lda.sweep_with(&mut exec); // warm: connections, worker scratch
        let t = std::time::Instant::now();
        for _ in 0..sweeps {
            lda.sweep_with(&mut exec);
        }
        let per_sweep = t.elapsed().as_secs_f64() / sweeps as f64;
        assert_eq!(exec.reassigns(), 0, "clean run must not reassign");
        assert_eq!(exec.local_fallbacks(), 0, "workers must do all the work");
        table.row([
            "dist-2".to_string(),
            format!("{:.3}", per_sweep * 1e3),
            exec.reassigns().to_string(),
        ]);
        let mut j = Json::obj();
        j.set("path", "dist-2")
            .set("sweep_secs", per_sweep)
            .set("reassigns", exec.reassigns())
            .set("speculations", exec.speculations());
        rows.push(j);
        wall.push(per_sweep);
        topic_counts.push(lda.counts.topic.clone());
        exec.shutdown();
        for h in handles {
            let _ = h.join();
        }
    }

    println!("{}", table.to_aligned());
    assert_eq!(
        topic_counts[0], topic_counts[1],
        "distributed training must be bit-identical to sequential"
    );

    let mut summary = Json::obj();
    summary
        .set("bench", "dist_overhead")
        .set("corpus", "nips-like")
        .set("scale", scale)
        .set("topics", topics)
        .set("p", p)
        .set("sweeps", sweeps)
        .set("results", rows);
    println!("BENCH_JSON {}", summary.to_string());
    println!(
        "dist/sequential wallclock = {:.3}x (bit-identical counts)",
        wall[1] / wall[0].max(1e-12)
    );
}
