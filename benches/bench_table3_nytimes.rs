//! Table III — load-balancing ratio η on NYTimes, P ∈ {1, 10, 30, 60}.
//!
//! Paper reference rows:
//! ```text
//! P                   1    10      30      60
//! Baseline          1.0  0.9700  0.9300  0.8500
//! A1                1.0  0.9559  0.9270  0.9011
//! A2                1.0  0.9626  0.9439  0.9175
//! A3                1.0  0.9981  0.9901  0.9757
//! ```
//! NYTimes is 200× more documents than NIPS, so η is high for everyone;
//! the paper's signature crossover is that A1/A2 only clearly beat the
//! baseline at P=60 while A3 dominates everywhere. Default corpus scale
//! is ÷10 (PPLDA_NYT_SCALE to override, PPLDA_BENCH_FAST=1 → ÷40 and 10
//! restarts).

use pplda::corpus::synthetic::{generate, Profile};
use pplda::partition::{partition, Algorithm};
use pplda::util::tsv::{f, Table};

fn main() {
    let fast = std::env::var("PPLDA_BENCH_FAST").as_deref() == Ok("1");
    let restarts = if fast { 10 } else { 100 };
    let scale: usize = std::env::var("PPLDA_NYT_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(if fast { 40 } else { 10 });
    let seed = 42;

    let bow = generate(&Profile::nytimes_like().scaled(scale), seed);
    println!(
        "bench_table3_nytimes: scale=1/{scale} D={} W={} N={} (restarts={restarts})",
        bow.num_docs(),
        bow.num_words(),
        bow.num_tokens()
    );

    let procs = [1usize, 10, 30, 60];
    let paper: [(&str, [f64; 4]); 4] = [
        ("baseline", [1.0, 0.9700, 0.9300, 0.8500]),
        ("A1", [1.0, 0.9559, 0.9270, 0.9011]),
        ("A2", [1.0, 0.9626, 0.9439, 0.9175]),
        ("A3", [1.0, 0.9981, 0.9901, 0.9757]),
    ];

    let mut table = Table::new(["algorithm", "P=1", "P=10", "P=30", "P=60", "source"]);
    let mut measured = std::collections::BTreeMap::new();
    for (name, algo) in [
        ("baseline", Algorithm::Baseline { restarts }),
        ("A1", Algorithm::A1),
        ("A2", Algorithm::A2),
        ("A3", Algorithm::A3 { restarts }),
    ] {
        let etas: Vec<f64> = procs
            .iter()
            .map(|&p| partition(&bow, p, algo, seed).eta)
            .collect();
        let mut row = vec![name.to_string()];
        row.extend(etas.iter().map(|&e| f(e, 4)));
        row.push("measured".into());
        table.row(row);
        measured.insert(name, etas);
    }
    for (name, vals) in paper {
        let mut row = vec![name.to_string()];
        row.extend(vals.iter().map(|&e| f(e, 4)));
        row.push("paper".into());
        table.row(row);
    }
    println!("{}", table.to_aligned());

    // Shape: A3 dominant everywhere; all proposed beat baseline at P=60;
    // baseline η higher than on NIPS at P=60 (bigger corpus balances
    // easier).
    let p60 = 3;
    for name in ["A1", "A2", "A3"] {
        assert!(
            measured[name][p60] > measured["baseline"][p60],
            "{name} must beat baseline at P=60"
        );
    }
    for pi in 1..procs.len() {
        // Small tolerance: at reduced corpus scale / restart budget the
        // deterministic algorithms can tie A3 within noise.
        assert!(
            measured["A3"][pi] + 0.02 >= measured["A1"][pi].max(measured["A2"][pi]),
            "A3 leads at P={}",
            procs[pi]
        );
    }
    println!("shape checks passed: A3 dominates; proposed beat baseline at P=60");
}
