//! §VI-C runtime claims:
//!
//! 1. Deterministic A1/A2 are ~two orders of magnitude faster than the
//!    randomized algorithms at their paper-default restart budgets
//!    (A1/A2 run once; A3/baseline run 100×).
//! 2. Partitioning time is small relative to training — "Algorithm A3's
//!    running time is two orders of magnitude faster than the model
//!    training time."
//!
//! Measures wall time of each partitioner on NIPS (and NYTimes-like at
//! reduced scale) plus the wall time of Gibbs sweeps for comparison.

use pplda::bench::{Bench, BenchConfig};
use pplda::corpus::synthetic::{generate, Profile};
use pplda::gibbs::serial::SerialLda;
use pplda::partition::{partition_threaded, Algorithm};
use pplda::util::json::Json;
use pplda::util::tsv::f;

fn main() {
    let fast = std::env::var("PPLDA_BENCH_FAST").as_deref() == Ok("1");
    let restarts = if fast { 10 } else { 100 };
    let seed = 42;
    let p = 30;

    for (label, profile) in [
        ("NIPS", Profile::nips_like()),
        ("NYTimes/10", Profile::nytimes_like().scaled(if fast { 40 } else { 10 })),
    ] {
        let bow = generate(&profile, seed);
        println!(
            "=== {label}: D={} W={} N={} P={p} ===",
            bow.num_docs(),
            bow.num_words(),
            bow.num_tokens()
        );

        // The table measures the *serial* draw loop (threads = 1): the
        // paper's runtime claims are about total draw work, which the
        // thread fan-out below divides but does not change.
        let mut bench = Bench::new(BenchConfig::heavy());
        bench.run("A1 (deterministic)", || {
            pplda::bench::black_box(partition_threaded(&bow, p, Algorithm::A1, seed, 1));
        });
        bench.run("A2 (deterministic)", || {
            pplda::bench::black_box(partition_threaded(&bow, p, Algorithm::A2, seed, 1));
        });
        bench.run(&format!("A3 ({restarts} restarts)"), || {
            pplda::bench::black_box(partition_threaded(
                &bow,
                p,
                Algorithm::A3 { restarts },
                seed,
                1,
            ));
        });
        bench.run(&format!("baseline ({restarts} restarts)"), || {
            pplda::bench::black_box(partition_threaded(
                &bow,
                p,
                Algorithm::Baseline { restarts },
                seed,
                1,
            ));
        });

        // One Gibbs sweep for the "partitioning ≪ training" comparison
        // (training = burn-in × sweeps; paper uses ≤200 sweeps).
        let sweep_secs = if label == "NIPS" {
            let mut lda = SerialLda::init(&bow, if fast { 8 } else { 64 }, 0.5, 0.1, seed);
            let t = std::time::Instant::now();
            lda.sweep();
            Some(t.elapsed().as_secs_f64())
        } else {
            None
        };

        println!("{}", bench.table().to_aligned());
        let results = bench.results();
        let a1 = results[0].per_iter.median;
        let a2 = results[1].per_iter.median;
        let a3 = results[2].per_iter.median;
        let base = results[3].per_iter.median;
        println!(
            "speed ratios: A3/A1 = {}x, baseline/A1 = {}x, A3/A2 = {}x",
            f(a3 / a1, 1),
            f(base / a1, 1),
            f(a3 / a2, 1)
        );
        // Paper claim 1: deterministic ≫ randomized at default budgets.
        assert!(
            a3 / a1.max(1e-9) > if fast { 5.0 } else { 30.0 },
            "A3 should cost ≫ A1 at {restarts} restarts"
        );
        if let Some(sweep) = sweep_secs {
            let training = sweep * 200.0;
            println!(
                "one K=64 Gibbs sweep: {:.2}s -> 200-sweep training ≈ {:.0}s; A3 partitioning {:.2}s ({}x faster than training)",
                sweep,
                training,
                a3,
                f(training / a3, 0)
            );
            // Paper claim 2: partitioning ≪ training.
            assert!(a3 < training / 10.0, "A3 must be ≪ training time");
        }
        println!();
    }

    parallel_draws(seed, restarts, fast);
    println!("runtime shape checks passed");
}

/// Satellite payoff: the A3/baseline restart loops are embarrassingly
/// parallel (each draw's RNG stream is keyed by its index), so
/// `partition` fans them out across threads — with bit-identical plans.
/// Measures serial (threads = 1) vs fanned-out wallclock on NIPS and
/// emits a `BENCH_JSON parallel_draws` line for the perf trajectory.
fn parallel_draws(seed: u64, restarts: usize, fast: bool) {
    let threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(8);
    let bow = generate(&Profile::nips_like(), seed);
    let p = 30;
    println!("=== parallel plan draws: NIPS, P={p}, {restarts} restarts, {threads} threads ===");
    let mut rows = Vec::new();
    for (name, algo) in [
        ("A3", Algorithm::A3 { restarts }),
        ("baseline", Algorithm::Baseline { restarts }),
    ] {
        let t0 = std::time::Instant::now();
        let serial_plan = partition_threaded(&bow, p, algo, seed, 1);
        let serial_secs = t0.elapsed().as_secs_f64();
        let t1 = std::time::Instant::now();
        let par_plan = partition_threaded(&bow, p, algo, seed, threads);
        let par_secs = t1.elapsed().as_secs_f64();
        assert_eq!(
            serial_plan.doc_group, par_plan.doc_group,
            "{name}: fan-out changed the chosen plan"
        );
        assert_eq!(serial_plan.word_group, par_plan.word_group, "{name}");
        println!(
            "{name}: serial {serial_secs:.3}s, {threads} threads {par_secs:.3}s ({}x)",
            f(serial_secs / par_secs.max(1e-12), 2)
        );
        // Wallclock acceptance only where it is meaningful: several
        // cores, full restart budget (FAST mode's 10 draws are noise).
        if !fast && threads >= 2 {
            assert!(
                par_secs < serial_secs,
                "{name}: fan-out failed to beat the serial draw loop \
                 ({par_secs:.3}s vs {serial_secs:.3}s)"
            );
        }
        let mut j = Json::obj();
        j.set("algo", name)
            .set("restarts", restarts)
            .set("threads", threads)
            .set("serial_secs", serial_secs)
            .set("parallel_secs", par_secs)
            .set("eta", par_plan.eta);
        rows.push(j);
    }
    let mut summary = Json::obj();
    summary
        .set("bench", "parallel_draws")
        .set("corpus", "nips-like")
        .set("p", p)
        .set("results", rows);
    println!("BENCH_JSON {}", summary.to_string());
    println!();
}
