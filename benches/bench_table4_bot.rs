//! Table IV — BoT perplexity on the MAS corpus: nonparallel vs parallel
//! P=10 and P=30.
//!
//! Paper reference:
//! ```text
//! Nonparallel  Parallel P=10  Parallel P=30
//!   595.2567       595.0593       593.9016
//! ```
//! Expected shape: all three within a fraction of a percent of each other
//! (parallelization does not hurt topic quality; often marginally better
//! due to added stochasticity). Absolute values differ — synthetic corpus,
//! scaled size, K configurable.
//!
//! Defaults: MAS ÷50, K=64, 60 sweeps. PPLDA_BENCH_FAST=1 → MAS ÷400,
//! K=16, 10 sweeps. PPLDA_MAS_SCALE / PPLDA_BOT_ITERS override.

use pplda::coordinator::{train_bot, TrainConfig};
use pplda::corpus::synthetic::{generate_timestamped, Profile};
use pplda::partition::Algorithm;
use pplda::util::tsv::{f, Table};

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key)
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(default)
}

fn main() {
    let fast = std::env::var("PPLDA_BENCH_FAST").as_deref() == Ok("1");
    let scale = env_usize("PPLDA_MAS_SCALE", if fast { 400 } else { 50 });
    let iters = env_usize("PPLDA_BOT_ITERS", if fast { 10 } else { 60 });
    let topics = env_usize("PPLDA_BOT_TOPICS", if fast { 16 } else { 64 });
    let seed = 42;

    let profile = Profile::mas_like().scaled(scale);
    let tc = generate_timestamped(&profile, seed);
    println!(
        "bench_table4_bot: {} D={} W={} N_words={} N_stamps={} K={topics} iters={iters}",
        profile.name,
        tc.bow.num_docs(),
        tc.bow.num_words(),
        tc.bow.num_tokens(),
        tc.dts.num_tokens()
    );

    // A3 with the paper's restart budget scaled down: 100 for R, 200 for
    // R' is the paper's setting; restarts only affect partitioning time.
    let restarts = if fast { 10 } else { 100 };
    let cfg = TrainConfig {
        topics,
        iters,
        seed,
        ..Default::default()
    };

    let serial = train_bot(&tc, 1, Algorithm::A1, &cfg);
    let p10 = train_bot(&tc, 10, Algorithm::A3 { restarts }, &cfg);
    let p30 = train_bot(&tc, 30, Algorithm::A3 { restarts }, &cfg);

    let mut t = Table::new([
        "config",
        "perplexity",
        "eta_dw",
        "eta_dts",
        "speedup_model",
        "train_secs",
    ]);
    for (name, r) in [
        ("nonparallel", &serial),
        ("parallel P=10", &p10),
        ("parallel P=30", &p30),
    ] {
        t.row([
            name.to_string(),
            f(r.final_perplexity, 4),
            f(r.eta_dw, 4),
            f(r.eta_dts, 4),
            f(r.speedup_model, 2),
            f(r.train_secs, 1),
        ]);
    }
    println!("{}", t.to_aligned());
    println!("paper: nonparallel 595.2567 | P=10 595.0593 | P=30 593.9016");

    // Shape: parallel perplexity within 2% of serial (paper: within
    // 0.25%); speedup model grows with P.
    for (name, r) in [("P=10", &p10), ("P=30", &p30)] {
        let rel = (r.final_perplexity - serial.final_perplexity).abs()
            / serial.final_perplexity;
        assert!(
            rel < 0.02,
            "{name}: parallel perplexity {} vs serial {} (rel {rel:.4})",
            r.final_perplexity,
            serial.final_perplexity
        );
    }
    assert!(p30.speedup_model > p10.speedup_model);
    println!("shape checks passed: parallel ≈ nonparallel perplexity");
}
