//! §VI-C speedup model: `speedup ≈ η·P`, near-linear as η → 1.
//!
//! For each P, partitions the corpus with baseline and A3, *measures* the
//! actual epoch token costs executed by the engine (validating Eq. 1
//! against the running system), and projects parallel sweep wallclock
//! from the measured single-core sampling rate. This regenerates the
//! paper's speedup narrative on hardware with fewer cores than P.

use pplda::corpus::synthetic::{generate, Profile};
use pplda::partition::{partition, Algorithm};
use pplda::scheduler::cost_model::SpeedupReport;
use pplda::scheduler::exec::{ExecMode, ParallelLda};
use pplda::util::tsv::{f, Table};

fn main() {
    let fast = std::env::var("PPLDA_BENCH_FAST").as_deref() == Ok("1");
    let restarts = if fast { 10 } else { 100 };
    let scale = if fast { 20 } else { 4 };
    let topics = if fast { 8 } else { 32 };
    let seed = 42;

    let bow = generate(&Profile::nips_like().scaled(scale), seed);
    println!(
        "bench_speedup: D={} W={} N={} K={topics}",
        bow.num_docs(),
        bow.num_words(),
        bow.num_tokens()
    );

    // Measure the single-core sampling rate with a serial sweep.
    let mut serial = pplda::gibbs::serial::SerialLda::init(&bow, topics, 0.5, 0.1, seed);
    serial.sweep(); // warm
    let t = std::time::Instant::now();
    serial.sweep();
    let serial_secs = t.elapsed().as_secs_f64();
    let rate = bow.num_tokens() as f64 / serial_secs;
    println!(
        "serial sweep: {:.3}s ({} tokens/s)\n",
        serial_secs,
        pplda::util::human_rate(rate)
    );

    let mut table = Table::new([
        "P",
        "algo",
        "eta",
        "speedup=eta*P",
        "ideal",
        "proj_sweep_s",
        "measured_cost_ok",
    ]);
    for &p in &[2usize, 4, 8, 16, 30] {
        for (name, algo) in [
            ("baseline", Algorithm::Baseline { restarts }),
            ("A3", Algorithm::A3 { restarts }),
        ] {
            let plan = partition(&bow, p, algo, seed);
            let model = SpeedupReport::of_plan(&plan);

            // Validate the model against one executed sweep.
            let mut lda = ParallelLda::init(&bow, &plan, topics, 0.5, 0.1, seed);
            let stats = lda.sweep(ExecMode::Sequential);
            let measured = SpeedupReport::of_stats(&stats, p);
            let agree = (measured.eta - model.eta).abs() < 1e-9;

            table.row([
                p.to_string(),
                name.to_string(),
                f(model.eta, 4),
                f(model.speedup, 2),
                p.to_string(),
                format!("{:.3}", model.projected_sweep_secs(rate)),
                agree.to_string(),
            ]);
            assert!(agree, "cost model must match executed epoch costs");
        }
    }
    println!("{}", table.to_aligned());
    println!("speedup model validated against executed epoch token costs");
}
