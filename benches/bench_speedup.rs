//! §VI-C speedup model: `speedup ≈ η·P`, near-linear as η → 1.
//!
//! For each P, partitions the corpus with baseline and A3, *measures* the
//! actual epoch token costs executed by the engine (validating Eq. 1
//! against the running system), and projects parallel sweep wallclock
//! from the measured single-core sampling rate. This regenerates the
//! paper's speedup narrative on hardware with fewer cores than P.

use pplda::corpus::synthetic::{generate, Profile};
use pplda::partition::eta::EtaComparison;
use pplda::partition::{partition, Algorithm};
use pplda::scheduler::cost_model::SpeedupReport;
use pplda::scheduler::exec::{ExecMode, ParallelLda};
use pplda::scheduler::schedule::{Schedule, ScheduleKind};
use pplda::util::json::Json;
use pplda::util::tsv::{f, Table};

fn main() {
    let fast = std::env::var("PPLDA_BENCH_FAST").as_deref() == Ok("1");
    let restarts = if fast { 10 } else { 100 };
    let scale = if fast { 20 } else { 4 };
    let topics = if fast { 8 } else { 32 };
    let seed = 42;

    let bow = generate(&Profile::nips_like().scaled(scale), seed);
    println!(
        "bench_speedup: D={} W={} N={} K={topics}",
        bow.num_docs(),
        bow.num_words(),
        bow.num_tokens()
    );

    // Measure the single-core sampling rate with a serial sweep.
    let mut serial = pplda::gibbs::serial::SerialLda::init(&bow, topics, 0.5, 0.1, seed);
    serial.sweep(); // warm
    let t = std::time::Instant::now();
    serial.sweep();
    let serial_secs = t.elapsed().as_secs_f64();
    let rate = bow.num_tokens() as f64 / serial_secs;
    println!(
        "serial sweep: {:.3}s ({} tokens/s)\n",
        serial_secs,
        pplda::util::human_rate(rate)
    );

    let mut table = Table::new([
        "P",
        "algo",
        "eta",
        "speedup=eta*P",
        "ideal",
        "proj_sweep_s",
        "measured_cost_ok",
    ]);
    for &p in &[2usize, 4, 8, 16, 30] {
        for (name, algo) in [
            ("baseline", Algorithm::Baseline { restarts }),
            ("A3", Algorithm::A3 { restarts }),
        ] {
            let plan = partition(&bow, p, algo, seed);
            let model = SpeedupReport::of_plan(&plan);

            // Validate the model against one executed sweep.
            let mut lda = ParallelLda::init(&bow, &plan, topics, 0.5, 0.1, seed);
            let stats = lda.sweep(ExecMode::Sequential);
            let measured = SpeedupReport::of_stats(&stats);
            let agree = (measured.eta - model.eta).abs() < 1e-9;

            table.row([
                p.to_string(),
                name.to_string(),
                f(model.eta, 4),
                f(model.speedup, 2),
                p.to_string(),
                format!("{:.3}", model.projected_sweep_secs(rate)),
                agree.to_string(),
            ]);
            assert!(agree, "cost model must match executed epoch costs");
        }
    }
    println!("{}", table.to_aligned());
    println!("speedup model validated against executed epoch token costs");

    schedule_eta_sweep(seed, fast);
    executor_overhead(seed, fast);
}

/// Diagonal-vs-packed sweep (the schedule abstraction's payoff): at a
/// fixed worker count `W`, over-decompose the grid by `g ∈ {1,2,4,8}`
/// and LPT-pack each diagonal onto the workers. Reports the schedule-η
/// each `(algo, g)` achieves against the plain diagonal η at `P = W`,
/// and asserts the acceptance bar: packed `g = 4` is at least as
/// balanced as the diagonal baseline for all four algorithms on the
/// skewed nips-like corpus. η here is analytic (token counts, not
/// wallclock), so the assertion is noise-free. Emits a `BENCH_JSON
/// schedule_eta` line for the trajectory.
fn schedule_eta_sweep(seed: u64, fast: bool) {
    let w = 8usize;
    let restarts = if fast { 10 } else { 100 };
    let bow = generate(&Profile::nips_like(), seed);
    println!(
        "\nschedule eta sweep: D={} W={} N={} workers={w}",
        bow.num_docs(),
        bow.num_words(),
        bow.num_tokens()
    );

    let mut table = Table::new(["algo", "g", "grid", "plan_eta", "sched_eta", "diag_eta_W8"]);
    let mut results = Vec::new();
    for name in ["baseline", "A1", "A2", "A3"] {
        let algo = |restarts| match name {
            "baseline" => Algorithm::Baseline { restarts },
            "A1" => Algorithm::A1,
            "A2" => Algorithm::A2,
            _ => Algorithm::A3 { restarts },
        };
        let diag = partition(&bow, w, algo(restarts), seed);
        for g in [1usize, 2, 4, 8] {
            let grid = g * w;
            let plan = partition(&bow, grid, algo(restarts), seed);
            let schedule =
                Schedule::build(ScheduleKind::Packed { grid_factor: g }, &plan.costs, w);
            let cmp = EtaComparison::of(&plan, &schedule);
            table.row([
                name.to_string(),
                g.to_string(),
                grid.to_string(),
                f(cmp.plan.eta, 4),
                f(cmp.schedule.eta, 4),
                f(diag.eta, 4),
            ]);
            let mut j = Json::obj();
            j.set("algo", name)
                .set("grid_factor", g)
                .set("grid", grid)
                .set("plan_eta", cmp.plan.eta)
                .set("schedule_eta", cmp.schedule.eta)
                .set("diagonal_eta", diag.eta);
            results.push(j);
            if g == 4 {
                assert!(
                    cmp.schedule.eta >= diag.eta - 1e-9,
                    "{name}: packed g=4 schedule-eta {} fell below diagonal eta {} at W={w}",
                    cmp.schedule.eta,
                    diag.eta
                );
            }
        }
    }
    println!("{}", table.to_aligned());
    let mut summary = Json::obj();
    summary
        .set("bench", "schedule_eta")
        .set("corpus", "nips-like")
        .set("workers", w)
        .set("restarts", restarts)
        .set("results", results);
    println!("BENCH_JSON {}", summary.to_string());
    println!("packed g=4 >= diagonal eta at W={w} for all four algorithms");
}

/// Executor-overhead micro-benchmark: per-sweep wall time of the three
/// executors at a *small* token count, where fixed per-epoch overhead
/// (P thread spawns per epoch for Threaded, snapshot clones and scratch
/// allocation for the legacy path) dominates the sampling work. This is
/// the cost the paper's speedup tables must not contain — the pooled
/// executor's job is to make it vanish.
///
/// Emits a `BENCH_JSON` line so the speedup trajectory can track the
/// overhead across commits.
fn executor_overhead(seed: u64, fast: bool) {
    let p = 8;
    let topics = 16;
    let bow = generate(&Profile::tiny(), seed);
    let plan = partition(&bow, p, Algorithm::A3 { restarts: 10 }, seed);
    let sweeps: usize = if fast { 10 } else { 40 };
    println!(
        "\nexecutor overhead: N={} P={p} K={topics} ({sweeps} sweeps/mode)",
        bow.num_tokens()
    );

    let mut table = Table::new(["mode", "sweep_ms", "epoch_us"]);
    let mut summary = Json::obj();
    summary
        .set("bench", "executor_overhead")
        .set("tokens", bow.num_tokens())
        .set("p", p)
        .set("topics", topics)
        .set("sweeps", sweeps);
    let mut per_mode = Vec::new();
    let mut secs_of = |mode: ExecMode| -> f64 {
        let mut lda = ParallelLda::init(&bow, &plan, topics, 0.5, 0.1, seed);
        // Warm: sizes scratch, materializes the pool in Pooled mode.
        lda.sweep(mode);
        lda.sweep(mode);
        let t = std::time::Instant::now();
        for _ in 0..sweeps {
            lda.sweep(mode);
        }
        let per_sweep = t.elapsed().as_secs_f64() / sweeps as f64;
        table.row([
            mode.name().to_string(),
            format!("{:.3}", per_sweep * 1e3),
            format!("{:.1}", per_sweep * 1e6 / p as f64),
        ]);
        let mut j = Json::obj();
        j.set("mode", mode.name()).set("sweep_secs", per_sweep);
        per_mode.push(j);
        per_sweep
    };

    let sequential = secs_of(ExecMode::Sequential);
    let threaded = secs_of(ExecMode::Threaded);
    let pooled = secs_of(ExecMode::Pooled);
    println!("{}", table.to_aligned());
    summary.set("modes", per_mode);
    println!("BENCH_JSON {}", summary.to_string());

    println!(
        "pooled/threaded = {:.3}x, pooled/sequential = {:.3}x",
        pooled / threaded,
        pooled / sequential
    );
    // Acceptance: reusing workers must not cost more than respawning
    // them. Wall-clock micro-benchmarks are noisy (scheduler hiccups,
    // frequency transitions, loaded CI boxes), so the check carries a
    // generous slack and is skipped entirely in the low-iteration FAST
    // mode, where a single hiccup dominates the mean.
    if fast {
        return;
    }
    assert!(
        pooled <= threaded * 1.25,
        "pooled executor slower than legacy scoped threads: \
         {pooled:.6}s vs {threaded:.6}s per sweep"
    );
}
