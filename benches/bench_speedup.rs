//! §VI-C speedup model: `speedup ≈ η·P`, near-linear as η → 1.
//!
//! For each P, partitions the corpus with baseline and A3, *measures* the
//! actual epoch token costs executed by the engine (validating Eq. 1
//! against the running system), and projects parallel sweep wallclock
//! from the measured single-core sampling rate. This regenerates the
//! paper's speedup narrative on hardware with fewer cores than P.

use std::sync::Arc;

use pplda::corpus::shard::Residency;
use pplda::corpus::synthetic::{generate, Profile};
use pplda::kernel::KernelKind;
use pplda::obs::trace::{EventKind, Tracer};
use pplda::partition::eta::EtaComparison;
use pplda::partition::{partition, Algorithm};
use pplda::scheduler::adaptive::{BalanceMode, Measured};
use pplda::scheduler::cost_model::{MeasuredReport, SpeedupReport};
use pplda::scheduler::exec::{CommitMode, ExecMode, ParallelLda};
use pplda::scheduler::schedule::{Schedule, ScheduleKind};
use pplda::util::human_bytes;
use pplda::util::json::Json;
use pplda::util::tsv::{f, Table};

fn main() {
    let fast = std::env::var("PPLDA_BENCH_FAST").as_deref() == Ok("1");
    let restarts = if fast { 10 } else { 100 };
    let scale = if fast { 20 } else { 4 };
    let topics = if fast { 8 } else { 32 };
    let seed = 42;

    let bow = generate(&Profile::nips_like().scaled(scale), seed);
    println!(
        "bench_speedup: D={} W={} N={} K={topics}",
        bow.num_docs(),
        bow.num_words(),
        bow.num_tokens()
    );

    // Measure the single-core sampling rate with a serial sweep.
    let mut serial = pplda::gibbs::serial::SerialLda::init(&bow, topics, 0.5, 0.1, seed);
    serial.sweep(); // warm
    let t = std::time::Instant::now();
    serial.sweep();
    let serial_secs = t.elapsed().as_secs_f64();
    let rate = bow.num_tokens() as f64 / serial_secs;
    println!(
        "serial sweep: {:.3}s ({} tokens/s)\n",
        serial_secs,
        pplda::util::human_rate(rate)
    );

    let mut table = Table::new([
        "P",
        "algo",
        "eta",
        "speedup=eta*P",
        "ideal",
        "proj_sweep_s",
        "measured_cost_ok",
    ]);
    for &p in &[2usize, 4, 8, 16, 30] {
        for (name, algo) in [
            ("baseline", Algorithm::Baseline { restarts }),
            ("A3", Algorithm::A3 { restarts }),
        ] {
            let plan = partition(&bow, p, algo, seed);
            let model = SpeedupReport::of_plan(&plan);

            // Validate the model against one executed sweep.
            let mut lda = ParallelLda::init(&bow, &plan, topics, 0.5, 0.1, seed);
            let stats = lda.sweep(ExecMode::Sequential);
            let measured = SpeedupReport::of_stats(&stats);
            let agree = (measured.eta - model.eta).abs() < 1e-9;

            table.row([
                p.to_string(),
                name.to_string(),
                f(model.eta, 4),
                f(model.speedup, 2),
                p.to_string(),
                format!("{:.3}", model.projected_sweep_secs(rate)),
                agree.to_string(),
            ]);
            assert!(agree, "cost model must match executed epoch costs");
        }
    }
    println!("{}", table.to_aligned());
    println!("speedup model validated against executed epoch token costs");

    schedule_eta_sweep(seed, fast);
    executor_overhead(seed, fast);
    balance_comparison(seed, fast);
    barrier_vs_ticketed(seed, fast);
    out_of_core_smoke(seed, fast);
    tracing_overhead(seed, fast);
}

/// Observability contract: per-task span tracing must be (a) strictly
/// observational — traced training is bit-identical to untraced
/// (asserted) — and (b) cheap enough that the untraced path shows no
/// wallclock regression and the traced path stays within noise of it
/// (asserted in slow mode only; FAST micro-runs are hiccup-dominated).
/// Also asserts full span coverage: exactly one Task span per scheduled
/// task, none lost to ring overflow. Emits a `BENCH_JSON
/// tracing_overhead` line for the perf trajectory.
fn tracing_overhead(seed: u64, fast: bool) {
    let w = 4usize;
    let g = 4usize;
    let grid = g * w;
    let topics = if fast { 16 } else { 64 };
    let sweeps = if fast { 3 } else { 10 };
    let restarts = if fast { 10 } else { 50 };
    let bow = generate(&Profile::nips_like(), seed);
    let plan = partition(&bow, grid, Algorithm::A3 { restarts }, seed);
    println!(
        "\ntracing overhead: D={} W={} N={} K={topics} grid={grid} workers={w} \
         ({sweeps} sweeps/mode, ticketed pooled)",
        bow.num_docs(),
        bow.num_words(),
        bow.num_tokens()
    );

    let mut table = Table::new(["tracing", "sweep_ms", "events", "dropped"]);
    let mut rows = Vec::new();
    let mut wall = Vec::new();
    let mut topic_counts: Vec<Vec<u32>> = Vec::new();
    for traced in [false, true] {
        let mut lda = ParallelLda::init_scheduled(
            &bow,
            &plan,
            topics,
            0.5,
            0.1,
            seed,
            ScheduleKind::Packed { grid_factor: g },
            w,
        );
        lda.set_commit(CommitMode::Ticketed);
        let tracer = traced.then(|| Arc::new(Tracer::new(w)));
        lda.set_tracer(tracer.clone());
        lda.sweep(ExecMode::Pooled); // warm: pool, scratch
        let t = std::time::Instant::now();
        for _ in 0..sweeps {
            lda.sweep(ExecMode::Pooled);
        }
        let per_sweep = t.elapsed().as_secs_f64() / sweeps as f64;
        let (events, dropped, task_spans) = match &tracer {
            Some(tr) => {
                let evs = tr.take();
                let tasks = evs.iter().filter(|e| e.kind == EventKind::Task).count();
                (evs.len() as u64, tr.dropped(), tasks as u64)
            }
            None => (0, 0, 0),
        };
        table.row([
            if traced { "on" } else { "off" }.to_string(),
            format!("{:.3}", per_sweep * 1e3),
            events.to_string(),
            dropped.to_string(),
        ]);
        let mut j = Json::obj();
        j.set("tracing", traced)
            .set("sweep_secs", per_sweep)
            .set("events", events)
            .set("dropped", dropped);
        rows.push(j);
        wall.push(per_sweep);
        topic_counts.push(lda.counts.topic.clone());
        if traced {
            assert_eq!(dropped, 0, "trace rings overflowed");
            // Warm sweep + timed sweeps, grid x grid tasks per sweep,
            // each covered by exactly one Task span.
            let expect = ((sweeps + 1) * grid * grid) as u64;
            assert_eq!(
                task_spans, expect,
                "trace must cover every scheduled task exactly once"
            );
        }
    }
    println!("{}", table.to_aligned());
    assert_eq!(
        topic_counts[0], topic_counts[1],
        "traced training must be bit-identical to untraced"
    );

    let mut summary = Json::obj();
    summary
        .set("bench", "tracing_overhead")
        .set("corpus", "nips-like")
        .set("workers", w)
        .set("grid_factor", g)
        .set("topics", topics)
        .set("sweeps", sweeps)
        .set("results", rows);
    println!("BENCH_JSON {}", summary.to_string());
    println!(
        "traced/untraced wallclock = {:.3}x (bit-identical counts)",
        wall[1] / wall[0].max(1e-12)
    );

    // Wallclock bound: slow mode only (micro-benchmark noise; see the
    // executor-overhead bench for the rationale).
    if fast {
        return;
    }
    assert!(
        wall[1] <= wall[0] * 1.25,
        "tracing overhead broke the noise bound: {:.4}s traced vs {:.4}s untraced per sweep",
        wall[1],
        wall[0]
    );
}

/// Tentpole payoff: the scatter → epoch-barrier → gather protocol vs the
/// ticketed pipeline on the skewed nips-like corpus, packed `P = 4·W` so
/// the in-order committer has run-ahead room (tickets fold while later
/// tickets are still sampling). Both runs must train bit-identically
/// (asserted), and the ticketed protocol's residual in-order work — its
/// O(K) snapshot republish plus the blocking tail folds — must cost at
/// most 0.7× the barrier protocol's gather bucket (asserted: the buckets
/// are CPU-work sums over all epochs, not end-to-end wallclock, so the
/// bound is stable on loaded boxes). Emits a `BENCH_JSON
/// barrier_vs_ticketed` line with per-mode wallclock, phase buckets, and
/// measured-η for the perf trajectory.
fn barrier_vs_ticketed(seed: u64, fast: bool) {
    let w = 4usize;
    let g = 4usize;
    let grid = g * w;
    let topics = if fast { 16 } else { 64 };
    let sweeps = if fast { 3 } else { 10 };
    let restarts = if fast { 10 } else { 50 };
    let bow = generate(&Profile::nips_like(), seed);
    let plan = partition(&bow, grid, Algorithm::A3 { restarts }, seed);
    println!(
        "\nbarrier vs ticketed: D={} W={} N={} K={topics} grid={grid} workers={w} \
         ({sweeps} sweeps/mode)",
        bow.num_docs(),
        bow.num_words(),
        bow.num_tokens()
    );

    let mut table = Table::new([
        "commit",
        "sweep_ms",
        "barrier_ms",
        "commit_ms",
        "runahead_ms",
        "measured_eta",
    ]);
    let mut rows = Vec::new();
    // Per-mode (barrier_secs, commit_secs) sums over all sweeps.
    let mut buckets: Vec<(f64, f64)> = Vec::new();
    let mut counts = Vec::new();
    for commit in [CommitMode::Barrier, CommitMode::Ticketed] {
        let mut lda = ParallelLda::init_scheduled(
            &bow,
            &plan,
            topics,
            0.5,
            0.1,
            seed,
            ScheduleKind::Packed { grid_factor: g },
            w,
        );
        lda.set_commit(commit);
        lda.sweep(ExecMode::Pooled); // warm: pool, scratch
        let t = std::time::Instant::now();
        let mut stats = Vec::with_capacity(sweeps);
        for _ in 0..sweeps {
            stats.push(lda.sweep(ExecMode::Pooled));
        }
        let sweep_secs = t.elapsed().as_secs_f64() / sweeps as f64;
        let barrier_secs: f64 = stats.iter().map(|s| s.barrier_secs).sum();
        let commit_secs: f64 = stats.iter().map(|s| s.commit_secs).sum();
        let runahead_secs: f64 = stats.iter().map(|s| s.runahead_secs).sum();
        let mr = MeasuredReport::of_sweeps(stats.iter());
        table.row([
            commit.name().to_string(),
            format!("{:.3}", sweep_secs * 1e3),
            format!("{:.3}", barrier_secs * 1e3),
            format!("{:.3}", commit_secs * 1e3),
            format!("{:.3}", runahead_secs * 1e3),
            f(mr.eta, 4),
        ]);
        let mut j = Json::obj();
        j.set("commit", commit.name())
            .set("sweep_secs", sweep_secs)
            .set("barrier_secs", barrier_secs)
            .set("commit_secs", commit_secs)
            .set("runahead_secs", runahead_secs)
            .set("measured_eta", mr.eta);
        rows.push(j);
        buckets.push((barrier_secs, commit_secs));
        counts.push((lda.counts.word_topic.clone(), lda.counts.topic.clone()));
    }
    println!("{}", table.to_aligned());
    assert_eq!(
        counts[0], counts[1],
        "ticketed training must be bit-identical to the barrier protocol"
    );

    let mut summary = Json::obj();
    summary
        .set("bench", "barrier_vs_ticketed")
        .set("corpus", "nips-like")
        .set("workers", w)
        .set("grid_factor", g)
        .set("topics", topics)
        .set("sweeps", sweeps)
        .set("results", rows);
    println!("BENCH_JSON {}", summary.to_string());

    // Acceptance: the in-order commit pipeline must retire the gather off
    // the critical path — what remains serialized (snapshot republish +
    // blocking tail folds) is bounded well below the barrier protocol's
    // full per-epoch merge.
    let (barrier_gather, _) = buckets[0];
    let (ticketed_barrier, ticketed_commit) = buckets[1];
    let residual = ticketed_barrier + ticketed_commit;
    println!(
        "ticketed residual commit work = {:.4}x of the barrier gather \
         ({:.6}s vs {:.6}s over {sweeps} sweeps)",
        residual / barrier_gather.max(1e-12),
        residual,
        barrier_gather
    );
    assert!(
        residual <= barrier_gather * 0.7,
        "ticketed commit failed to hide the gather: residual {residual:.6}s vs \
         barrier {barrier_gather:.6}s (bound 0.7x)"
    );
}

/// Process peak RSS (`VmHWM`) in bytes, if the platform exposes it.
fn peak_rss_bytes() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    let line = status.lines().find(|l| l.starts_with("VmHWM:"))?;
    let kb: u64 = line.split_whitespace().nth(1)?.parse().ok()?;
    Some(kb * 1024)
}

/// Out-of-core acceptance: a memory-budgeted spill run on a
/// NYTimes-shaped synthetic corpus must (a) train bit-identically to
/// in-core, (b) keep resident token bytes inside the budget, and (c) —
/// thanks to the prefetch thread overlapping loads with sampling — stay
/// within ~1.5× of in-core wallclock (asserted in slow mode only;
/// micro-runs on loaded CI boxes make wallclock ratios meaningless).
/// Emits `BENCH_JSON out_of_core` rows (wallclock, trainer-tracked peak
/// resident bytes, and process peak RSS) for the perf trajectory.
fn out_of_core_smoke(seed: u64, fast: bool) {
    let scale = if fast { 600 } else { 60 };
    let topics = if fast { 8 } else { 32 };
    let sweeps = if fast { 3 } else { 6 };
    let restarts = if fast { 5 } else { 20 };
    let (grid, w) = (4usize, 4usize);
    let bow = generate(&Profile::nytimes_like().scaled(scale), seed);
    let plan = partition(&bow, grid, Algorithm::A3 { restarts }, seed);
    let corpus_bytes = bow.num_tokens() * 12;
    // Roughly two of the four diagonals plus slack — the budget the
    // prefetch window must respect.
    let budget = corpus_bytes * 5 / 8;
    println!(
        "\nout-of-core smoke: D={} W={} N={} K={topics} grid={grid} workers={w} \
         ({sweeps} sweeps/residency, budget {})",
        bow.num_docs(),
        bow.num_words(),
        bow.num_tokens(),
        human_bytes(budget as usize)
    );

    let mut table = Table::new(["residency", "sweep_ms", "peak_resident", "peak_rss"]);
    let mut rows = Vec::new();
    let mut wall = Vec::new();
    let mut topic_counts: Vec<Vec<u32>> = Vec::new();
    for residency in [Residency::InCore, Residency::Spill { budget_bytes: budget }] {
        let mut lda = ParallelLda::init_resident(
            &bow,
            &plan,
            topics,
            0.5,
            0.1,
            seed,
            ScheduleKind::Diagonal,
            w,
            residency,
        )
        .expect("init");
        lda.sweep(ExecMode::Pooled); // warm: pool, scratch, first loads
        let t = std::time::Instant::now();
        for _ in 0..sweeps {
            lda.sweep(ExecMode::Pooled);
        }
        let per_sweep = t.elapsed().as_secs_f64() / sweeps as f64;
        let peak = lda.peak_resident_bytes();
        let rss = peak_rss_bytes().unwrap_or(0);
        table.row([
            residency.label(),
            format!("{:.3}", per_sweep * 1e3),
            human_bytes(peak as usize),
            human_bytes(rss as usize),
        ]);
        let mut j = Json::obj();
        j.set("residency", residency.name())
            .set("sweep_secs", per_sweep)
            .set("peak_resident_bytes", peak)
            .set("peak_rss_bytes", rss);
        rows.push(j);
        wall.push(per_sweep);
        topic_counts.push(lda.counts.topic.clone());
        if let Residency::Spill { budget_bytes } = residency {
            assert!(
                peak <= budget_bytes,
                "resident token bytes {peak} exceeded the {budget_bytes} budget"
            );
            assert!(
                peak < corpus_bytes,
                "spill mode held the whole corpus ({peak} vs {corpus_bytes})"
            );
        }
    }
    println!("{}", table.to_aligned());
    assert_eq!(
        topic_counts[0], topic_counts[1],
        "spill training must be bit-identical to in-core"
    );

    let mut summary = Json::obj();
    summary
        .set("bench", "out_of_core")
        .set("corpus", "nytimes-like")
        .set("scale", scale)
        .set("topics", topics)
        .set("sweeps", sweeps)
        .set("workers", w)
        .set("budget_bytes", budget)
        .set("results", rows);
    println!("BENCH_JSON {}", summary.to_string());
    println!(
        "spill/in-core wallclock = {:.3}x (bit-identical counts)",
        wall[1] / wall[0].max(1e-12)
    );

    // Wallclock bound: slow mode only (see the executor-overhead bench
    // for the rationale on micro-benchmark noise).
    if fast {
        return;
    }
    assert!(
        wall[1] <= wall[0] * 1.5,
        "prefetch overlap failed to keep spill within 1.5x of in-core: \
         {:.4}s vs {:.4}s per sweep",
        wall[1],
        wall[0]
    );
}

/// Tentpole payoff: static token-LPT vs adaptive measured-cost
/// re-packing vs work stealing, under the *sparse* kernel on the skewed
/// nips-like corpus — exactly the regime where per-token cost is
/// non-uniform (it tracks `k_doc + k_word`, not 1) and token-count
/// packing mis-balances real wallclock.
///
/// Emits a `BENCH_JSON balance_modes` line with per-mode sweep wallclock
/// and measured-η next to token-η, and asserts two things:
///
/// 1. (deterministic, runs in CI FAST mode) Re-packing against the
///    measured per-partition cost field can only shrink the modeled
///    critical path relative to the token packing evaluated on the same
///    field — the static-vs-adaptive η smoke assert.
/// 2. (slow mode only, wallclock) adaptive or stealing beats static on
///    measured sweep η — the paper-level claim that runtime balancing
///    recovers what the token proxy loses.
fn balance_comparison(seed: u64, fast: bool) {
    let w = 4usize;
    let g = 4usize;
    let grid = g * w;
    let topics = if fast { 16 } else { 64 };
    let sweeps = if fast { 3 } else { 10 };
    let restarts = if fast { 10 } else { 50 };
    let bow = generate(&Profile::nips_like(), seed);
    let plan = partition(&bow, grid, Algorithm::A3 { restarts }, seed);
    println!(
        "\nbalance comparison: D={} W={} N={} K={topics} kernel=sparse grid={grid} workers={w} \
         ({sweeps} sweeps/mode)",
        bow.num_docs(),
        bow.num_words(),
        bow.num_tokens()
    );

    let mut table = Table::new(["balance", "sweep_ms", "measured_eta", "token_eta"]);
    let mut rows = Vec::new();
    let mut measured: Vec<(&'static str, f64)> = Vec::new();
    let mut static_stats = Vec::new();
    for balance in [BalanceMode::Static, BalanceMode::Adaptive, BalanceMode::Steal] {
        let mut lda = ParallelLda::init_scheduled(
            &bow,
            &plan,
            topics,
            0.5,
            0.1,
            seed,
            ScheduleKind::Packed { grid_factor: g },
            w,
        );
        lda.set_kernel(KernelKind::Sparse);
        lda.set_balance(balance);
        // Warm: pool + kernel scratch; gives Adaptive its first
        // measurements to repack from.
        lda.sweep(ExecMode::Pooled);
        let t = std::time::Instant::now();
        let mut stats = Vec::with_capacity(sweeps);
        for _ in 0..sweeps {
            stats.push(lda.sweep(ExecMode::Pooled));
        }
        let sweep_secs = t.elapsed().as_secs_f64() / sweeps as f64;
        let mr = MeasuredReport::of_sweeps(stats.iter());
        let token_eta = SpeedupReport::of_stats(stats.last().unwrap()).eta;
        table.row([
            balance.name().to_string(),
            format!("{:.3}", sweep_secs * 1e3),
            f(mr.eta, 4),
            f(token_eta, 4),
        ]);
        let mut j = Json::obj();
        j.set("balance", balance.name())
            .set("sweep_secs", sweep_secs)
            .set("measured_eta", mr.eta)
            .set("token_eta", token_eta);
        rows.push(j);
        measured.push((balance.name(), mr.eta));
        if balance == BalanceMode::Static {
            static_stats = stats;
        }
    }
    println!("{}", table.to_aligned());

    // (1) The deterministic smoke assert: feed a Measured estimator the
    // static run's real telemetry, then compare the modeled critical
    // path of the token packing vs the repacked schedule under that same
    // cost field.
    let mut est = Measured::new(grid);
    for st in &static_stats {
        est.observe_sweep(&plan.costs, &st.task_nanos);
    }
    let mut schedule = Schedule::build(ScheduleKind::Packed { grid_factor: g }, &plan.costs, w);
    let model_cost = |s: &Schedule, est: &Measured| {
        use pplda::scheduler::adaptive::CostEstimator;
        use pplda::scheduler::schedule::partition_id;
        s.cost_with(|m, n| est.estimate(partition_id(m, n, grid), plan.costs.get(m, n)))
    };
    let static_crit = model_cost(&schedule, &est);
    est.repack(&mut schedule, &plan.costs);
    let adaptive_crit = model_cost(&schedule, &est);
    println!(
        "modeled crit (measured cost field): static {static_crit} ns vs repacked \
         {adaptive_crit} ns (ratio {:.4})",
        adaptive_crit as f64 / static_crit.max(1) as f64
    );
    // LPT is a (4/3 − 1/(3W))-approximation (Graham), and the token
    // packing can never beat OPT on the measured field, so the repacked
    // crit is bounded by 4/3 × the token packing's — a theorem-backed
    // ceiling that cannot flake, while still catching a repack that
    // produces garbage. (In practice the ratio is ≤ 1: the repack
    // optimizes the very objective being scored; but LPT's
    // non-optimality means that is not a guarantee.)
    assert!(
        adaptive_crit as f64 <= static_crit as f64 * (4.0 / 3.0) + 1.0,
        "repacking against measured costs exceeded the LPT bound vs token packing: \
         {adaptive_crit} vs {static_crit}"
    );

    let mut summary = Json::obj();
    summary
        .set("bench", "balance_modes")
        .set("corpus", "nips-like")
        .set("kernel", "sparse")
        .set("workers", w)
        .set("grid_factor", g)
        .set("topics", topics)
        .set("sweeps", sweeps)
        .set("modeled_static_crit_nanos", static_crit)
        .set("modeled_adaptive_crit_nanos", adaptive_crit)
        .set("results", rows);
    println!("BENCH_JSON {}", summary.to_string());

    // (2) Measured-η ordering (wallclock-derived), slow mode only:
    // micro-noise on loaded CI boxes makes this assert meaningless at 3
    // sweeps.
    if fast {
        return;
    }
    let eta_of = |name: &str| measured.iter().find(|(n, _)| *n == name).unwrap().1;
    let best_dynamic = eta_of("adaptive").max(eta_of("steal"));
    assert!(
        best_dynamic >= eta_of("static") - 0.05,
        "neither adaptive ({:.4}) nor stealing ({:.4}) kept up with static ({:.4}) measured-eta",
        eta_of("adaptive"),
        eta_of("steal"),
        eta_of("static")
    );
}

/// Diagonal-vs-packed sweep (the schedule abstraction's payoff): at a
/// fixed worker count `W`, over-decompose the grid by `g ∈ {1,2,4,8}`
/// and LPT-pack each diagonal onto the workers. Reports the schedule-η
/// each `(algo, g)` achieves against the plain diagonal η at `P = W`,
/// and asserts the acceptance bar: packed `g = 4` is at least as
/// balanced as the diagonal baseline for all four algorithms on the
/// skewed nips-like corpus. η here is analytic (token counts, not
/// wallclock), so the assertion is noise-free. Emits a `BENCH_JSON
/// schedule_eta` line for the trajectory.
fn schedule_eta_sweep(seed: u64, fast: bool) {
    let w = 8usize;
    let restarts = if fast { 10 } else { 100 };
    let bow = generate(&Profile::nips_like(), seed);
    println!(
        "\nschedule eta sweep: D={} W={} N={} workers={w}",
        bow.num_docs(),
        bow.num_words(),
        bow.num_tokens()
    );

    let mut table = Table::new(["algo", "g", "grid", "plan_eta", "sched_eta", "diag_eta_W8"]);
    let mut results = Vec::new();
    for name in ["baseline", "A1", "A2", "A3"] {
        let algo = |restarts| match name {
            "baseline" => Algorithm::Baseline { restarts },
            "A1" => Algorithm::A1,
            "A2" => Algorithm::A2,
            _ => Algorithm::A3 { restarts },
        };
        let diag = partition(&bow, w, algo(restarts), seed);
        for g in [1usize, 2, 4, 8] {
            let grid = g * w;
            let plan = partition(&bow, grid, algo(restarts), seed);
            let schedule =
                Schedule::build(ScheduleKind::Packed { grid_factor: g }, &plan.costs, w);
            let cmp = EtaComparison::of(&plan, &schedule);
            table.row([
                name.to_string(),
                g.to_string(),
                grid.to_string(),
                f(cmp.plan.eta, 4),
                f(cmp.schedule.eta, 4),
                f(diag.eta, 4),
            ]);
            let mut j = Json::obj();
            j.set("algo", name)
                .set("grid_factor", g)
                .set("grid", grid)
                .set("plan_eta", cmp.plan.eta)
                .set("schedule_eta", cmp.schedule.eta)
                .set("diagonal_eta", diag.eta);
            results.push(j);
            if g == 4 {
                assert!(
                    cmp.schedule.eta >= diag.eta - 1e-9,
                    "{name}: packed g=4 schedule-eta {} fell below diagonal eta {} at W={w}",
                    cmp.schedule.eta,
                    diag.eta
                );
            }
        }
    }
    println!("{}", table.to_aligned());
    let mut summary = Json::obj();
    summary
        .set("bench", "schedule_eta")
        .set("corpus", "nips-like")
        .set("workers", w)
        .set("restarts", restarts)
        .set("results", results);
    println!("BENCH_JSON {}", summary.to_string());
    println!("packed g=4 >= diagonal eta at W={w} for all four algorithms");
}

/// Executor-overhead micro-benchmark: per-sweep wall time of the three
/// executors at a *small* token count, where fixed per-epoch overhead
/// (P thread spawns per epoch for Threaded, snapshot clones and scratch
/// allocation for the legacy path) dominates the sampling work. This is
/// the cost the paper's speedup tables must not contain — the pooled
/// executor's job is to make it vanish.
///
/// Emits a `BENCH_JSON` line so the speedup trajectory can track the
/// overhead across commits.
fn executor_overhead(seed: u64, fast: bool) {
    let p = 8;
    let topics = 16;
    let bow = generate(&Profile::tiny(), seed);
    let plan = partition(&bow, p, Algorithm::A3 { restarts: 10 }, seed);
    let sweeps: usize = if fast { 10 } else { 40 };
    println!(
        "\nexecutor overhead: N={} P={p} K={topics} ({sweeps} sweeps/mode)",
        bow.num_tokens()
    );

    let mut table = Table::new(["mode", "sweep_ms", "epoch_us"]);
    let mut summary = Json::obj();
    summary
        .set("bench", "executor_overhead")
        .set("tokens", bow.num_tokens())
        .set("p", p)
        .set("topics", topics)
        .set("sweeps", sweeps);
    let mut per_mode = Vec::new();
    let mut secs_of = |mode: ExecMode| -> f64 {
        let mut lda = ParallelLda::init(&bow, &plan, topics, 0.5, 0.1, seed);
        // Warm: sizes scratch, materializes the pool in Pooled mode.
        lda.sweep(mode);
        lda.sweep(mode);
        let t = std::time::Instant::now();
        for _ in 0..sweeps {
            lda.sweep(mode);
        }
        let per_sweep = t.elapsed().as_secs_f64() / sweeps as f64;
        table.row([
            mode.name().to_string(),
            format!("{:.3}", per_sweep * 1e3),
            format!("{:.1}", per_sweep * 1e6 / p as f64),
        ]);
        let mut j = Json::obj();
        j.set("mode", mode.name()).set("sweep_secs", per_sweep);
        per_mode.push(j);
        per_sweep
    };

    let sequential = secs_of(ExecMode::Sequential);
    let threaded = secs_of(ExecMode::Threaded);
    let pooled = secs_of(ExecMode::Pooled);
    println!("{}", table.to_aligned());
    summary.set("modes", per_mode);
    println!("BENCH_JSON {}", summary.to_string());

    println!(
        "pooled/threaded = {:.3}x, pooled/sequential = {:.3}x",
        pooled / threaded,
        pooled / sequential
    );
    // Acceptance: reusing workers must not cost more than respawning
    // them. Wall-clock micro-benchmarks are noisy (scheduler hiccups,
    // frequency transitions, loaded CI boxes), so the check carries a
    // generous slack and is skipped entirely in the low-iteration FAST
    // mode, where a single hiccup dominates the mean.
    if fast {
        return;
    }
    assert!(
        pooled <= threaded * 1.25,
        "pooled executor slower than legacy scoped threads: \
         {pooled:.6}s vs {threaded:.6}s per sweep"
    );
}
