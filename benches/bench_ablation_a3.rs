//! Ablations on the design choices behind the proposed algorithms:
//!
//! 1. **A3 restart budget** — η as a function of restarts (1/10/100);
//!    the paper repeats A3 100× (200× for R'). Shows diminishing returns.
//! 2. **Permutation vs split** — the proposed algorithms change *two*
//!    things relative to Yan et al.: the ordering heuristic and the
//!    equal-token (vs equal-count) split. This ablation crosses them:
//!    {uniform, A3-stratified} × {equal-count, equal-mass}, attributing
//!    the gain to each component.
//! 3. **Restart-equalized comparison** — baseline with the same wallclock
//!    budget as A3 (same restarts) still loses: the stratified proposal
//!    distribution, not the search budget, is the win.

use pplda::corpus::synthetic::{generate, Profile};
use pplda::partition::{eta, partition, permutation, split, Algorithm};
use pplda::util::rng::Rng;
use pplda::util::tsv::{f, Table};

fn main() {
    let fast = std::env::var("PPLDA_BENCH_FAST").as_deref() == Ok("1");
    let scale = if fast { 20 } else { 1 };
    let seed = 42;
    let p = 30;

    let bow = generate(&Profile::nips_like().scaled(scale), seed);
    println!(
        "bench_ablation_a3: D={} W={} N={} P={p}\n",
        bow.num_docs(),
        bow.num_words(),
        bow.num_tokens()
    );

    // ---- 1. restart budget ----
    let mut t1 = Table::new(["restarts", "A3_eta", "baseline_eta"]);
    let budgets: &[usize] = if fast { &[1, 4, 10] } else { &[1, 10, 100] };
    let mut prev_a3 = 0.0;
    for &r in budgets {
        let a3 = partition(&bow, p, Algorithm::A3 { restarts: r }, seed).eta;
        let base = partition(&bow, p, Algorithm::Baseline { restarts: r }, seed).eta;
        t1.row([r.to_string(), f(a3, 4), f(base, 4)]);
        assert!(a3 >= prev_a3 - 1e-12, "A3 eta must be monotone in restarts");
        assert!(a3 > base, "A3 beats baseline at equal budget {r}");
        prev_a3 = a3;
    }
    println!("restart budget:\n{}", t1.to_aligned());

    // ---- 2. permutation × split cross ----
    let mut t2 = Table::new(["permutation", "split", "eta"]);
    let mut rng = Rng::stream(seed, 1);
    let orders: [(&str, Vec<u32>, Vec<u32>); 2] = [
        (
            "uniform (Yan)",
            permutation::uniform_shuffle(bow.num_docs(), &mut rng),
            permutation::uniform_shuffle(bow.num_words(), &mut rng),
        ),
        (
            "A3 stratified",
            permutation::stratified_shuffle(bow.row_sums(), p, &mut rng),
            permutation::stratified_shuffle(bow.col_sums(), p, &mut rng),
        ),
    ];
    let mut cross = std::collections::BTreeMap::new();
    for (oname, dorder, worder) in &orders {
        for (sname, equal_mass) in [("equal-count", false), ("equal-mass", true)] {
            let (dg, wg) = if equal_mass {
                (
                    split::split_equal_mass(dorder, bow.row_sums(), p),
                    split::split_equal_mass(worder, bow.col_sums(), p),
                )
            } else {
                (
                    split::split_equal_count(dorder, p),
                    split::split_equal_count(worder, p),
                )
            };
            let e = eta::eta(&bow, &dg, &wg, p).eta;
            t2.row([oname.to_string(), sname.to_string(), f(e, 4)]);
            cross.insert((*oname, sname), e);
        }
    }
    println!("permutation × split (single draw each):\n{}", t2.to_aligned());
    // Both components must contribute on the skewed corpus.
    assert!(
        cross[&("uniform (Yan)", "equal-mass")] > cross[&("uniform (Yan)", "equal-count")],
        "equal-mass split alone should improve on Yan's equal-count"
    );
    assert!(
        cross[&("A3 stratified", "equal-count")]
            > cross[&("uniform (Yan)", "equal-count")],
        "stratification should improve on uniform under the equal-count split"
    );
    // Under the equal-mass split, single draws of stratified vs uniform
    // are comparable (wide tolerance): stratification's value there is
    // variance reduction across restarts, which section 1/3 measure.
    assert!(
        cross[&("A3 stratified", "equal-mass")]
            >= cross[&("uniform (Yan)", "equal-mass")] - 0.06,
        "stratified permutation should not substantially hurt"
    );

    // ---- 3. equalized-budget head-to-head ----
    let r = if fast { 10 } else { 100 };
    let a3 = partition(&bow, p, Algorithm::A3 { restarts: r }, seed);
    let base = partition(&bow, p, Algorithm::Baseline { restarts: r }, seed);
    println!(
        "equal budget ({r} restarts): A3 {} vs baseline {} -> A3 wins by {:.2}%",
        f(a3.eta, 4),
        f(base.eta, 4),
        100.0 * (a3.eta - base.eta) / base.eta
    );
    assert!(a3.eta > base.eta);
    println!("\nablation checks passed");
}
