"""Pure-jnp correctness oracles for the Pallas kernels.

These are the ground-truth implementations of the two per-token block
computations that the rust coordinator offloads:

* ``topic_sample_ref`` — collapsed-Gibbs conditional + Gumbel-max draw for
  a batch of B tokens: ``p(k) ∝ (n_jk + α)(n_kw + β) / (n_k + Wβ)``.
* ``loglik_ref`` — per-token log-likelihood used by the paper's training
  perplexity (Eq. 3-4): ``log Σ_k θ_{k|j} φ_{w|k}`` with
  ``θ_{k|j} = (n_jk + α)/(n_j + Kα)`` and ``φ_{w|k} = (n_kw + β)/(n_k + Wβ)``.

The Pallas kernels in ``topic_sample.py`` / ``perplexity.py`` must match
these up to float tolerance; ``python/tests`` sweeps shapes with
hypothesis and asserts allclose.
"""

import jax.numpy as jnp

# Index layout of the packed scalar-parameter row (shape [1, 4]).
P_ALPHA = 0   # Dirichlet prior on document-topic
P_BETA = 1    # Dirichlet prior on topic-word
P_KALPHA = 2  # K * alpha  (theta normalizer)
P_WBETA = 3   # W * beta   (phi   normalizer)


def gumbel_from_uniform(u):
    """Map uniforms in (0,1) to standard Gumbel noise, clamped for safety."""
    eps = jnp.float32(1e-20)
    return -jnp.log(-jnp.log(jnp.maximum(u, eps)) + eps)


def topic_logits_ref(njk, nkw, nk, params):
    """Unnormalized log conditional of collapsed Gibbs for each (token, k).

    njk: [B, K] doc-topic counts for each token's document (token excluded)
    nkw: [B, K] topic-word counts for each token's word   (token excluded)
    nk:  [1, K] topic totals                              (token excluded)
    params: [1, 4] packed scalars (alpha, beta, kalpha, wbeta)
    returns: [B, K] float32 logits
    """
    alpha = params[0, P_ALPHA]
    beta = params[0, P_BETA]
    wbeta = params[0, P_WBETA]
    return (
        jnp.log(njk + alpha)
        + jnp.log(nkw + beta)
        - jnp.log(nk + wbeta)
    )


def topic_sample_ref(njk, nkw, nk, unif, params):
    """Gumbel-max categorical draw from the collapsed Gibbs conditional.

    unif: [B, K] i.i.d. uniforms in (0, 1) supplied by the coordinator's
    deterministic PRNG, so draws are reproducible across backends.
    returns: [B] int32 sampled topics.
    """
    logits = topic_logits_ref(njk, nkw, nk, params)
    g = gumbel_from_uniform(unif)
    return jnp.argmax(logits + g, axis=1).astype(jnp.int32)


def loglik_ref(njk, nj, nkw, nk, params):
    """Per-token log-likelihood  log Σ_k θ_{k|j} φ_{w|k}  (paper Eq. 4).

    njk: [B, K]; nj: [B, 1] doc lengths; nkw: [B, K]; nk: [1, K];
    params: [1, 4]. returns: [B] float32.
    """
    alpha = params[0, P_ALPHA]
    beta = params[0, P_BETA]
    kalpha = params[0, P_KALPHA]
    wbeta = params[0, P_WBETA]
    theta = (njk + alpha) / (nj + kalpha)
    phi = (nkw + beta) / (nk + wbeta)
    return jnp.log(jnp.sum(theta * phi, axis=1))


def pack_params(alpha, beta, num_topics, num_words):
    """Pack model hyperparameters into the [1, 4] scalar row."""
    return jnp.array(
        [[alpha, beta, num_topics * alpha, num_words * beta]],
        dtype=jnp.float32,
    )
