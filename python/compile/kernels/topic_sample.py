"""L1 Pallas kernel: blocked collapsed-Gibbs topic sampling.

The hot spot of collapsed Gibbs sampling is, per token (j, w),

    p(k) ∝ (n_jk + α)(n_kw + β) / (n_k + Wβ),     k = 1..K

followed by a categorical draw. For a batch of B tokens inside one
conflict-free partition this is dense [B, K] arithmetic: elementwise logs
on the VPU and a lane reduction (argmax) per token. The kernel is tiled
over the batch dimension with ``BlockSpec`` so one ``[Bt, K]`` tile of each
operand is VMEM-resident per grid step — the TPU analogue of the
threadblock tiling used by the paper's GPU substrate (Yan et al. 2009).

The categorical draw is Gumbel-max over supplied uniforms, which keeps the
kernel deterministic given the coordinator's PRNG stream and avoids an
in-kernel RNG.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernel is lowered through the interpreter to plain
HLO. Real-TPU tiling/VMEM estimates live in DESIGN.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# Default batch tile. K is never tiled: one token's full topic row must be
# resident for the argmax reduction, and K ≤ 1024 keeps a [Bt, K] f32 tile
# (128*1024*4 = 512 KiB) comfortably inside a TPU core's ~16 MiB VMEM even
# with 4 operands double-buffered.
DEFAULT_BLOCK_B = 128


def _topic_sample_kernel(njk_ref, nkw_ref, nk_ref, unif_ref, params_ref,
                         out_ref):
    """One [Bt, K] tile: logits + Gumbel noise, argmax over K."""
    alpha = params_ref[0, ref.P_ALPHA]
    beta = params_ref[0, ref.P_BETA]
    wbeta = params_ref[0, ref.P_WBETA]
    eps = jnp.float32(1e-20)

    njk = njk_ref[...]
    nkw = nkw_ref[...]
    nk = nk_ref[...]          # [1, K], broadcasts over the tile
    u = unif_ref[...]

    logits = (
        jnp.log(njk + alpha)
        + jnp.log(nkw + beta)
        - jnp.log(nk + wbeta)
    )
    gumbel = -jnp.log(-jnp.log(jnp.maximum(u, eps)) + eps)
    out_ref[...] = jnp.argmax(logits + gumbel, axis=1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block_b",))
def topic_sample(njk, nkw, nk, unif, params, *, block_b=DEFAULT_BLOCK_B):
    """Sample topics for a batch of tokens.

    njk:  [B, K] f32 — doc-topic counts for each token's document
    nkw:  [B, K] f32 — topic-word counts for each token's word
    nk:   [1, K] f32 — topic totals
    unif: [B, K] f32 — uniforms in (0, 1) from the coordinator PRNG
    params: [1, 4] f32 — (alpha, beta, K*alpha, W*beta), see ref.py
    returns [B] i32 sampled topics.
    """
    b, k = njk.shape
    bt = min(block_b, b)
    if b % bt != 0:
        raise ValueError(f"batch {b} not divisible by block {bt}")
    grid = (b // bt,)

    tile = pl.BlockSpec((bt, k), lambda i: (i, 0))
    whole_row = pl.BlockSpec((1, k), lambda i: (0, 0))
    params_spec = pl.BlockSpec((1, 4), lambda i: (0, 0))

    return pl.pallas_call(
        _topic_sample_kernel,
        grid=grid,
        in_specs=[tile, tile, whole_row, tile, params_spec],
        out_specs=pl.BlockSpec((bt,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), jnp.int32),
        interpret=True,
    )(njk, nkw, nk, unif, params)
