"""L1 Pallas kernel: blocked per-token log-likelihood (training perplexity).

Implements the inner term of the paper's Eq. 3-4:

    log p(x) = Σ_{ji} log Σ_k θ_{k|j} φ_{x_ji|k}
    θ_{k|j} = (n_jk + α) / (n_j + Kα)
    φ_{w|k} = (n_kw + β) / (n_k + Wβ)

The coordinator gathers the [B, K] count rows/cols for a batch of tokens;
the kernel forms θ·φ and reduces over K, one [Bt, K] VMEM tile per grid
step. The final Σ over tokens and the exp(−·/N) wrapper stay in rust,
which accumulates across batches in f64.

interpret=True: see topic_sample.py.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

DEFAULT_BLOCK_B = 128


def _loglik_kernel(njk_ref, nj_ref, nkw_ref, nk_ref, params_ref, out_ref):
    """One [Bt, K] tile: per-token log Σ_k θ φ."""
    alpha = params_ref[0, ref.P_ALPHA]
    beta = params_ref[0, ref.P_BETA]
    kalpha = params_ref[0, ref.P_KALPHA]
    wbeta = params_ref[0, ref.P_WBETA]

    theta = (njk_ref[...] + alpha) / (nj_ref[...] + kalpha)   # [Bt,K]/[Bt,1]
    phi = (nkw_ref[...] + beta) / (nk_ref[...] + wbeta)       # [Bt,K]/[1,K]
    out_ref[...] = jnp.log(jnp.sum(theta * phi, axis=1))


@functools.partial(jax.jit, static_argnames=("block_b",))
def loglik(njk, nj, nkw, nk, params, *, block_b=DEFAULT_BLOCK_B):
    """Per-token log-likelihood for a batch of tokens.

    njk: [B, K] f32; nj: [B, 1] f32 doc lengths; nkw: [B, K] f32;
    nk: [1, K] f32; params: [1, 4] f32 (alpha, beta, K*alpha, W*beta).
    returns [B] f32 log Σ_k θ_{k|j} φ_{w|k}.
    """
    b, k = njk.shape
    bt = min(block_b, b)
    if b % bt != 0:
        raise ValueError(f"batch {b} not divisible by block {bt}")
    grid = (b // bt,)

    tile = pl.BlockSpec((bt, k), lambda i: (i, 0))
    col = pl.BlockSpec((bt, 1), lambda i: (i, 0))
    whole_row = pl.BlockSpec((1, k), lambda i: (0, 0))
    params_spec = pl.BlockSpec((1, 4), lambda i: (0, 0))

    return pl.pallas_call(
        _loglik_kernel,
        grid=grid,
        in_specs=[tile, col, tile, whole_row, params_spec],
        out_specs=pl.BlockSpec((bt,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), jnp.float32),
        interpret=True,
    )(njk, nj, nkw, nk, params)
