"""L1: Pallas kernels for the collapsed-Gibbs hot spot, plus jnp oracles."""

from . import perplexity, ref, topic_sample  # noqa: F401
