"""AOT lowering: jax (L2 + L1) → HLO *text* artifacts for the rust runtime.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax ≥ 0.5
emits protos with 64-bit instruction ids which the xla crate's bundled
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``). The HLO text
parser reassigns ids, so text round-trips cleanly
(see /opt/xla-example/README.md).

Emitted per (batch B, topics K) variant:

    artifacts/sampler_{B}x{K}.hlo.txt
    artifacts/loglik_{B}x{K}.hlo.txt

plus ``artifacts/manifest.tsv`` — one line per artifact with its entry
name, shapes and dtypes, which the rust runtime parses to pick the right
executable for a model configuration.

Run via ``make artifacts`` (no-op if artifacts are newer than sources).
"""

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from . import model

# (B, K) variants built by default. K=64 is the test/bench size, K=256 is
# the paper's configuration (Number of topics = 256, §V-C).
DEFAULT_VARIANTS = ((2048, 64), (2048, 256))


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_variant(batch, num_topics):
    """Lower both entry points for one (B, K) variant → {name: hlo_text}."""
    sampler = jax.jit(model.sampler_fn).lower(
        *model.sampler_example_args(batch, num_topics)
    )
    loglik = jax.jit(model.loglik_fn).lower(
        *model.loglik_example_args(batch, num_topics)
    )
    return {
        f"sampler_{batch}x{num_topics}": to_hlo_text(sampler),
        f"loglik_{batch}x{num_topics}": to_hlo_text(loglik),
    }


def manifest_rows(variants):
    """Rows for manifest.tsv: kind, batch, topics, file."""
    rows = []
    for batch, k in variants:
        rows.append(("sampler", batch, k, f"sampler_{batch}x{k}.hlo.txt"))
        rows.append(("loglik", batch, k, f"loglik_{batch}x{k}.hlo.txt"))
    return rows


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts",
                        help="directory to write *.hlo.txt into")
    parser.add_argument("--variants", default=None,
                        help="comma-separated BxK list, e.g. 2048x64,2048x256")
    args = parser.parse_args()

    if args.variants:
        variants = tuple(
            tuple(int(x) for x in v.split("x")) for v in args.variants.split(",")
        )
    else:
        variants = DEFAULT_VARIANTS

    os.makedirs(args.out_dir, exist_ok=True)
    for batch, k in variants:
        for name, text in lower_variant(batch, k).items():
            path = os.path.join(args.out_dir, f"{name}.hlo.txt")
            with open(path, "w") as f:
                f.write(text)
            print(f"wrote {path} ({len(text)} chars)")

    manifest = os.path.join(args.out_dir, "manifest.tsv")
    with open(manifest, "w") as f:
        f.write("kind\tbatch\ttopics\tfile\n")
        for kind, batch, k, fname in manifest_rows(variants):
            f.write(f"{kind}\t{batch}\t{k}\t{fname}\n")
    print(f"wrote {manifest}")


if __name__ == "__main__":
    main()
