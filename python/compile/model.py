"""L2: the jax compute graphs that get AOT-lowered for the rust runtime.

The paper's contribution is the L3 coordinator (partitioning + diagonal
scheduling); L2 is therefore deliberately thin — it wires the L1 Pallas
kernels into the two graphs the coordinator invokes per conflict-free
partition batch:

* ``sampler_fn``  — Gumbel-max collapsed-Gibbs draw for B tokens.
* ``loglik_fn``   — per-token log-likelihood plus its in-graph batch sum,
  so the rust side ships one scalar back per batch instead of [B] floats
  when it only needs the perplexity accumulator.

The coordinator performs the sparse gathers (doc rows of Cθ, word columns
of Cφ) natively — they are memcpy-shaped and partition sizes vary, so
doing them in rust keeps one artifact per (B, K) instead of one per
(B, Dblk, Wblk, K). All shapes here are static; the rust side pads the
final short batch.

Functions return tuples because the AOT path lowers with
``return_tuple=True`` (see aot.py and /opt/xla-example/gen_hlo.py).
"""

import jax
import jax.numpy as jnp

from .kernels import perplexity, topic_sample


def sampler_fn(njk, nkw, nk, unif, params):
    """AOT entry: sample topics for one padded token batch.

    njk, nkw, unif: [B, K] f32; nk: [1, K] f32; params: [1, 4] f32.
    Returns ([B] i32,).
    """
    return (topic_sample.topic_sample(njk, nkw, nk, unif, params),)


def loglik_fn(njk, nj, nkw, nk, params):
    """AOT entry: per-token log-likelihood and its batch sum.

    njk, nkw: [B, K] f32; nj: [B, 1] f32; nk: [1, K] f32; params: [1, 4].
    Returns (scalar f32 sum, [B] f32 per-token).

    Padding rows are handled on the rust side by subtracting the padded
    tokens' contributions (it knows which rows are padding); the graph
    stays branch-free.
    """
    ll = perplexity.loglik(njk, nj, nkw, nk, params)
    return (jnp.sum(ll, dtype=jnp.float32), ll)


def sampler_example_args(batch, num_topics):
    """ShapeDtypeStructs matching sampler_fn's signature."""
    f32 = jnp.float32
    return (
        jax.ShapeDtypeStruct((batch, num_topics), f32),   # njk
        jax.ShapeDtypeStruct((batch, num_topics), f32),   # nkw
        jax.ShapeDtypeStruct((1, num_topics), f32),       # nk
        jax.ShapeDtypeStruct((batch, num_topics), f32),   # unif
        jax.ShapeDtypeStruct((1, 4), f32),                # params
    )


def loglik_example_args(batch, num_topics):
    """ShapeDtypeStructs matching loglik_fn's signature."""
    f32 = jnp.float32
    return (
        jax.ShapeDtypeStruct((batch, num_topics), f32),   # njk
        jax.ShapeDtypeStruct((batch, 1), f32),            # nj
        jax.ShapeDtypeStruct((batch, num_topics), f32),   # nkw
        jax.ShapeDtypeStruct((1, num_topics), f32),       # nk
        jax.ShapeDtypeStruct((1, 4), f32),                # params
    )
