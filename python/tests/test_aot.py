"""AOT path checks: lowering emits parseable HLO text with stable entry
signatures, and the manifest describes exactly what was emitted."""

import re

from compile import aot, model


def test_lower_variant_emits_both_entries():
    arts = aot.lower_variant(256, 16)
    assert set(arts) == {"sampler_256x16", "loglik_256x16"}
    for text in arts.values():
        assert text.startswith("HloModule"), text[:80]
        assert "ENTRY" in text


def test_sampler_hlo_signature_shapes():
    text = aot.lower_variant(256, 16)["sampler_256x16"]
    # Signature lives in entry_computation_layout on the HloModule line.
    header = text.splitlines()[0]
    assert header.count("f32[256,16]") == 3            # njk, nkw, unif
    assert "f32[1,16]" in header                        # nk
    assert "f32[1,4]" in header                         # params
    # return_tuple=True ⇒ tuple-of-one s32[256] result.
    assert re.search(r"->\s*\(s32\[256\]", header), header


def test_loglik_hlo_signature_shapes():
    text = aot.lower_variant(128, 8)["loglik_128x8"]
    header = text.splitlines()[0]
    assert header.count("f32[128,8]") == 2              # njk, nkw
    assert "f32[128,1]" in header                       # nj
    assert "f32[1,8]" in header                         # nk
    # tuple (scalar sum, per-token ll)
    assert re.search(r"->\s*\(f32\[\],\s*f32\[128\]", header), header


def test_hlo_has_no_custom_calls():
    """interpret=True must lower to plain HLO the CPU PJRT client can run —
    a Mosaic custom-call here would break the rust runtime."""
    for text in aot.lower_variant(128, 8).values():
        assert "custom-call" not in text, "unexpected custom-call in HLO"


def test_manifest_rows_cover_variants():
    rows = aot.manifest_rows(((2048, 64), (2048, 256)))
    kinds = [(r[0], r[1], r[2]) for r in rows]
    assert ("sampler", 2048, 64) in kinds
    assert ("loglik", 2048, 256) in kinds
    assert len(rows) == 4
    for _, _, _, fname in rows:
        assert fname.endswith(".hlo.txt")


def test_example_args_match_fn_arity():
    assert len(model.sampler_example_args(8, 4)) == 5
    assert len(model.loglik_example_args(8, 4)) == 5
