"""Pallas kernels vs pure-jnp oracles — the core L1 correctness signal.

Hypothesis sweeps batch sizes, topic counts, hyperparameters and count
magnitudes; every case asserts the Pallas kernel (interpret=True) matches
ref.py exactly (argmax is discrete) or to float tolerance (loglik).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import perplexity, ref, topic_sample

jax.config.update("jax_platform_name", "cpu")


def make_inputs(rng, b, k, max_count=50.0):
    """Random but realistic count tensors for a [B, K] token batch."""
    njk = jnp.asarray(rng.integers(0, max_count, (b, k)), jnp.float32)
    nkw = jnp.asarray(rng.integers(0, max_count, (b, k)), jnp.float32)
    nk = jnp.asarray(rng.integers(1, max_count * 10, (1, k)), jnp.float32)
    nj = jnp.sum(njk, axis=1, keepdims=True)
    unif = jnp.asarray(rng.uniform(1e-6, 1.0 - 1e-6, (b, k)), jnp.float32)
    return njk, nj, nkw, nk, unif


shape_strategy = st.tuples(
    st.sampled_from([1, 2, 8, 128, 256, 384]),     # B (block=128 ⇒ exercises
    st.sampled_from([1, 2, 16, 64, 256]),          #   sub-block & multi-block)
    st.integers(0, 2**31 - 1),                     # numpy seed
)


@settings(max_examples=25, deadline=None)
@given(shape_strategy,
       st.sampled_from([0.05, 0.5, 2.0]),
       st.sampled_from([0.01, 0.1, 1.0]))
def test_topic_sample_matches_ref(shape, alpha, beta):
    b, k, seed = shape
    rng = np.random.default_rng(seed)
    njk, _, nkw, nk, unif = make_inputs(rng, b, k)
    params = ref.pack_params(alpha, beta, k, num_words=1000)

    got = topic_sample.topic_sample(njk, nkw, nk, unif, params)
    want = ref.topic_sample_ref(njk, nkw, nk, unif, params)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert got.dtype == jnp.int32
    assert np.all(np.asarray(got) >= 0) and np.all(np.asarray(got) < k)


@settings(max_examples=25, deadline=None)
@given(shape_strategy,
       st.sampled_from([0.05, 0.5, 2.0]),
       st.sampled_from([0.01, 0.1, 1.0]))
def test_loglik_matches_ref(shape, alpha, beta):
    b, k, seed = shape
    rng = np.random.default_rng(seed)
    njk, nj, nkw, nk, _ = make_inputs(rng, b, k)
    params = ref.pack_params(alpha, beta, k, num_words=1000)

    got = perplexity.loglik(njk, nj, nkw, nk, params)
    want = ref.loglik_ref(njk, nj, nkw, nk, params)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)
    assert np.all(np.asarray(got) <= 0.0 + 1e-6)  # log of a probability


def test_topic_sample_prefers_dominant_topic():
    """With one topic overwhelmingly weighted, argmax must pick it."""
    b, k = 128, 16
    njk = jnp.zeros((b, k), jnp.float32).at[:, 3].set(1e6)
    nkw = jnp.zeros((b, k), jnp.float32).at[:, 3].set(1e6)
    nk = jnp.ones((1, k), jnp.float32)
    unif = jnp.full((b, k), 0.5, jnp.float32)
    params = ref.pack_params(0.5, 0.1, k, 100)
    got = topic_sample.topic_sample(njk, nkw, nk, unif, params)
    assert np.all(np.asarray(got) == 3)


def test_topic_sample_empirical_distribution():
    """Gumbel-max over uniform logits ⇒ empirically uniform topic draws."""
    b, k = 2048, 8
    rng = np.random.default_rng(0)
    njk = jnp.ones((b, k), jnp.float32)
    nkw = jnp.ones((b, k), jnp.float32)
    nk = jnp.full((1, k), 8.0, jnp.float32)
    unif = jnp.asarray(rng.uniform(1e-6, 1 - 1e-6, (b, k)), jnp.float32)
    params = ref.pack_params(0.5, 0.1, k, 100)
    got = np.asarray(topic_sample.topic_sample(njk, nkw, nk, unif, params))
    counts = np.bincount(got, minlength=k)
    # Each topic should get ~B/k = 256; allow generous ±40% band.
    assert counts.min() > 0.6 * b / k and counts.max() < 1.4 * b / k


def test_loglik_sum_matches_tokens():
    from compile import model

    b, k = 256, 32
    rng = np.random.default_rng(7)
    njk, nj, nkw, nk, _ = make_inputs(rng, b, k)
    params = ref.pack_params(0.5, 0.1, k, 500)
    total, per_token = model.loglik_fn(njk, nj, nkw, nk, params)
    np.testing.assert_allclose(float(total), float(np.sum(np.asarray(per_token))),
                               rtol=1e-5)


def test_block_not_dividing_batch_raises():
    with pytest.raises(ValueError):
        topic_sample.topic_sample(
            jnp.ones((130, 4)), jnp.ones((130, 4)), jnp.ones((1, 4)),
            jnp.full((130, 4), 0.5), ref.pack_params(0.5, 0.1, 4, 10),
            block_b=128,
        )
