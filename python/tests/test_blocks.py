"""L1 structure checks: BlockSpec tiling must not change results, and the
VMEM-footprint accounting used in DESIGN.md §Perf must hold.

interpret=True gives CPU-numpy timings only, so kernel *structure*
(tiling invariance, footprint) is what we test — real-TPU perf is
estimated analytically in DESIGN.md.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import perplexity, ref, topic_sample

BLOCKS = [8, 32, 128, 512]


def make_case(b, k, seed=0):
    rng = np.random.default_rng(seed)
    njk = jnp.asarray(rng.integers(0, 40, (b, k)), jnp.float32)
    nkw = jnp.asarray(rng.integers(0, 40, (b, k)), jnp.float32)
    nk = jnp.asarray(rng.integers(1, 400, (1, k)), jnp.float32)
    nj = jnp.sum(njk, axis=1, keepdims=True)
    unif = jnp.asarray(rng.uniform(1e-6, 1 - 1e-6, (b, k)), jnp.float32)
    params = ref.pack_params(0.5, 0.1, k, 1000)
    return njk, nj, nkw, nk, unif, params


@pytest.mark.parametrize("block_b", BLOCKS)
def test_sampler_invariant_to_block_size(block_b):
    b, k = 512, 16
    njk, _, nkw, nk, unif, params = make_case(b, k)
    want = ref.topic_sample_ref(njk, nkw, nk, unif, params)
    got = topic_sample.topic_sample(njk, nkw, nk, unif, params, block_b=block_b)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("block_b", BLOCKS)
def test_loglik_invariant_to_block_size(block_b):
    b, k = 512, 16
    njk, nj, nkw, nk, _, params = make_case(b, k, seed=1)
    want = ref.loglik_ref(njk, nj, nkw, nk, params)
    got = perplexity.loglik(njk, nj, nkw, nk, params, block_b=block_b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)


def vmem_bytes_sampler(bt, k):
    """f32 VMEM bytes for one grid step of the sampler kernel:
    njk/nkw/unif tiles [Bt,K], nk row [1,K], params [1,4], out [Bt]."""
    return 4 * (3 * bt * k + k + 4 + bt)


def test_default_block_fits_tpu_vmem():
    # One grid step at the paper's K=256 with the default tile must stay
    # far below a TPU core's ~16 MiB VMEM, even double-buffered.
    bt = topic_sample.DEFAULT_BLOCK_B
    footprint = vmem_bytes_sampler(bt, 256)
    assert 2 * footprint < 16 * 1024 * 1024 / 4, (
        f"double-buffered footprint {2 * footprint}B should be <1/4 of VMEM"
    )


def test_footprint_scales_linearly_in_block():
    assert vmem_bytes_sampler(256, 64) > 1.9 * vmem_bytes_sampler(128, 64)
