//! End-to-end driver — exercises every layer of the system on a real
//! workload, proving they compose:
//!
//! 1. **Corpus substrate**: generate the full-size NIPS-shaped corpus
//!    (D=1500, W=12419, N≈1.9M; Table I).
//! 2. **L3 contribution**: partition with all four algorithms, pick A3
//!    (paper's best), report η and the η·P speedup model.
//! 3. **Parallel engine**: train LDA with the diagonal-epoch engine
//!    (P workers, conflict-free partitions, epoch barriers).
//! 4. **L1/L2 via PJRT**: evaluate training perplexity through the
//!    AOT-compiled JAX/Pallas log-likelihood kernel, and cross-check it
//!    against the native computation at the end.
//!
//! Headline metrics (recorded in EXPERIMENTS.md): final perplexity,
//! η per algorithm, model speedup, sampling throughput.
//!
//! ```text
//! cargo run --release --example end_to_end
//!     [-- --iters 200 --procs 8 --topics 64 --eval-every 20
//!         --out e2e_results.tsv]
//! ```

use std::time::Instant;

use pplda::corpus::synthetic::{generate, Profile};
use pplda::gibbs::perplexity as native_perplexity;
use pplda::partition::{partition, Algorithm};
use pplda::runtime::executor::Artifacts;
use pplda::runtime::sampler_xla::XlaPerplexity;
use pplda::scheduler::cost_model::SpeedupReport;
use pplda::scheduler::exec::{ExecMode, ParallelLda};
use pplda::util::cli::Args;
use pplda::util::tsv::{f, Table};

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let iters = args.get::<usize>("iters", 200);
    let p = args.get::<usize>("procs", 8);
    let topics = args.get::<usize>("topics", 64);
    let eval_every = args.get::<usize>("eval-every", 20);
    let seed = args.get::<u64>("seed", 42);
    let out = args.get_str("out").unwrap_or("e2e_results.tsv").to_string();

    // ---- 1. corpus ----
    let profile = Profile::nips_like();
    let t0 = Instant::now();
    let bow = generate(&profile, seed);
    println!(
        "[1/4] corpus {}: D={} W={} N={} ({:.1}s)",
        profile.name,
        bow.num_docs(),
        bow.num_words(),
        bow.num_tokens(),
        t0.elapsed().as_secs_f64()
    );

    // ---- 2. partitioning (the paper's contribution) ----
    let algos = [
        Algorithm::Baseline { restarts: 100 },
        Algorithm::A1,
        Algorithm::A2,
        Algorithm::A3 { restarts: 100 },
    ];
    let mut eta_table = Table::new(["algorithm", "eta", "speedup_model", "secs"]);
    let mut chosen = None;
    for algo in algos {
        let t = Instant::now();
        let plan = partition(&bow, p, algo, seed);
        let r = SpeedupReport::of_plan(&plan);
        eta_table.row([
            plan.algorithm.to_string(),
            f(r.eta, 4),
            f(r.speedup, 2),
            format!("{:.3}", t.elapsed().as_secs_f64()),
        ]);
        if plan.algorithm == "A3" {
            chosen = Some(plan);
        }
    }
    println!("[2/4] partitioning at P={p}:\n{}", eta_table.to_aligned());
    let plan = chosen.expect("A3 plan");

    // ---- 3. parallel training with XLA perplexity evals ----
    let arts = Artifacts::discover(Artifacts::default_dir())
        .expect("run `make artifacts` first — the e2e driver exercises the XLA path");
    let batch = arts
        .variants("loglik")
        .into_iter()
        .find(|&(_, k)| k == topics)
        .unwrap_or_else(|| panic!("no loglik artifact for K={topics}"))
        .0;
    let mut xla_perp = XlaPerplexity::new(arts.loglik(batch, topics).unwrap());

    let mut lda = ParallelLda::init(&bow, &plan, topics, 0.5, 0.1, seed);
    let mut curve = Table::new(["iter", "perplexity_xla", "sweep_secs", "tokens_per_sec"]);
    let train_started = Instant::now();
    let mut sampled: u64 = 0;
    for it in 1..=iters {
        let sweep_t = Instant::now();
        let stats = lda.sweep(ExecMode::Sequential);
        sampled += stats.total_tokens;
        let dt = sweep_t.elapsed().as_secs_f64();
        if it % eval_every == 0 || it == iters || it == 1 {
            let perp = xla_perp
                .perplexity(&bow, &lda.counts, &lda.h)
                .expect("XLA perplexity");
            curve.row([
                it.to_string(),
                f(perp, 4),
                format!("{dt:.3}"),
                pplda::util::human_rate(stats.total_tokens as f64 / dt),
            ]);
            println!(
                "  iter {it:4}  perplexity {perp:10.4}  ({:.3}s/sweep)",
                dt
            );
        }
    }
    let train_secs = train_started.elapsed().as_secs_f64();
    println!(
        "[3/4] trained {iters} sweeps in {train_secs:.1}s — {} tokens/s sustained",
        pplda::util::human_rate(sampled as f64 / train_secs)
    );

    // ---- 4. XLA vs native cross-check ----
    let xla = xla_perp
        .perplexity(&bow, &lda.counts, &lda.h)
        .expect("XLA perplexity");
    let native = native_perplexity::perplexity(&bow, &lda.counts, &lda.h);
    let rel = (xla - native).abs() / native;
    println!(
        "[4/4] perplexity cross-check: xla {xla:.4} vs native {native:.4} (rel err {rel:.2e})"
    );
    assert!(rel < 1e-3, "XLA and native perplexity diverged");

    curve.write_tsv(&out).expect("write results");
    println!(
        "headline: eta={:.4} speedup_model={:.2} final_perplexity={:.4} -> {out}",
        plan.eta,
        plan.eta * p as f64,
        xla
    );
}
