//! Quickstart: generate a small corpus, compare all four partitioning
//! algorithms, then train parallel LDA under the best plan.
//!
//! ```text
//! cargo run --release --example quickstart [-- --scale 20 --procs 8]
//! ```

use pplda::coordinator::{train_lda, TrainConfig};
use pplda::corpus::synthetic::{generate, Profile};
use pplda::partition::{partition, Algorithm};
use pplda::util::cli::Args;
use pplda::util::tsv::{f, Table};

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let scale = args.get::<usize>("scale", 20);
    let p = args.get::<usize>("procs", 8);
    let seed = args.get::<u64>("seed", 42);

    // 1. A NIPS-shaped corpus, scaled down for a quick run.
    let profile = Profile::nips_like().scaled(scale);
    let bow = generate(&profile, seed);
    println!(
        "corpus {}: {} docs, {} words, {} tokens\n",
        profile.name,
        bow.num_docs(),
        bow.num_words(),
        bow.num_tokens()
    );

    // 2. Partition with all four algorithms; compare load balance.
    let algos = [
        Algorithm::Baseline { restarts: 20 },
        Algorithm::A1,
        Algorithm::A2,
        Algorithm::A3 { restarts: 20 },
    ];
    let mut table = Table::new(["algorithm", "eta", "speedup=eta*P"]);
    let mut best = None;
    for algo in algos {
        let plan = partition(&bow, p, algo, seed);
        table.row([
            plan.algorithm.to_string(),
            f(plan.eta, 4),
            f(plan.eta * p as f64, 2),
        ]);
        if best
            .as_ref()
            .map(|b: &pplda::partition::Plan| plan.eta > b.eta)
            .unwrap_or(true)
        {
            best = Some(plan);
        }
    }
    println!("partitioning at P={p}:\n{}", table.to_aligned());
    let plan = best.unwrap();

    // 3. Train parallel LDA under the best plan.
    let cfg = TrainConfig {
        topics: 32,
        iters: 50,
        eval_every: 10,
        seed,
        ..Default::default()
    };
    println!(
        "training LDA: K={} iters={} under {} (eta={:.4})\n",
        cfg.topics, cfg.iters, plan.algorithm, plan.eta
    );
    let report = train_lda(&bow, &plan, &cfg);
    println!("{}", report.curve_table().to_aligned());
    println!(
        "final perplexity {:.2}, {:.2}s, {} tokens/s, model speedup ≈ {:.2}×",
        report.final_perplexity,
        report.train_secs,
        pplda::util::human_rate(report.tokens_per_sec),
        report.speedup_model
    );
}
