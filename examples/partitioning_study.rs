//! Full partitioning study — regenerates the paper's Tables II and III
//! (load-balancing ratio η per algorithm per P) on synthetic NIPS-like
//! and NYTimes-like corpora, or on the real UCI files via `--uci`.
//!
//! ```text
//! cargo run --release --example partitioning_study
//!     [-- --procs 1,10,30,60 --restarts 100 --nytimes-scale 10
//!         --uci-nips docword.nips.txt --uci-nytimes docword.nytimes.txt]
//! ```

use pplda::corpus::synthetic::{generate, Profile};
use pplda::corpus::{uci, BagOfWords};
use pplda::partition::{partition, Algorithm};
use pplda::util::cli::Args;
use pplda::util::timer::time_once;
use pplda::util::tsv::{f, Table};

fn study(name: &str, bow: &BagOfWords, procs: &[usize], restarts: usize, seed: u64) {
    println!(
        "=== {name}: D={} W={} N={} ===",
        bow.num_docs(),
        bow.num_words(),
        bow.num_tokens()
    );
    let mut table = Table::new(["P", "baseline", "A1", "A2", "A3"]);
    let mut runtime = Table::new(["P", "baseline_s", "A1_s", "A2_s", "A3_s"]);
    for &p in procs {
        let algos = [
            Algorithm::Baseline { restarts },
            Algorithm::A1,
            Algorithm::A2,
            Algorithm::A3 { restarts },
        ];
        let mut etas = vec![p.to_string()];
        let mut secs = vec![p.to_string()];
        for algo in algos {
            let (plan, dt) = time_once(|| partition(bow, p, algo, seed));
            etas.push(f(plan.eta, 4));
            secs.push(format!("{:.3}", dt.as_secs_f64()));
        }
        table.row(etas);
        runtime.row(secs);
    }
    println!("load-balancing ratio eta:\n{}", table.to_aligned());
    println!(
        "partitioner wall time (restarts={restarts} for randomized):\n{}",
        runtime.to_aligned()
    );
}

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let procs = args.get_list::<usize>("procs", &[1, 10, 30, 60]);
    let restarts = args.get::<usize>("restarts", 100);
    let seed = args.get::<u64>("seed", 42);

    // Table II — NIPS.
    let nips = match args.get_str("uci-nips") {
        Some(path) => uci::load_bow(path).expect("load NIPS"),
        None => generate(&Profile::nips_like(), seed),
    };
    study("Table II (NIPS)", &nips, &procs, restarts, seed);

    // Table III — NYTimes (scaled synthetic by default; full via --nytimes-scale 1).
    let nyt_scale = args.get::<usize>("nytimes-scale", 10);
    let nyt = match args.get_str("uci-nytimes") {
        Some(path) => uci::load_bow(path).expect("load NYTimes"),
        None => generate(&Profile::nytimes_like().scaled(nyt_scale), seed),
    };
    study("Table III (NYTimes)", &nyt, &procs, restarts, seed);

    println!("paper reference (Table II, NIPS):");
    println!("  baseline 1.0 / 0.9500 / 0.7800 / 0.5700");
    println!("  A1       1.0 / 0.9613 / 0.8657 / 0.7126");
    println!("  A2       1.0 / 0.9633 / 0.8568 / 0.7097");
    println!("  A3       1.0 / 0.9800 / 0.8929 / 0.7553");
    println!("paper reference (Table III, NYTimes):");
    println!("  baseline 1.0 / 0.9700 / 0.9300 / 0.8500");
    println!("  A1       1.0 / 0.9559 / 0.9270 / 0.9011");
    println!("  A2       1.0 / 0.9626 / 0.9439 / 0.9175");
    println!("  A3       1.0 / 0.9981 / 0.9901 / 0.9757");
}
