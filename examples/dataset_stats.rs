//! Table I reproduction: statistics of the three (synthetic) corpora the
//! experiments run on, side by side, plus the skew measures that make the
//! load-balancing problem hard.
//!
//! ```text
//! cargo run --release --example dataset_stats [-- --full]
//! ```
//!
//! By default NYTimes and MAS are generated at reduced scale (÷10 / ÷20);
//! `--full` generates them at the paper's full size (slow, ~200M tokens).

use pplda::corpus::stats::{table_i, CorpusStats};
use pplda::corpus::synthetic::{generate, generate_timestamped, Profile};
use pplda::util::cli::Args;

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let seed = args.get::<u64>("seed", 42);
    let full = args.has("full");
    let (nyt_scale, mas_scale) = if full { (1, 1) } else { (10, 20) };

    let nips = generate(&Profile::nips_like(), seed);
    let nyt = generate(&Profile::nytimes_like().scaled(nyt_scale), seed);
    let mas_profile = Profile::mas_like().scaled(mas_scale);
    let mas = generate_timestamped(&mas_profile, seed);

    let stats = [
        CorpusStats::of("NIPS", &nips),
        CorpusStats::of(&format!("NYTimes/{nyt_scale}"), &nyt),
        CorpusStats::of_timestamped(&format!("MAS/{mas_scale}"), &mas),
    ];
    println!("{}", table_i(&stats).to_aligned());

    println!("paper Table I reference:");
    println!("  Documents D:      1500 / 300,000 / 1,182,744");
    println!("  Unique words W:   12,419 / 102,660 / 402,252 (stemmed)");
    println!("  Word instances N: 1,932,365 / 99,542,125 / 92,531,014");
    println!("  Timestamps WTS:   N/A / N/A / 60 (1951-2010)");
}
