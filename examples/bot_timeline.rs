//! Bag-of-Timestamps analysis of an MAS-like scientific-publication
//! corpus (the paper's contribution 3): train parallel BoT, then report
//! each topic's presence over the 1951–2010 timeline — rising topics,
//! falling topics, peak years.
//!
//! ```text
//! cargo run --release --example bot_timeline
//!     [-- --scale 100 --procs 10 --topics 32 --iters 30]
//! ```

use pplda::coordinator::{train_bot, TrainConfig};
use pplda::corpus::synthetic::{generate_timestamped, Profile};
use pplda::partition::Algorithm;
use pplda::util::cli::Args;

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let scale = args.get::<usize>("scale", 100);
    let p = args.get::<usize>("procs", 10);
    let seed = args.get::<u64>("seed", 42);

    let profile = Profile::mas_like().scaled(scale);
    let tc = generate_timestamped(&profile, seed);
    println!(
        "corpus {}: {} docs, {} words, {} word tokens, {} timestamps, {} ts tokens",
        profile.name,
        tc.bow.num_docs(),
        tc.bow.num_words(),
        tc.bow.num_tokens(),
        tc.num_stamps,
        tc.dts.num_tokens()
    );

    let cfg = TrainConfig {
        topics: args.get::<usize>("topics", 32),
        iters: args.get::<usize>("iters", 30),
        seed,
        ..Default::default()
    };
    println!(
        "training parallel BoT: P={p} K={} iters={} (A3 partitioning on DW and DTS)",
        cfg.topics, cfg.iters
    );
    let report = train_bot(&tc, p, Algorithm::A3 { restarts: 20 }, &cfg);
    println!(
        "perplexity {:.2} | eta_dw {:.4} | eta_dts {:.4} | speedup ≈ {:.2}× | {:.1}s\n",
        report.final_perplexity,
        report.eta_dw,
        report.eta_dts,
        report.speedup_model,
        report.train_secs
    );

    let first_year = profile.time.as_ref().unwrap().first_year;
    println!(
        "topic trends over {}–{}:\n{}",
        first_year,
        profile.time.as_ref().unwrap().last_year,
        pplda::bot::timeline::trend_table(&report.timelines, first_year, 5).to_aligned()
    );

    // Sparkline-ish presence curves for the strongest rising topics.
    let mut by_slope: Vec<_> = report.timelines.iter().collect();
    by_slope.sort_by(|a, b| b.slope.partial_cmp(&a.slope).unwrap());
    for tl in by_slope.iter().take(3) {
        let bars: String = tl
            .pi
            .iter()
            .map(|&v| {
                let lvl = (v * tl.pi.len() as f64 * 2.0).min(7.0) as usize;
                ['.', ':', '-', '=', '+', '*', '#', '@'][lvl]
            })
            .collect();
        println!("topic {:3} [{}] peak {}", tl.topic, bars, first_year + tl.peak as u32);
    }
}
