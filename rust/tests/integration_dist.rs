//! Distributed training end-to-end over real localhost TCP: in-process
//! `serve_on` workers driven by a [`DistExec`] coordinator, asserted
//! bit-identical to the Sequential oracle — with and without injected
//! faults (worker crash, torn send, corrupt receive, frozen worker,
//! total worker loss).
//!
//! Every test takes the file-local `SERIAL` lock: the fault plan is
//! process-global, so a wildcard fault armed by one test must never be
//! consumed by another test's worker threads.

use std::net::{SocketAddr, TcpListener};
use std::sync::{Mutex, MutexGuard};
use std::thread::{self, JoinHandle};

use pplda::bot::{BotHyper, ParallelBot};
use pplda::corpus::synthetic::{generate, generate_timestamped, Profile, TimeProfile};
use pplda::corpus::BagOfWords;
use pplda::dist::{DistExec, DistOptions, WorkerOptions};
use pplda::kernel::KernelKind;
use pplda::partition::{partition, Algorithm, Plan};
use pplda::scheduler::exec::{CommitMode, ExecMode, ParallelLda};

static SERIAL: Mutex<()> = Mutex::new(());

fn lock() -> MutexGuard<'static, ()> {
    // A fault test that panicked by design poisons the lock; the state
    // it guards (the global fault plan) is cleared by its guard drop.
    SERIAL.lock().unwrap_or_else(|e| e.into_inner())
}

fn small_bow() -> BagOfWords {
    let mut p = Profile::nips_like().scaled(15);
    p.len_sigma = 0.4;
    generate(&p, 2101)
}

fn small_plan(bow: &BagOfWords) -> Plan {
    partition(bow, 3, Algorithm::A3 { restarts: 3 }, 7)
}

/// Bind `n` ephemeral listeners and serve one coordinator session on
/// each from its own thread. `once` workers exit when the session ends
/// (shutdown, crash, or socket teardown), so joining is safe.
fn spawn_workers(n: usize) -> (Vec<SocketAddr>, Vec<JoinHandle<()>>) {
    let mut addrs = Vec::new();
    let mut handles = Vec::new();
    for _ in 0..n {
        let listener = TcpListener::bind("127.0.0.1:0").expect("bind worker");
        addrs.push(listener.local_addr().expect("local addr"));
        handles.push(thread::spawn(move || {
            let opts = WorkerOptions {
                once: true,
                ..WorkerOptions::default()
            };
            let _ = pplda::dist::serve_on(listener, &opts);
        }));
    }
    (addrs, handles)
}

/// Join worker threads, tolerating the ones an injected fault panicked.
fn reap(handles: Vec<JoinHandle<()>>) {
    for h in handles {
        let _ = h.join();
    }
}

fn fast_opts() -> DistOptions {
    DistOptions {
        heartbeat_ms: 25,
        liveness_timeout_ms: 2000,
        spec_factor: f64::INFINITY,
        connect_attempts: 20,
        max_reconnects: 3,
    }
}

fn oracle_lda(
    bow: &BagOfWords,
    plan: &Plan,
    kernel: KernelKind,
    commit: CommitMode,
    sweeps: usize,
) -> ParallelLda {
    let mut lda = ParallelLda::init(bow, plan, 8, 0.5, 0.1, 11);
    lda.set_kernel(kernel);
    lda.set_commit(commit);
    for _ in 0..sweeps {
        lda.sweep(ExecMode::Sequential);
    }
    lda
}

fn assert_lda_counts_match(lda: &ParallelLda, oracle: &ParallelLda, tag: &str) {
    assert_eq!(lda.counts.doc_topic, oracle.counts.doc_topic, "{tag}: n_dk");
    assert_eq!(lda.counts.word_topic, oracle.counts.word_topic, "{tag}: n_wk");
    assert_eq!(lda.counts.topic, oracle.counts.topic, "{tag}: n_k");
}

#[test]
fn dist_lda_bit_identical_across_kernels_and_commit_modes() {
    let _g = lock();
    let bow = small_bow();
    let plan = small_plan(&bow);
    for kernel in KernelKind::all() {
        for commit in [CommitMode::Barrier, CommitMode::Ticketed] {
            let tag = format!("{kernel:?}/{commit:?}");
            let oracle = oracle_lda(&bow, &plan, kernel, commit, 3);
            let (addrs, handles) = spawn_workers(2);
            let mut exec = DistExec::connect(&addrs, fast_opts()).expect("connect");
            let mut lda = ParallelLda::init(&bow, &plan, 8, 0.5, 0.1, 11);
            lda.set_kernel(kernel);
            lda.set_commit(commit);
            for _ in 0..3 {
                lda.sweep_with(&mut exec);
            }
            assert_eq!(exec.reassigns(), 0, "{tag}: clean run reassigns nothing");
            assert_eq!(exec.local_fallbacks(), 0, "{tag}: workers did all the work");
            assert_lda_counts_match(&lda, &oracle, &tag);
            assert_eq!(
                lda.perplexity(&bow).to_bits(),
                oracle.perplexity(&bow).to_bits(),
                "{tag}: perplexity bits"
            );
            exec.shutdown();
            reap(handles);
        }
    }
}

#[test]
fn dist_bot_bit_identical_to_sequential() {
    let _g = lock();
    let mut profile = Profile::tiny();
    profile.time = Some(TimeProfile {
        first_year: 2000,
        last_year: 2009,
        growth: 0.1,
        stamps_per_doc: 4,
    });
    let tc = generate_timestamped(&profile, 2104);
    let plan_dw = partition(&tc.bow, 3, Algorithm::A3 { restarts: 3 }, 9);
    let plan_dts = partition(&tc.dts, 3, Algorithm::A3 { restarts: 3 }, 9 ^ 0xD75);
    let h = BotHyper::new(8, 0.5, 0.1, 0.1, tc.bow.num_words(), tc.num_stamps);

    let mut oracle = ParallelBot::init(&tc, &plan_dw, &plan_dts, h, 13);
    oracle.set_commit(CommitMode::Ticketed);
    for _ in 0..3 {
        oracle.sweep(ExecMode::Sequential);
    }

    let (addrs, handles) = spawn_workers(2);
    let mut exec = DistExec::connect(&addrs, fast_opts()).expect("connect");
    let mut bot = ParallelBot::init(&tc, &plan_dw, &plan_dts, h, 13);
    bot.set_commit(CommitMode::Ticketed);
    for _ in 0..3 {
        bot.sweep_with(&mut exec);
    }
    assert_eq!(exec.reassigns(), 0, "clean BoT run reassigns nothing");
    assert_eq!(bot.counts.doc_topic, oracle.counts.doc_topic, "n_jk");
    assert_eq!(bot.counts.word_topic, oracle.counts.word_topic, "n_kw");
    assert_eq!(bot.counts.stamp_topic, oracle.counts.stamp_topic, "n_ks");
    exec.shutdown();
    reap(handles);
}

/// The chaos matrix. Reassignment counts are exact because assignment
/// is round-robin over live nodes in index order and every fault is
/// keyed to a deterministic `(node, sweep, ticket/partition)` site.
#[cfg(feature = "failpoints")]
mod chaos {
    use super::*;
    use pplda::util::fault::{self, install, Fault, FaultKind, ANY};

    /// A worker panic mid-sweep: node 0 dies executing its first task
    /// (ticket 0), so both of its round-robin tickets {0, 2} of the
    /// 3-task epoch replay on node 1 — exactly 2 reassigns, and the
    /// replayed `(sweep, partition)` RNG streams keep the run
    /// bit-identical to the undisturbed oracle.
    #[test]
    fn worker_crash_mid_sweep_replays_bit_identically() {
        let _g = lock();
        let bow = small_bow();
        let plan = small_plan(&bow);
        for commit in [CommitMode::Barrier, CommitMode::Ticketed] {
            let tag = format!("crash/{commit:?}");
            let oracle = oracle_lda(&bow, &plan, KernelKind::Dense, commit, 3);
            let (addrs, handles) = spawn_workers(2);
            let mut exec = DistExec::connect(&addrs, fast_opts()).expect("connect");
            let mut lda = ParallelLda::init(&bow, &plan, 8, 0.5, 0.1, 11);
            lda.set_commit(commit);
            let guard = install(vec![Fault {
                site: fault::sites::DIST_WORKER,
                key: [0, ANY, ANY],
                kind: FaultKind::Panic,
            }]);
            for _ in 0..3 {
                lda.sweep_with(&mut exec);
            }
            drop(guard);
            assert_eq!(exec.reassigns(), 2, "{tag}: node 0 held tickets 0 and 2");
            assert_lda_counts_match(&lda, &oracle, &tag);
            exec.shutdown();
            reap(handles);
        }
    }

    /// A torn task write to node 1 at `(sweep 0, ticket 1)`: the frame
    /// is cut mid-header, the node is buried, and only that one ticket
    /// (node 1's first — nothing else was in flight there) reassigns.
    #[test]
    fn torn_send_reassigns_exactly_one_ticket() {
        let _g = lock();
        let bow = small_bow();
        let plan = small_plan(&bow);
        let oracle = oracle_lda(&bow, &plan, KernelKind::Dense, CommitMode::Barrier, 3);
        let (addrs, handles) = spawn_workers(2);
        let mut exec = DistExec::connect(&addrs, fast_opts()).expect("connect");
        let mut lda = ParallelLda::init(&bow, &plan, 8, 0.5, 0.1, 11);
        let guard = install(vec![Fault {
            site: fault::sites::DIST_SEND,
            key: [1, 0, 1],
            kind: FaultKind::TornWrite,
        }]);
        for _ in 0..3 {
            lda.sweep_with(&mut exec);
        }
        drop(guard);
        assert_eq!(exec.reassigns(), 1, "only ticket 1 was in flight on node 1");
        assert_eq!(exec.live_nodes(), 1, "node 1 stays buried (its worker exited)");
        assert_lda_counts_match(&lda, &oracle, "torn-send");
        exec.shutdown();
        reap(handles);
    }

    /// A corrupt delta from node 0: the first reply it sends in sweep 0
    /// is discarded at receipt, the node is buried, and both of its
    /// in-flight tickets {0, 2} replay elsewhere — exactly 2 reassigns,
    /// and the discarded half-result never touches the model (the
    /// replay writes the same absolute rows the clean run would).
    #[test]
    fn corrupt_delta_discards_node_and_replays() {
        let _g = lock();
        let bow = small_bow();
        let plan = small_plan(&bow);
        let oracle = oracle_lda(&bow, &plan, KernelKind::Dense, CommitMode::Ticketed, 3);
        let (addrs, handles) = spawn_workers(2);
        let mut exec = DistExec::connect(&addrs, fast_opts()).expect("connect");
        let mut lda = ParallelLda::init(&bow, &plan, 8, 0.5, 0.1, 11);
        lda.set_commit(CommitMode::Ticketed);
        let guard = install(vec![Fault {
            site: fault::sites::DIST_RECV,
            key: [0, 0, ANY],
            kind: FaultKind::IoError,
        }]);
        for _ in 0..3 {
            lda.sweep_with(&mut exec);
        }
        drop(guard);
        assert_eq!(exec.reassigns(), 2, "node 0 held tickets 0 and 2 at discard time");
        assert_lda_counts_match(&lda, &oracle, "corrupt-recv");
        exec.shutdown();
        reap(handles);
    }

    /// Losing every worker degrades to local execution: with one node,
    /// a send fault on the very first task buries it, reconnects are
    /// exhausted (budget 0), and all 27 tasks (3 sweeps × 3 epochs × 3
    /// partitions) run on the coordinator through the same
    /// `pool::run_task` — still bit-identical.
    #[test]
    fn total_worker_loss_falls_back_to_local_execution() {
        let _g = lock();
        let bow = small_bow();
        let plan = small_plan(&bow);
        let oracle = oracle_lda(&bow, &plan, KernelKind::Sparse, CommitMode::Barrier, 3);
        let (addrs, handles) = spawn_workers(1);
        let opts = DistOptions {
            max_reconnects: 0,
            ..fast_opts()
        };
        let mut exec = DistExec::connect(&addrs, opts).expect("connect");
        let mut lda = ParallelLda::init(&bow, &plan, 8, 0.5, 0.1, 11);
        lda.set_kernel(KernelKind::Sparse);
        let guard = install(vec![Fault {
            site: fault::sites::DIST_SEND,
            key: [0, ANY, ANY],
            kind: FaultKind::IoError,
        }]);
        for _ in 0..3 {
            lda.sweep_with(&mut exec);
        }
        drop(guard);
        assert_eq!(exec.reassigns(), 1, "the failed first send");
        assert_eq!(exec.local_fallbacks(), 27, "every task ran locally");
        assert_eq!(exec.live_nodes(), 0);
        assert_lda_counts_match(&lda, &oracle, "local-fallback");
        exec.shutdown();
        reap(handles);
    }

    /// A frozen worker (stops ponging and taking tasks, socket open):
    /// the liveness timeout buries it and its stalled work replays.
    /// The freeze latches on the first heartbeat that reaches node 1,
    /// whose timing depends on event-loop gaps, so this asserts bounds,
    /// not exact counts — sweeps continue until the detector has fired.
    #[test]
    fn frozen_worker_is_detected_by_liveness_timeout() {
        let _g = lock();
        let bow = small_bow();
        let plan = small_plan(&bow);
        let (addrs, handles) = spawn_workers(2);
        let opts = DistOptions {
            heartbeat_ms: 1,
            liveness_timeout_ms: 150,
            ..fast_opts()
        };
        let mut exec = DistExec::connect(&addrs, opts).expect("connect");
        let mut lda = ParallelLda::init(&bow, &plan, 8, 0.5, 0.1, 11);
        let guard = install(vec![Fault {
            site: fault::sites::DIST_HEARTBEAT,
            key: [1, ANY, ANY],
            kind: FaultKind::IoError,
        }]);
        let mut sweeps = 0;
        while sweeps < 30 && (exec.reassigns() == 0 || sweeps < 3) {
            lda.sweep_with(&mut exec);
            sweeps += 1;
        }
        drop(guard);
        assert!(exec.pings_sent() > 0, "heartbeats were exchanged");
        assert!(
            exec.reassigns() >= 1,
            "the frozen node's stalled tickets were reassigned"
        );
        let oracle = oracle_lda(&bow, &plan, KernelKind::Dense, CommitMode::Barrier, sweeps);
        assert_lda_counts_match(&lda, &oracle, "frozen-worker");
        exec.shutdown();
        reap(handles);
    }
}
