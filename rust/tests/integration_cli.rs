//! Smoke tests of the `pplda` binary (launcher + CLI parsing + output
//! shapes), driven through `CARGO_BIN_EXE_pplda`.

use std::process::Command;

fn pplda(args: &[&str]) -> (String, String, bool) {
    let out = Command::new(env!("CARGO_BIN_EXE_pplda"))
        .args(args)
        .env("PPLDA_ARTIFACTS", concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"))
        .output()
        .expect("spawn pplda");
    (
        String::from_utf8_lossy(&out.stdout).into_owned(),
        String::from_utf8_lossy(&out.stderr).into_owned(),
        out.status.success(),
    )
}

#[test]
fn no_args_prints_usage_and_fails() {
    let (_, err, ok) = pplda(&[]);
    assert!(!ok);
    assert!(err.contains("usage: pplda"));
}

#[test]
fn unknown_subcommand_fails() {
    let (_, err, ok) = pplda(&["frobnicate"]);
    assert!(!ok);
    assert!(err.contains("unknown subcommand"));
}

#[test]
fn stats_tiny() {
    let (out, _, ok) = pplda(&["stats", "--profile", "tiny"]);
    assert!(ok);
    assert!(out.contains("Documents, D"));
    assert!(out.contains("60"));
}

#[test]
fn partition_tiny_all_algorithms() {
    let (out, _, ok) = pplda(&[
        "partition",
        "--profile",
        "tiny",
        "--procs",
        "1,4",
        "--restarts",
        "3",
    ]);
    assert!(ok, "{out}");
    assert!(out.contains("baseline"));
    assert!(out.contains("A3"));
    // P=1 row must be all 1.0000.
    let p1_line = out
        .lines()
        .find(|l| l.trim_start().starts_with('1') && l.contains("1.0000"))
        .unwrap();
    assert_eq!(p1_line.matches("1.0000").count(), 4, "{p1_line}");
}

#[test]
fn train_tiny() {
    let (out, _, ok) = pplda(&[
        "train",
        "--profile",
        "tiny",
        "--procs",
        "3",
        "--topics",
        "8",
        "--iters",
        "5",
        "--eval-every",
        "5",
        "--restarts",
        "2",
    ]);
    assert!(ok, "{out}");
    assert!(out.contains("final perplexity"));
    assert!(out.contains("eta="));
}

#[test]
fn train_bot_tiny_with_timeline() {
    let (out, _, ok) = pplda(&[
        "train-bot",
        "--profile",
        "tiny",
        "--procs",
        "2",
        "--topics",
        "4",
        "--iters",
        "3",
        "--restarts",
        "2",
        "--timeline",
    ]);
    assert!(ok, "{out}");
    assert!(out.contains("perplexity="));
    assert!(out.contains("rising"));
}

#[test]
fn train_pooled_mode_via_cli() {
    let (out, _, ok) = pplda(&[
        "train", "--profile", "tiny", "--procs", "2", "--topics", "4",
        "--iters", "2", "--restarts", "2", "--mode", "pooled",
    ]);
    assert!(ok, "{out}");
    assert!(out.contains("final perplexity"));
}

#[test]
fn train_packed_schedule_via_cli() {
    let (out, _, ok) = pplda(&[
        "train", "--profile", "tiny", "--workers", "2", "--grid-factor", "2",
        "--schedule", "packed", "--topics", "4", "--iters", "2", "--restarts", "2",
        "--mode", "pooled",
    ]);
    assert!(ok, "{out}");
    assert!(out.contains("P=4"), "{out}");
    assert!(out.contains("schedule packed(x2) workers=2"), "{out}");
    assert!(out.contains("schedule_eta="), "{out}");
    assert!(out.contains("final perplexity"), "{out}");
}

#[test]
fn train_bot_packed_schedule_via_cli() {
    let (out, _, ok) = pplda(&[
        "train-bot", "--profile", "tiny", "--workers", "2", "--grid-factor", "2",
        "--topics", "4", "--iters", "2", "--restarts", "2",
    ]);
    assert!(ok, "{out}");
    assert!(out.contains("workers=2 schedule=packed(x2)"), "{out}");
}

#[test]
fn train_sparse_and_alias_kernels_via_cli() {
    for kernel in ["sparse", "alias"] {
        let (out, _, ok) = pplda(&[
            "train", "--profile", "tiny", "--procs", "2", "--topics", "4",
            "--iters", "2", "--restarts", "2", "--mode", "pooled",
            "--kernel", kernel,
        ]);
        assert!(ok, "{kernel}: {out}");
        assert!(out.contains(&format!("kernel={kernel}")), "{out}");
        assert!(out.contains("final perplexity"), "{out}");
    }
}

#[test]
fn train_bot_kernel_via_cli() {
    let (out, _, ok) = pplda(&[
        "train-bot", "--profile", "tiny", "--procs", "2", "--topics", "4",
        "--iters", "2", "--restarts", "2", "--kernel", "sparse",
    ]);
    assert!(ok, "{out}");
    assert!(out.contains("kernel=sparse"), "{out}");
}

#[test]
fn train_balance_modes_via_cli() {
    for balance in ["adaptive", "steal"] {
        let (out, _, ok) = pplda(&[
            "train", "--profile", "tiny", "--workers", "2", "--grid-factor", "2",
            "--schedule", "packed", "--topics", "4", "--iters", "2", "--restarts", "2",
            "--mode", "pooled", "--kernel", "sparse", "--balance", balance,
        ]);
        assert!(ok, "{balance}: {out}");
        assert!(out.contains(&format!("balance={balance}")), "{out}");
        assert!(out.contains("measured_eta="), "{out}");
        assert!(out.contains("phases: "), "{out}");
        assert!(out.contains("final perplexity"), "{out}");
    }
}

#[test]
fn train_bot_balance_via_cli() {
    let (out, _, ok) = pplda(&[
        "train-bot", "--profile", "tiny", "--workers", "2", "--grid-factor", "2",
        "--topics", "4", "--iters", "2", "--restarts", "2", "--balance", "steal",
        "--mode", "pooled",
    ]);
    assert!(ok, "{out}");
    assert!(out.contains("balance=steal"), "{out}");
    assert!(out.contains("measured_eta_dw="), "{out}");
}

#[test]
fn unknown_balance_fails() {
    let (_, err, ok) = pplda(&[
        "train", "--profile", "tiny", "--topics", "4", "--iters", "1",
        "--balance", "magic",
    ]);
    assert!(!ok);
    assert!(err.contains("unknown balance mode"), "{err}");
}

#[test]
fn unknown_kernel_fails() {
    let (_, err, ok) = pplda(&[
        "train", "--profile", "tiny", "--topics", "4", "--iters", "1",
        "--kernel", "gpu",
    ]);
    assert!(!ok);
    assert!(err.contains("unknown kernel"), "{err}");
}

#[test]
fn grid_factor_without_packed_schedule_fails() {
    let (_, err, ok) = pplda(&[
        "train", "--profile", "tiny", "--schedule", "diagonal", "--grid-factor", "4",
        "--topics", "4", "--iters", "1",
    ]);
    assert!(!ok);
    assert!(err.contains("requires --schedule packed"), "{err}");
}

#[test]
fn train_json_report() {
    let dir = std::env::temp_dir().join("pplda_cli_test.json");
    let path = dir.to_str().unwrap();
    let (out, _, ok) = pplda(&[
        "train", "--profile", "tiny", "--procs", "2", "--topics", "4",
        "--iters", "2", "--restarts", "2", "--json", path,
    ]);
    assert!(ok, "{out}");
    let json = std::fs::read_to_string(path).unwrap();
    assert!(json.contains("\"final_perplexity\""));
    std::fs::remove_file(path).ok();
}

#[test]
fn train_spill_residency_via_cli() {
    // Determinism across residency at the CLI surface: the same run
    // in-core and spilled (with a byte budget + explicit spill dir)
    // prints identical perplexity lines.
    let dir = std::env::temp_dir().join(format!("pplda-cli-spill-{}", std::process::id()));
    let base = [
        "train", "--profile", "tiny", "--procs", "3", "--topics", "4",
        "--iters", "3", "--eval-every", "3", "--restarts", "2",
    ];
    let (in_core, _, ok) = pplda(&base);
    assert!(ok, "{in_core}");
    let mut spill_args: Vec<&str> = base.to_vec();
    let dir_s = dir.to_str().unwrap().to_string();
    spill_args.extend_from_slice(&[
        "--residency", "spill", "--memory-budget", "4m", "--spill-dir", &dir_s,
    ]);
    let (spilled, _, ok) = pplda(&spill_args);
    assert!(ok, "{spilled}");
    assert!(spilled.contains("residency=spill(4.00MiB)"), "{spilled}");
    let perplexity_of = |out: &str| {
        out.lines()
            .find(|l| l.contains("final perplexity"))
            .map(String::from)
            .unwrap()
    };
    assert_eq!(perplexity_of(&spilled), perplexity_of(&in_core));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn train_bot_spill_residency_via_cli() {
    let (out, _, ok) = pplda(&[
        "train-bot", "--profile", "tiny", "--procs", "2", "--topics", "4",
        "--iters", "2", "--restarts", "2", "--residency", "spill",
        "--mode", "pooled",
    ]);
    assert!(ok, "{out}");
    assert!(out.contains("residency=spill"), "{out}");
    assert!(out.contains("perplexity="), "{out}");
}

#[test]
fn train_checkpoint_resume_via_cli() {
    // Interrupt-and-resume at the CLI surface: 4 of 6 iterations with
    // --checkpoint-every 2, then --resume from the checkpoint root,
    // matches the uninterrupted run's final perplexity exactly.
    let root = std::env::temp_dir().join(format!("pplda-cli-ckpt-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&root);
    let root_s = root.to_str().unwrap().to_string();
    let base = [
        "train", "--profile", "tiny", "--procs", "3", "--topics", "4",
        "--eval-every", "6", "--restarts", "2",
    ];
    let mut oracle_args: Vec<&str> = base.to_vec();
    oracle_args.extend_from_slice(&["--iters", "6"]);
    let (oracle, _, ok) = pplda(&oracle_args);
    assert!(ok, "{oracle}");

    let mut partial_args: Vec<&str> = base.to_vec();
    partial_args.extend_from_slice(&[
        "--iters", "4", "--checkpoint-every", "2", "--checkpoint-dir", &root_s,
    ]);
    let (partial, _, ok) = pplda(&partial_args);
    assert!(ok, "{partial}");
    assert!(root.join("ckpt-2").is_dir(), "periodic checkpoint at sweep 2");
    assert!(root.join("ckpt-4").is_dir(), "periodic checkpoint at sweep 4");

    let mut resume_args: Vec<&str> = base.to_vec();
    resume_args.extend_from_slice(&["--iters", "6", "--resume", &root_s]);
    let (resumed, _, ok) = pplda(&resume_args);
    assert!(ok, "{resumed}");
    // Compare only the perplexity field — wall seconds differ per run.
    let perplexity_of = |out: &str| {
        out.lines()
            .find(|l| l.contains("final perplexity"))
            .and_then(|l| l.split('|').next())
            .map(|s| s.trim().to_string())
            .unwrap()
    };
    assert_eq!(perplexity_of(&resumed), perplexity_of(&oracle));
    std::fs::remove_dir_all(&root).ok();
}

#[test]
fn train_bot_checkpoint_resume_via_cli() {
    let root = std::env::temp_dir().join(format!("pplda-cli-bot-ckpt-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&root);
    let root_s = root.to_str().unwrap().to_string();
    let base = [
        "train-bot", "--profile", "tiny", "--procs", "2", "--topics", "4",
        "--restarts", "2",
    ];
    let mut oracle_args: Vec<&str> = base.to_vec();
    oracle_args.extend_from_slice(&["--iters", "4"]);
    let (oracle, _, ok) = pplda(&oracle_args);
    assert!(ok, "{oracle}");

    let mut partial_args: Vec<&str> = base.to_vec();
    partial_args.extend_from_slice(&[
        "--iters", "2", "--checkpoint-every", "2", "--checkpoint-dir", &root_s,
    ]);
    let (partial, _, ok) = pplda(&partial_args);
    assert!(ok, "{partial}");
    assert!(root.join("ckpt-2").is_dir(), "{partial}");

    let mut resume_args: Vec<&str> = base.to_vec();
    resume_args.extend_from_slice(&["--iters", "4", "--resume", &root_s]);
    let (resumed, _, ok) = pplda(&resume_args);
    assert!(ok, "{resumed}");
    let perplexity_of = |out: &str| {
        out.split_whitespace()
            .find(|t| t.starts_with("perplexity="))
            .map(String::from)
            .unwrap()
    };
    assert_eq!(perplexity_of(&resumed), perplexity_of(&oracle));
    std::fs::remove_dir_all(&root).ok();
}

#[test]
fn train_trace_out_and_analyze_trace_via_cli() {
    // Record a trace through the CLI surface, then feed it back through
    // `analyze-trace`: the trace must validate against the span schema
    // (every task covered exactly once) and yield a measured η.
    let path = std::env::temp_dir().join(format!("pplda-cli-trace-{}.json", std::process::id()));
    let path_s = path.to_str().unwrap().to_string();
    let (out, _, ok) = pplda(&[
        "train", "--profile", "tiny", "--workers", "2", "--grid-factor", "2",
        "--schedule", "packed", "--topics", "4", "--iters", "3", "--restarts", "2",
        "--mode", "pooled", "--commit", "ticketed", "--trace-out", &path_s,
    ]);
    assert!(ok, "{out}");
    assert!(out.contains("wrote "), "{out}");
    assert!(out.contains("events, 0 dropped"), "{out}");
    let raw = std::fs::read_to_string(&path).unwrap();
    assert!(raw.contains("\"traceEvents\""), "Perfetto-loadable Chrome trace");
    assert!(raw.contains("\"ph\":\"X\""), "{}", &raw[..200.min(raw.len())]);

    let (an, err, ok) = pplda(&["analyze-trace", &path_s]);
    assert!(ok, "{an}\n{err}");
    assert!(an.contains("measured_eta[word]"), "{an}");
    assert!(an.contains("critical path"), "{an}");
    assert!(an.contains("workers (busy"), "{an}");
    std::fs::remove_file(&path).ok();
}

#[test]
fn train_bot_trace_out_via_cli() {
    let path =
        std::env::temp_dir().join(format!("pplda-cli-bot-trace-{}.jsonl", std::process::id()));
    let path_s = path.to_str().unwrap().to_string();
    let (out, _, ok) = pplda(&[
        "train-bot", "--profile", "tiny", "--procs", "2", "--topics", "4",
        "--iters", "2", "--restarts", "2", "--mode", "pooled", "--trace-out", &path_s,
    ]);
    assert!(ok, "{out}");
    assert!(out.contains("events, 0 dropped"), "{out}");
    // Both phase families appear in the JSONL stream.
    let raw = std::fs::read_to_string(&path).unwrap();
    assert!(raw.lines().any(|l| l.contains("\"family\":0")), "word-phase events");
    assert!(raw.lines().any(|l| l.contains("\"family\":1")), "stamp-phase events");
    let (an, err, ok) = pplda(&["analyze-trace", &path_s]);
    assert!(ok, "{an}\n{err}");
    assert!(an.contains("measured_eta[stamp]"), "{an}");
    std::fs::remove_file(&path).ok();
}

#[test]
fn analyze_trace_rejects_garbage() {
    let path = std::env::temp_dir().join(format!("pplda-cli-bad-trace-{}", std::process::id()));
    std::fs::write(&path, "not a trace").unwrap();
    let (_, err, ok) = pplda(&["analyze-trace", path.to_str().unwrap()]);
    assert!(!ok);
    assert!(err.contains("analyze-trace"), "{err}");
    std::fs::remove_file(&path).ok();
}

#[test]
fn checkpoint_every_without_dir_fails() {
    let (_, err, ok) = pplda(&[
        "train", "--profile", "tiny", "--topics", "4", "--iters", "2",
        "--checkpoint-every", "2",
    ]);
    assert!(!ok);
    assert!(err.contains("requires --checkpoint-dir"), "{err}");
}

#[test]
fn unknown_residency_fails() {
    let (_, err, ok) = pplda(&[
        "train", "--profile", "tiny", "--topics", "4", "--iters", "1",
        "--residency", "tape",
    ]);
    assert!(!ok);
    assert!(err.contains("unknown residency"), "{err}");
}

#[test]
fn in_core_with_memory_budget_fails() {
    // A stale --memory-budget must not silently become an unbounded run.
    let (_, err, ok) = pplda(&[
        "train", "--profile", "tiny", "--topics", "4", "--iters", "1",
        "--residency", "in-core", "--memory-budget", "4m",
    ]);
    assert!(!ok);
    assert!(err.contains("only applies to --residency spill"), "{err}");
}
