//! Cross-module integration: training pipelines (serial, parallel LDA,
//! BoT) — determinism, convergence, and the Table-IV equivalence claim.

use pplda::coordinator::{
    train_bot, train_bot_checkpointed, train_lda, train_lda_checkpointed, TrainConfig,
};
use pplda::corpus::shard::Residency;
use pplda::corpus::synthetic::{generate, generate_timestamped, Profile, TimeProfile};
use pplda::gibbs::serial::SerialLda;
use pplda::kernel::KernelKind;
use pplda::partition::{partition, Algorithm};
use pplda::scheduler::exec::{ExecMode, ParallelLda};
use pplda::scheduler::schedule::ScheduleKind;

fn small_profile() -> Profile {
    let mut p = Profile::nips_like().scaled(40);
    p.len_sigma = 0.4; // tame giant-doc outliers at this tiny scale
    p
}

#[test]
fn parallel_and_serial_converge_together_across_p() {
    let bow = generate(&small_profile(), 101);
    let k = 16;
    let iters = 25;

    let mut serial = SerialLda::init(&bow, k, 0.5, 0.1, 5);
    serial.train(&bow, iters, 0);
    let ps = serial.perplexity(&bow);

    for p in [2usize, 5, 10] {
        let plan = partition(&bow, p, Algorithm::A3 { restarts: 5 }, 5);
        let mut par = ParallelLda::init(&bow, &plan, k, 0.5, 0.1, 5);
        par.train(&bow, iters, 0, ExecMode::Sequential);
        let pp = par.perplexity(&bow);
        let rel = (pp - ps).abs() / ps;
        assert!(
            rel < 0.05,
            "P={p}: parallel {pp:.2} vs serial {ps:.2} (rel {rel:.4})"
        );
    }
}

#[test]
fn training_is_deterministic_per_seed_and_plan() {
    let bow = generate(&small_profile(), 102);
    let plan = partition(&bow, 4, Algorithm::A2, 1);
    let cfg = TrainConfig::quick(8, 5);
    let a = train_lda(&bow, &plan, &cfg);
    let b = train_lda(&bow, &plan, &cfg);
    assert_eq!(a.final_perplexity, b.final_perplexity);
    assert_eq!(a.curve, b.curve);
}

#[test]
fn better_eta_means_lower_sweep_cost() {
    let bow = generate(&Profile::nips_like().scaled(10), 103);
    let p = 16;
    let base = partition(&bow, p, Algorithm::Baseline { restarts: 5 }, 2);
    let a3 = partition(&bow, p, Algorithm::A3 { restarts: 5 }, 2);
    assert!(a3.eta > base.eta);
    // Eq. 1 cost is inversely proportional to eta at fixed N, P.
    assert!(a3.cost < base.cost);
}

#[test]
fn bot_pipeline_end_to_end() {
    let mut profile = Profile::tiny();
    profile.num_docs = 120;
    profile.num_tokens = 12_000;
    profile.time = Some(TimeProfile {
        first_year: 1990,
        last_year: 2009,
        growth: 0.1,
        stamps_per_doc: 8,
    });
    let tc = generate_timestamped(&profile, 104);
    let cfg = TrainConfig::quick(8, 15);

    let serial = train_bot(&tc, 1, Algorithm::A1, &cfg);
    let parallel = train_bot(&tc, 5, Algorithm::A3 { restarts: 5 }, &cfg);

    let rel = (parallel.final_perplexity - serial.final_perplexity).abs()
        / serial.final_perplexity;
    assert!(rel < 0.05, "BoT Table IV: rel {rel}");
    assert!(parallel.speedup_model > 2.0);
    // Timeline extraction present for every topic, each normalized.
    assert_eq!(parallel.timelines.len(), 8);
    for tl in &parallel.timelines {
        let sum: f64 = tl.pi.iter().sum();
        assert!((sum - 1.0).abs() < 1e-6);
    }
}

#[test]
fn threaded_mode_matches_sequential_through_driver() {
    let bow = generate(&small_profile(), 105);
    let plan = partition(&bow, 3, Algorithm::A3 { restarts: 3 }, 3);
    let mut cfg = TrainConfig::quick(8, 5);
    let seq = train_lda(&bow, &plan, &cfg);
    cfg.mode = ExecMode::Threaded;
    let thr = train_lda(&bow, &plan, &cfg);
    assert_eq!(seq.final_perplexity, thr.final_perplexity);
}

#[test]
fn pooled_mode_matches_sequential_through_driver() {
    let bow = generate(&small_profile(), 106);
    let plan = partition(&bow, 3, Algorithm::A3 { restarts: 3 }, 3);
    let mut cfg = TrainConfig::quick(8, 5);
    let seq = train_lda(&bow, &plan, &cfg);
    cfg.mode = ExecMode::Pooled;
    let pooled = train_lda(&bow, &plan, &cfg);
    assert_eq!(seq.final_perplexity, pooled.final_perplexity);
    assert_eq!(seq.curve, pooled.curve);
}

#[test]
fn pooled_bot_matches_sequential_through_driver() {
    let mut profile = Profile::tiny();
    profile.time = Some(TimeProfile {
        first_year: 2000,
        last_year: 2009,
        growth: 0.1,
        stamps_per_doc: 4,
    });
    let tc = generate_timestamped(&profile, 107);
    let mut cfg = TrainConfig::quick(8, 5);
    let seq = train_bot(&tc, 4, Algorithm::A3 { restarts: 3 }, &cfg);
    cfg.mode = ExecMode::Pooled;
    let pooled = train_bot(&tc, 4, Algorithm::A3 { restarts: 3 }, &cfg);
    assert_eq!(seq.final_perplexity, pooled.final_perplexity);
}

#[test]
fn packed_schedule_matches_diagonal_through_driver() {
    // The tentpole's end-to-end determinism claim: the same grid-8 plan
    // trained diagonally (W=8, sequential) and packed onto fewer workers
    // (W ∈ {2, 4}, pooled) produces identical perplexity curves.
    let bow = generate(&small_profile(), 109);
    let plan = partition(&bow, 8, Algorithm::A3 { restarts: 3 }, 7);
    let mut cfg = TrainConfig::quick(8, 5);
    cfg.eval_every = 5;
    let diag = train_lda(&bow, &plan, &cfg);
    for workers in [2usize, 4] {
        let mut packed_cfg = cfg;
        packed_cfg.schedule = ScheduleKind::Packed { grid_factor: 8 / workers };
        packed_cfg.workers = workers;
        packed_cfg.mode = ExecMode::Pooled;
        let packed = train_lda(&bow, &plan, &packed_cfg);
        assert_eq!(diag.final_perplexity, packed.final_perplexity, "W={workers}");
        assert_eq!(diag.curve, packed.curve, "W={workers}");
        assert_eq!(packed.workers, workers);
        assert!(packed.schedule_eta > 0.0 && packed.schedule_eta <= 1.0 + 1e-12);
    }
}

#[test]
fn packed_bot_matches_diagonal_through_driver() {
    let mut profile = Profile::tiny();
    profile.time = Some(TimeProfile {
        first_year: 2000,
        last_year: 2009,
        growth: 0.1,
        stamps_per_doc: 4,
    });
    let tc = generate_timestamped(&profile, 110);
    let mut cfg = TrainConfig::quick(8, 4);
    let diag = train_bot(&tc, 4, Algorithm::A3 { restarts: 3 }, &cfg);
    cfg.schedule = ScheduleKind::Packed { grid_factor: 2 };
    cfg.workers = 2;
    cfg.mode = ExecMode::Pooled;
    let packed = train_bot(&tc, 4, Algorithm::A3 { restarts: 3 }, &cfg);
    assert_eq!(diag.final_perplexity, packed.final_perplexity);
    assert_eq!(packed.workers, 2);
}

#[test]
fn sparse_and_alias_kernels_bit_identical_across_modes_and_workers() {
    // The kernel subsystem's end-to-end determinism claim (`--kernel
    // sparse|alias` equivalent): for each non-dense kernel, the
    // Sequential diagonal run is the oracle, and every (mode, W)
    // combination over the same grid-4 plan — Threaded and Pooled,
    // packed onto W ∈ {1, 2, 4} workers — reproduces its perplexity
    // curve bit for bit.
    let bow = generate(&small_profile(), 111);
    let plan = partition(&bow, 4, Algorithm::A3 { restarts: 3 }, 11);
    for kernel in [KernelKind::Sparse, KernelKind::Alias] {
        let mut cfg = TrainConfig::quick(8, 4);
        cfg.eval_every = 2;
        cfg.kernel = kernel;
        let oracle = train_lda(&bow, &plan, &cfg);
        assert_eq!(oracle.kernel, kernel.name());
        for workers in [1usize, 2, 4] {
            for mode in [ExecMode::Threaded, ExecMode::Pooled] {
                let mut c = cfg;
                c.schedule = ScheduleKind::Packed { grid_factor: 4 / workers };
                c.workers = workers;
                c.mode = mode;
                let r = train_lda(&bow, &plan, &c);
                assert_eq!(
                    oracle.final_perplexity,
                    r.final_perplexity,
                    "{kernel:?} {mode:?} W={workers}"
                );
                assert_eq!(oracle.curve, r.curve, "{kernel:?} {mode:?} W={workers}");
            }
        }
    }
}

#[test]
fn sparse_and_alias_bot_bit_identical_across_modes_and_workers() {
    // Same determinism matrix for BoT (both phases, timestamp factor
    // folded into the phase hyperparameters).
    let mut profile = Profile::tiny();
    profile.time = Some(TimeProfile {
        first_year: 2000,
        last_year: 2009,
        growth: 0.1,
        stamps_per_doc: 4,
    });
    let tc = generate_timestamped(&profile, 113);
    for kernel in [KernelKind::Sparse, KernelKind::Alias] {
        let mut cfg = TrainConfig::quick(8, 3);
        cfg.kernel = kernel;
        let oracle = train_bot(&tc, 4, Algorithm::A3 { restarts: 3 }, &cfg);
        assert_eq!(oracle.kernel, kernel.name());
        for workers in [1usize, 2, 4] {
            for mode in [ExecMode::Threaded, ExecMode::Pooled] {
                let mut c = cfg;
                c.schedule = ScheduleKind::Packed { grid_factor: 4 / workers };
                c.workers = workers;
                c.mode = mode;
                let r = train_bot(&tc, 4, Algorithm::A3 { restarts: 3 }, &c);
                assert_eq!(
                    oracle.final_perplexity,
                    r.final_perplexity,
                    "{kernel:?} {mode:?} W={workers}"
                );
            }
        }
    }
}

#[test]
fn sparse_and_alias_converge_with_dense_on_nips_like() {
    // Statistical validation on the nips-like synthetic corpus: the
    // sparse buckets and the MH-corrected alias sampler target the same
    // posterior as the dense reference, so trained perplexities agree
    // within tolerance (the chains differ bit-wise by construction).
    let bow = generate(&small_profile(), 112);
    let plan = partition(&bow, 5, Algorithm::A3 { restarts: 5 }, 12);
    let mut cfg = TrainConfig::quick(16, 25);
    let dense = train_lda(&bow, &plan, &cfg);
    for kernel in [KernelKind::Sparse, KernelKind::Alias] {
        cfg.kernel = kernel;
        let r = train_lda(&bow, &plan, &cfg);
        let rel = (r.final_perplexity - dense.final_perplexity).abs() / dense.final_perplexity;
        assert!(
            rel < 0.05,
            "{kernel:?}: dense {} vs {} (rel {rel:.4})",
            dense.final_perplexity,
            r.final_perplexity
        );
    }
}

#[test]
fn spill_residency_through_driver_is_bit_identical() {
    // The out-of-core determinism claim end to end: `--residency spill`
    // (with and without a byte budget) reproduces the in-core perplexity
    // curve bit for bit, across exec modes and packed schedules.
    let bow = generate(&small_profile(), 114);
    let plan = partition(&bow, 4, Algorithm::A3 { restarts: 3 }, 13);
    let mut cfg = TrainConfig::quick(8, 4);
    cfg.eval_every = 2;
    let in_core = train_lda(&bow, &plan, &cfg);
    assert_eq!(in_core.residency, "in-core");

    for (residency, label) in [
        (Residency::Spill { budget_bytes: 0 }, "spill".to_string()),
        // Half the corpus comfortably covers two of the four diagonals.
        (
            Residency::Spill { budget_bytes: bow.num_tokens() * 12 / 2 },
            format!(
                "spill({})",
                pplda::util::human_bytes((bow.num_tokens() * 12 / 2) as usize)
            ),
        ),
    ] {
        for mode in [ExecMode::Sequential, ExecMode::Pooled] {
            let mut c = cfg;
            c.residency = residency;
            c.mode = mode;
            let r = train_lda(&bow, &plan, &c);
            assert_eq!(r.residency, label, "{mode:?}");
            assert_eq!(r.final_perplexity, in_core.final_perplexity, "{mode:?} {label}");
            assert_eq!(r.curve, in_core.curve, "{mode:?} {label}");
        }
    }
}

#[test]
fn spill_bot_through_driver_is_bit_identical() {
    let mut profile = Profile::tiny();
    profile.time = Some(TimeProfile {
        first_year: 2000,
        last_year: 2009,
        growth: 0.1,
        stamps_per_doc: 4,
    });
    let tc = generate_timestamped(&profile, 115);
    let mut cfg = TrainConfig::quick(8, 3);
    let in_core = train_bot(&tc, 4, Algorithm::A3 { restarts: 3 }, &cfg);
    assert_eq!(in_core.residency, "in-core");
    cfg.residency = Residency::Spill { budget_bytes: 0 };
    cfg.mode = ExecMode::Pooled;
    let spilled = train_bot(&tc, 4, Algorithm::A3 { restarts: 3 }, &cfg);
    assert_eq!(spilled.residency, "spill");
    assert_eq!(spilled.final_perplexity, in_core.final_perplexity);
    // Spill-mode phase breakdown surfaces the write-back bucket.
    let names: Vec<&str> = spilled.phases.iter().map(|(n, _)| n.as_str()).collect();
    assert!(names.contains(&"spill_write"), "{names:?}");
}

#[test]
fn checkpoint_interrupt_resume_reproduces_uninterrupted_run() {
    // The fault-tolerance acceptance claim end to end: a `--checkpoint-
    // every 2` run interrupted after 4 of 6 sweeps and resumed from its
    // latest checkpoint reproduces the uninterrupted run bit for bit —
    // even when the resumed leg runs on a different executor.
    let bow = generate(&small_profile(), 116);
    let plan = partition(&bow, 4, Algorithm::A3 { restarts: 3 }, 14);
    let mut cfg = TrainConfig::quick(8, 6);
    cfg.eval_every = 3;
    let oracle = train_lda(&bow, &plan, &cfg);
    assert_eq!(oracle.task_retries, 0);
    assert_eq!(oracle.io_retries, 0);

    let root = std::env::temp_dir().join(format!("pplda-it-ckpt-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&root);
    cfg.iters = 4;
    cfg.checkpoint_every = 2;
    train_lda_checkpointed(&bow, &plan, &cfg, Some(&root), None);
    assert!(root.join("ckpt-2").is_dir() && root.join("ckpt-4").is_dir());

    cfg.iters = 6;
    cfg.checkpoint_every = 0;
    cfg.mode = ExecMode::Pooled;
    let resumed = train_lda_checkpointed(&bow, &plan, &cfg, None, Some(&root));
    assert_eq!(resumed.final_perplexity, oracle.final_perplexity);
    assert_eq!(resumed.curve.last(), oracle.curve.last());
    std::fs::remove_dir_all(&root).unwrap();
}

#[test]
fn checkpoint_interrupt_resume_reproduces_uninterrupted_bot_run() {
    let mut profile = Profile::tiny();
    profile.time = Some(TimeProfile {
        first_year: 2000,
        last_year: 2009,
        growth: 0.1,
        stamps_per_doc: 4,
    });
    let tc = generate_timestamped(&profile, 117);
    let algo = Algorithm::A3 { restarts: 3 };
    let mut cfg = TrainConfig::quick(8, 6);
    let oracle = train_bot(&tc, 4, algo, &cfg);
    assert_eq!(oracle.task_retries, 0);
    assert_eq!(oracle.io_retries, 0);

    let root = std::env::temp_dir().join(format!("pplda-it-bot-ckpt-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&root);
    cfg.iters = 4;
    cfg.checkpoint_every = 2;
    train_bot_checkpointed(&tc, 4, algo, &cfg, Some(&root), None);
    assert!(root.join("ckpt-4").is_dir());

    cfg.iters = 6;
    cfg.checkpoint_every = 0;
    cfg.mode = ExecMode::Pooled;
    let resumed = train_bot_checkpointed(&tc, 4, algo, &cfg, None, Some(&root));
    assert_eq!(resumed.final_perplexity, oracle.final_perplexity);
    std::fs::remove_dir_all(&root).unwrap();
}

#[test]
fn pooled_training_is_deterministic_and_reuses_one_pool() {
    let bow = generate(&small_profile(), 108);
    let plan = partition(&bow, 4, Algorithm::A2, 9);
    let mut a = ParallelLda::init(&bow, &plan, 8, 0.5, 0.1, 9);
    let mut b = ParallelLda::init(&bow, &plan, 8, 0.5, 0.1, 9);
    a.train(&bow, 4, 0, ExecMode::Pooled);
    b.train(&bow, 4, 0, ExecMode::Pooled);
    assert_eq!(a.counts.doc_topic, b.counts.doc_topic);
    assert_eq!(a.counts.topic, b.counts.topic);
    let pool = a.pool().expect("pooled training materializes the pool");
    assert_eq!(pool.workers(), 4);
    assert_eq!(pool.epochs_run(), 16, "4 sweeps x 4 epochs on one pool");
}
