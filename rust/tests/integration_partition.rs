//! Cross-module integration: corpus generation → partitioning →
//! partition map → cost invariants, over randomized profiles and all
//! four algorithms.

use pplda::corpus::synthetic::{generate, Profile};
use pplda::partition::scheme::PartitionMap;
use pplda::partition::{eta, partition, Algorithm};
use pplda::testing::prop;

fn algorithms(restarts: usize) -> [Algorithm; 4] {
    [
        Algorithm::Baseline { restarts },
        Algorithm::A1,
        Algorithm::A2,
        Algorithm::A3 { restarts },
    ]
}

#[test]
fn plan_invariants_over_random_corpora() {
    prop::check("plan-invariants", 0x1A7E6, 12, |rng| {
        let mut profile = Profile::tiny();
        profile.num_docs = prop::gen_size(rng, 5, 150);
        profile.num_tokens = (profile.num_docs as u64) * (10 + rng.gen_range(200) as u64);
        profile.vocab = prop::gen_size(rng, 10, 400);
        let bow = generate(&profile, rng.next_u64());
        let p = 1 + rng.gen_range(12);

        for algo in algorithms(2) {
            let plan = partition(&bow, p, algo, rng.next_u64());
            // Exhaustive assignment.
            assert_eq!(plan.doc_group.len(), bow.num_docs());
            assert_eq!(plan.word_group.len(), bow.num_words());
            // Eta consistent with a recomputation from scratch.
            let again = eta::eta(&bow, &plan.doc_group, &plan.word_group, p);
            assert!((plan.eta - again.eta).abs() < 1e-12);
            // Cost matrix conserves tokens.
            assert_eq!(plan.costs.total(), bow.num_tokens());
            // Map materialization agrees cell-for-cell.
            let map = PartitionMap::build(&bow, &plan);
            for m in 0..p {
                for n in 0..p {
                    assert_eq!(map.tokens(m, n), plan.costs.get(m, n));
                }
            }
        }
    });
}

#[test]
fn serial_cost_equals_tokens_only_at_p1() {
    let bow = generate(&Profile::tiny(), 7);
    let plan = partition(&bow, 1, Algorithm::A1, 7);
    assert_eq!(plan.cost as u64, bow.num_tokens());
    assert!((plan.eta - 1.0).abs() < 1e-12);
}

#[test]
fn paper_ordering_holds_on_nips_scale_corpus() {
    // The paper's Table II ordering at P=30/60 on the full-size NIPS-like
    // corpus. Restarts reduced vs paper (10 vs 100) to keep test time
    // sane; the ordering is robust to that.
    let bow = generate(&Profile::nips_like(), 42);
    for p in [30usize, 60] {
        let base = partition(&bow, p, Algorithm::Baseline { restarts: 10 }, 1).eta;
        let a1 = partition(&bow, p, Algorithm::A1, 1).eta;
        let a2 = partition(&bow, p, Algorithm::A2, 1).eta;
        let a3 = partition(&bow, p, Algorithm::A3 { restarts: 10 }, 1).eta;
        assert!(a1 > base && a2 > base && a3 > base, "P={p}: proposed > baseline");
        assert!(a3 + 0.02 > a1.max(a2), "P={p}: A3 leads");
    }
}

#[test]
fn eta_degrades_monotonically_in_p_for_baseline() {
    let bow = generate(&Profile::nips_like().scaled(4), 9);
    let mut last = f64::INFINITY;
    for p in [1usize, 10, 30, 60] {
        let e = partition(&bow, p, Algorithm::Baseline { restarts: 5 }, 3).eta;
        assert!(e <= last + 0.02, "baseline eta should fall with P");
        last = e;
    }
}
