//! Cross-layer integration: the AOT artifacts (L1 Pallas kernels lowered
//! through L2 jax into HLO text) loaded and driven from the L3
//! coordinator. Skipped (with a notice) when `make artifacts` has not
//! run.

use pplda::coordinator::{train_lda, Backend, TrainConfig};
use pplda::corpus::synthetic::{generate, Profile};
use pplda::partition::{partition, Algorithm};
use pplda::runtime::executor::Artifacts;

fn artifacts_or_skip() -> Option<Artifacts> {
    let dir = Artifacts::default_dir();
    if !Artifacts::available(&dir) {
        eprintln!("SKIP: no artifacts at {dir:?}; run `make artifacts`");
        return None;
    }
    Some(Artifacts::discover(dir).unwrap())
}

#[test]
fn xla_backend_trains_through_the_driver() {
    let Some(arts) = artifacts_or_skip() else { return };
    let (_, k) = arts
        .variants("sampler")
        .into_iter()
        .min_by_key(|&(_, k)| k)
        .unwrap();

    let bow = generate(&Profile::tiny(), 201);
    let plan = partition(&bow, 1, Algorithm::A1, 201);
    let cfg = TrainConfig {
        topics: k,
        iters: 8,
        eval_every: 4,
        backend: Backend::Xla,
        ..Default::default()
    };
    let report = train_lda(&bow, &plan, &cfg);
    assert_eq!(report.backend, "xla");
    assert_eq!(report.curve.len(), 2);
    // Learning happened.
    assert!(report.curve[1].1 < report.curve[0].1 * 1.02);
    assert!(report.final_perplexity.is_finite());
}

#[test]
fn xla_and_native_backends_agree_on_converged_perplexity() {
    let Some(arts) = artifacts_or_skip() else { return };
    let (_, k) = arts
        .variants("sampler")
        .into_iter()
        .min_by_key(|&(_, k)| k)
        .unwrap();

    let bow = generate(&Profile::tiny(), 202);
    let plan = partition(&bow, 1, Algorithm::A1, 202);
    let iters = 20;
    let native = train_lda(
        &bow,
        &plan,
        &TrainConfig {
            topics: k,
            iters,
            ..Default::default()
        },
    );
    let xla = train_lda(
        &bow,
        &plan,
        &TrainConfig {
            topics: k,
            iters,
            backend: Backend::Xla,
            ..Default::default()
        },
    );
    // Different samplers (exact CGS vs batched ESCA-style), same model:
    // converged perplexities should be close.
    let rel = (native.final_perplexity - xla.final_perplexity).abs()
        / native.final_perplexity;
    assert!(
        rel < 0.08,
        "native {} vs xla {} (rel {rel:.4})",
        native.final_perplexity,
        xla.final_perplexity
    );
}

#[test]
fn every_manifest_artifact_compiles_and_runs() {
    let Some(arts) = artifacts_or_skip() else { return };
    for (b, k) in arts.variants("sampler") {
        let exe = arts.sampler(b, k).expect("compile");
        let z = exe
            .run(
                &vec![1.0; b * k],
                &vec![1.0; b * k],
                &vec![k as f32; k],
                &vec![0.5; b * k],
                [0.5, 0.1, 0.5 * k as f32, 0.1 * 50.0],
            )
            .expect("run");
        assert_eq!(z.len(), b);
        assert!(z.iter().all(|&t| (t as usize) < k));
    }
    for (b, k) in arts.variants("loglik") {
        let exe = arts.loglik(b, k).expect("compile");
        let (sum, ll) = exe
            .run(
                &vec![1.0; b * k],
                &vec![k as f32; b],
                &vec![1.0; b * k],
                &vec![k as f32; k],
                [0.5, 0.1, 0.5 * k as f32, 0.1 * 50.0],
            )
            .expect("run");
        assert_eq!(ll.len(), b);
        assert!(sum.is_finite() && sum < 0.0);
    }
}
