//! End-to-end serve path: snapshot export at the CLI surface, the TCP
//! JSON-lines protocol against a live `pplda serve` process (info /
//! query / typed errors / shutdown), hot reload triggered by a snapshot
//! publish, rejection of a corrupt publish, SIGINT drain, and the
//! `query-bench` driver. Everything runs against the real binary via
//! `CARGO_BIN_EXE_pplda`; in-process oracles come from the library
//! (`serve::engine::fold_in`), which the server must match bit for bit.

use std::io::{BufRead, BufReader, Read};
use std::net::SocketAddr;
use std::path::{Path, PathBuf};
use std::process::{Child, ChildStdout, Command, Stdio};
use std::time::{Duration, Instant};

use pplda::corpus::synthetic::{generate, Profile};
use pplda::gibbs::serial::SerialLda;
use pplda::serve::engine::{fold_in, FoldScratch};
use pplda::serve::net::Client;
use pplda::serve::snapshot::ModelSnapshot;
use pplda::util::json::Json;

fn pplda(args: &[&str]) -> (String, String, bool) {
    let out = Command::new(env!("CARGO_BIN_EXE_pplda"))
        .args(args)
        .output()
        .expect("spawn pplda");
    (
        String::from_utf8_lossy(&out.stdout).into_owned(),
        String::from_utf8_lossy(&out.stderr).into_owned(),
        out.status.success(),
    )
}

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("pplda-serve-{}-{name}", std::process::id()))
}

/// A briefly-trained tiny model, written as a snapshot at `path`.
fn write_snapshot(path: &Path, seed: u64) -> ModelSnapshot {
    let bow = generate(&Profile::tiny(), 42);
    let mut lda = SerialLda::init(&bow, 8, 0.5, 0.1, 42);
    for _ in 0..3 {
        lda.sweep();
    }
    let snap = ModelSnapshot::from_counts(&lda.counts, 0.5, 0.1, seed);
    snap.write(path).expect("write snapshot");
    snap
}

/// Spawn `pplda serve` and block until it announces its bound address.
/// Returns the child, the parsed address, and the reader positioned
/// just past the `listening` line (for watching later stdout).
fn spawn_serve(snap: &Path, extra: &[&str]) -> (Child, SocketAddr, BufReader<ChildStdout>) {
    let mut child = Command::new(env!("CARGO_BIN_EXE_pplda"))
        .arg("serve")
        .arg(snap)
        .args(["--addr", "127.0.0.1:0"])
        .args(extra)
        .stdout(Stdio::piped())
        .stderr(Stdio::piped())
        .spawn()
        .expect("spawn pplda serve");
    let mut reader = BufReader::new(child.stdout.take().expect("child stdout"));
    let mut line = String::new();
    let addr = loop {
        line.clear();
        if reader.read_line(&mut line).expect("read serve stdout") == 0 {
            panic!("serve exited before announcing its address");
        }
        if let Some(rest) = line.trim().strip_prefix("serve: listening on ") {
            break rest.parse::<SocketAddr>().expect("parse announced address");
        }
    };
    (child, addr, reader)
}

/// Reap the child after a graceful stop and return (stdout_rest, stderr).
fn finish(mut child: Child, mut reader: BufReader<ChildStdout>) -> (String, String) {
    let status = child.wait().expect("wait for serve");
    assert!(status.success(), "serve exited nonzero: {status:?}");
    let mut rest = String::new();
    reader.read_to_string(&mut rest).expect("drain stdout");
    let mut err = String::new();
    if let Some(mut stderr) = child.stderr.take() {
        stderr.read_to_string(&mut err).expect("drain stderr");
    }
    (rest, err)
}

#[test]
fn train_snapshot_out_matches_export_snapshot_byte_for_byte() {
    // The same final counts reached two ways — train-end `--snapshot-out`
    // and `export-snapshot` from the sweep-4 checkpoint — must produce
    // identical snapshot files (the format has no timestamps or other
    // nondeterminism).
    let root = tmp("ckpt");
    let _ = std::fs::remove_dir_all(&root);
    let snap_a = tmp("train-end.ppsnap");
    let snap_b = tmp("exported.ppsnap");
    let root_s = root.to_str().unwrap().to_string();
    let (a_s, b_s) = (snap_a.to_str().unwrap(), snap_b.to_str().unwrap());

    let flags = [
        "--profile", "tiny", "--procs", "3", "--topics", "4", "--iters", "4",
        "--seed", "42", "--restarts", "2",
    ];
    let mut train_args = vec!["train"];
    train_args.extend_from_slice(&flags);
    train_args.extend_from_slice(&[
        "--eval-every", "4", "--checkpoint-every", "4", "--checkpoint-dir", &root_s,
        "--snapshot-out", a_s,
    ]);
    let (out, err, ok) = pplda(&train_args);
    assert!(ok, "{out}\n{err}");
    assert!(out.contains("wrote snapshot"), "{out}");
    assert!(!out.contains("checkpointed at sweep"), "no interrupt happened: {out}");
    assert!(root.join("ckpt-4").is_dir(), "{out}");

    let mut export_args = vec!["export-snapshot"];
    export_args.extend_from_slice(&flags);
    export_args.extend_from_slice(&["--from", &root_s, "--out", b_s]);
    let (out, err, ok) = pplda(&export_args);
    assert!(ok, "{out}\n{err}");
    assert!(out.contains("exported snapshot"), "{out}");

    let bytes_a = std::fs::read(&snap_a).unwrap();
    let bytes_b = std::fs::read(&snap_b).unwrap();
    assert_eq!(bytes_a, bytes_b, "snapshot files differ");

    // And the file round-trips through the loader.
    let loaded = ModelSnapshot::load(&snap_a).expect("load train-end snapshot");
    assert_eq!(loaded.k, 4);
    assert_eq!(loaded.seed, 42);

    std::fs::remove_dir_all(&root).ok();
    std::fs::remove_file(&snap_a).ok();
    std::fs::remove_file(&snap_b).ok();
}

#[test]
fn serve_protocol_round_trip_with_oracle_and_typed_errors() {
    let path = tmp("proto.ppsnap");
    let snap = write_snapshot(&path, 7);
    let (child, addr, reader) = spawn_serve(&path, &["--no-watch"]);
    let mut client = Client::connect(&addr).expect("connect");

    let info = client.info().expect("info");
    assert_eq!(info.get("ok").and_then(Json::as_bool), Some(true));
    assert_eq!(info.get("k").and_then(Json::as_u64), Some(snap.k as u64));
    assert_eq!(info.get("v").and_then(Json::as_u64), Some(snap.v as u64));
    assert_eq!(info.get("seed").and_then(Json::as_u64), Some(7));

    // Replies over the wire are bit-identical to the in-process engine
    // oracle: floats serialize shortest-roundtrip, so equality is exact.
    let words: Vec<u32> = (0..12).map(|i| (i * 3 % snap.v) as u32).collect();
    for id in [0u64, 9, 1 << 40] {
        let reply = client.query(id, &words, None).expect("query");
        assert_eq!(reply.get("ok").and_then(Json::as_bool), Some(true), "{}", reply.to_string());
        assert_eq!(reply.get("degraded").and_then(Json::as_bool), Some(false));
        let oracle = fold_in(&snap, &mut FoldScratch::new(), &words, id, 10);
        let theta = reply.get("theta").and_then(Json::as_arr).expect("theta array");
        assert_eq!(theta.len(), snap.k);
        for (i, j) in theta.iter().enumerate() {
            assert_eq!(j.as_f64(), Some(f64::from(oracle[i])), "theta[{i}] of id {id}");
        }
    }

    // Typed errors come back as tags, and the connection keeps working.
    let bad = client.query(99, &[snap.v as u32], None).expect("oov query");
    assert_eq!(bad.get("ok").and_then(Json::as_bool), Some(false));
    assert_eq!(bad.get("error").and_then(Json::as_str), Some("bad-request"));

    let late = client.query(100, &words, Some(0)).expect("expired query");
    assert_eq!(late.get("ok").and_then(Json::as_bool), Some(false));
    assert_eq!(late.get("error").and_then(Json::as_str), Some("deadline"));

    let ok_again = client.query(101, &words, None).expect("recovery query");
    assert_eq!(ok_again.get("ok").and_then(Json::as_bool), Some(true));

    let stats = client.stats().expect("stats");
    assert_eq!(stats.get("ok").and_then(Json::as_bool), Some(true));

    let bye = client.shutdown().expect("shutdown");
    assert_eq!(bye.get("draining").and_then(Json::as_bool), Some(true));
    let (rest, err) = finish(child, reader);
    assert!(rest.contains("serve: draining"), "{rest}\n{err}");
    assert!(rest.contains("serve: drained |"), "{rest}");
    assert!(rest.contains("SERVE_JSON "), "{rest}");
    std::fs::remove_file(&path).ok();
}

#[test]
fn hot_reload_swaps_on_publish_and_survives_a_corrupt_publish() {
    let path = tmp("reload.ppsnap");
    write_snapshot(&path, 1);
    let (child, addr, reader) = spawn_serve(&path, &[]);
    let mut client = Client::connect(&addr).expect("connect");
    assert_eq!(client.info().unwrap().get("seed").and_then(Json::as_u64), Some(1));

    // Publish a new snapshot (same K/V, new seed) the way a trainer
    // would: full write + atomic rename. The watcher must swap it in.
    std::thread::sleep(Duration::from_millis(50));
    write_snapshot(&path, 2);
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        let seed = client.info().expect("info").get("seed").and_then(Json::as_u64);
        if seed == Some(2) {
            break;
        }
        assert!(Instant::now() < deadline, "snapshot never hot-reloaded (seed {seed:?})");
        std::thread::sleep(Duration::from_millis(100));
    }

    // A corrupt publish (truncated garbage straight into the path) must
    // be rejected while the old snapshot keeps serving.
    std::fs::write(&path, b"PPSNAP1\0 definitely not a snapshot").unwrap();
    std::thread::sleep(Duration::from_millis(1200));
    let info = client.info().expect("server still serving");
    assert_eq!(info.get("seed").and_then(Json::as_u64), Some(2));
    let reply = client.query(5, &[0, 1, 2], None).expect("query after bad publish");
    assert_eq!(reply.get("ok").and_then(Json::as_bool), Some(true));

    client.shutdown().expect("shutdown");
    let (rest, err) = finish(child, reader);
    assert!(rest.contains("serve: snapshot hot-reloaded"), "{rest}");
    assert!(err.contains("reload rejected"), "stderr: {err}\nstdout: {rest}");
    std::fs::remove_file(&path).ok();
}

#[cfg(unix)]
#[test]
fn sigint_drains_the_server_gracefully() {
    let path = tmp("sigint.ppsnap");
    write_snapshot(&path, 3);
    let (child, addr, reader) = spawn_serve(&path, &["--no-watch"]);
    let mut client = Client::connect(&addr).expect("connect");
    let reply = client.query(1, &[0, 1], None).expect("query");
    assert_eq!(reply.get("ok").and_then(Json::as_bool), Some(true));

    let kill = Command::new("kill")
        .args(["-INT", &child.id().to_string()])
        .status()
        .expect("send SIGINT");
    assert!(kill.success());
    let (rest, err) = finish(child, reader);
    assert!(rest.contains("serve: drained |"), "{rest}\n{err}");
    std::fs::remove_file(&path).ok();
}

#[test]
fn query_bench_drives_a_live_server() {
    let path = tmp("qbench.ppsnap");
    write_snapshot(&path, 4);
    let (child, addr, reader) = spawn_serve(&path, &["--no-watch"]);

    let addr_s = addr.to_string();
    let (out, err, ok) = pplda(&[
        "query-bench", "--addr", &addr_s, "--requests", "40", "--words", "8",
    ]);
    assert!(ok, "{out}\n{err}");
    let bench_rows: Vec<&str> =
        out.lines().filter(|l| l.starts_with("BENCH_JSON ")).collect();
    assert_eq!(bench_rows.len(), 2, "{out}");
    for (row, mix) in bench_rows.iter().zip(["uniform", "skewed"]) {
        assert!(out.contains(&format!("query-bench {mix}:")), "{out}");
        assert!(row.contains("\"bench\":\"query_bench\""), "{row}");
        assert!(row.contains(&format!("\"mix\":\"{mix}\"")), "{row}");
        assert!(row.contains("\"errors\":0"), "{row}");
    }
    assert!(out.contains("errors 0"), "{out}");

    let mut client = Client::connect(&addr).expect("connect");
    client.shutdown().expect("shutdown");
    let (rest, _) = finish(child, reader);
    assert!(rest.contains("serve: drained |"), "{rest}");
    std::fs::remove_file(&path).ok();
}
