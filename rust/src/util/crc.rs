//! In-tree CRC32 (IEEE 802.3 polynomial, reflected) for spill-block and
//! checkpoint integrity.
//!
//! The offline build environment has no crates.io cache, so the checksum
//! the shard store and the checkpoint manifests need is implemented here:
//! a single 256-entry table, byte-at-a-time. Throughput (~1 GB/s) is far
//! above what the spill path needs — blocks are checksummed once per
//! sweep write-back, against IO that costs more than the scan.

/// Reflected CRC32 polynomial (IEEE 802.3, same as zlib's `crc32`).
const POLY: u32 = 0xEDB8_8320;

/// Byte-indexed remainder table, built at compile time.
const TABLE: [u32; 256] = {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut crc = i as u32;
        let mut bit = 0;
        while bit < 8 {
            crc = if crc & 1 != 0 { (crc >> 1) ^ POLY } else { crc >> 1 };
            bit += 1;
        }
        table[i] = crc;
        i += 1;
    }
    table
};

/// CRC32 of `bytes` (init `0xFFFF_FFFF`, final xor — matches zlib).
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut crc = u32::MAX;
    for &b in bytes {
        crc = (crc >> 8) ^ TABLE[((crc ^ b as u32) & 0xFF) as usize];
    }
    !crc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // Reference values from the zlib `crc32` implementation.
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
    }

    #[test]
    fn single_bit_flip_changes_checksum() {
        let mut data = vec![0u8; 1024];
        data[100] = 0x5A;
        let base = crc32(&data);
        for byte in [0usize, 100, 1023] {
            for bit in 0..8 {
                let mut flipped = data.clone();
                flipped[byte] ^= 1 << bit;
                assert_ne!(crc32(&flipped), base, "flip at byte {byte} bit {bit}");
            }
        }
    }

    #[test]
    fn concatenation_is_order_sensitive() {
        assert_ne!(crc32(b"ab"), crc32(b"ba"));
    }
}
