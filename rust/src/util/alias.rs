//! Walker alias method: O(n) construction, O(1) sampling from a fixed
//! discrete distribution. Used by the synthetic corpus generator (per-topic
//! word distributions over vocabularies of 10^5+) where linear-scan
//! categorical sampling would make corpus generation quadratic, and by
//! the alias sampling kernel ([`crate::kernel::AliasKernel`]) for O(1)
//! stale word-proposal draws.

use crate::util::rng::Rng;

#[derive(Clone, Debug, Default)]
pub struct AliasTable {
    prob: Vec<f64>,
    alias: Vec<u32>,
    /// Construction worklists, kept so [`Self::rebuild`] is
    /// allocation-free once warmed.
    small: Vec<u32>,
    large: Vec<u32>,
}

impl AliasTable {
    /// Build from unnormalized non-negative weights (at least one > 0).
    pub fn new(weights: &[f64]) -> Self {
        let mut t = Self::default();
        t.rebuild(weights);
        t
    }

    /// Rebuild in place from new weights, reusing the `prob`/`alias`
    /// buffers and the construction worklists — long-lived tables
    /// (pooled per-task slots in the alias kernel) refresh without
    /// allocating once warmed.
    pub fn rebuild(&mut self, weights: &[f64]) {
        let n = weights.len();
        assert!(n > 0, "AliasTable over empty support");
        let total: f64 = weights.iter().sum();
        assert!(
            total > 0.0 && total.is_finite(),
            "AliasTable needs positive finite total weight"
        );
        let scale = n as f64 / total;
        self.prob.clear();
        self.prob.extend(weights.iter().map(|w| w * scale));
        self.alias.clear();
        self.alias.resize(n, 0);
        let Self { prob, alias, small, large } = self;
        small.clear();
        large.clear();
        for (i, &p) in prob.iter().enumerate() {
            if p < 1.0 {
                small.push(i as u32);
            } else {
                large.push(i as u32);
            }
        }
        while let (Some(&s), Some(&l)) = (small.last(), large.last()) {
            small.pop();
            alias[s as usize] = l;
            // l donates mass to fill s's bucket to 1.
            prob[l as usize] = (prob[l as usize] + prob[s as usize]) - 1.0;
            if prob[l as usize] < 1.0 {
                large.pop();
                small.push(l);
            }
        }
        // Leftovers are 1.0 up to float error.
        for &i in small.iter().chain(large.iter()) {
            prob[i as usize] = 1.0;
            alias[i as usize] = i;
        }
    }

    #[inline]
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let i = rng.gen_range(self.prob.len());
        if rng.f64() < self.prob[i] {
            i
        } else {
            self.alias[i] as usize
        }
    }

    /// Sample from a single externally-supplied uniform `u ∈ [0, 1)`:
    /// the integer part of `u·n` picks the bucket, the fractional part
    /// serves as the bucket coin. Lets callers that already hold a
    /// uniform (e.g. the alias kernel, which splits one draw across its
    /// proposal mixture) sample without consuming more RNG state.
    /// Values at or above 1.0 (possible from upstream fp rounding)
    /// clamp to the last bucket.
    #[inline]
    pub fn sample_with(&self, u: f64) -> usize {
        let n = self.prob.len();
        let scaled = u * n as f64;
        let i = (scaled as usize).min(n - 1);
        let frac = scaled - i as f64;
        if frac < self.prob[i] {
            i
        } else {
            self.alias[i] as usize
        }
    }

    pub fn len(&self) -> usize {
        self.prob.len()
    }

    pub fn is_empty(&self) -> bool {
        self.prob.is_empty()
    }

    /// The built table as `(prob, alias)` slices — the serialization
    /// view used by the model-snapshot format, which persists tables so
    /// a serving process never pays the O(V·K) rebuild (and so the
    /// on-disk bytes, not a rebuild, define the sampling behaviour).
    pub fn parts(&self) -> (&[f64], &[u32]) {
        (&self.prob, &self.alias)
    }

    /// Reassemble a table from serialized [`Self::parts`]. The pair must
    /// come from a built table: `prob` entries in `[0, 1]` scale and
    /// `alias` entries in-range, which [`crate::serve::snapshot`]
    /// validates before calling.
    pub fn from_parts(prob: Vec<f64>, alias: Vec<u32>) -> Self {
        assert_eq!(prob.len(), alias.len(), "prob/alias length mismatch");
        assert!(!prob.is_empty(), "AliasTable over empty support");
        Self { prob, alias, small: Vec::new(), large: Vec::new() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn empirical(weights: &[f64], draws: usize, seed: u64) -> Vec<f64> {
        let table = AliasTable::new(weights);
        let mut rng = Rng::new(seed);
        let mut counts = vec![0usize; weights.len()];
        for _ in 0..draws {
            counts[table.sample(&mut rng)] += 1;
        }
        counts.iter().map(|&c| c as f64 / draws as f64).collect()
    }

    fn empirical_with(weights: &[f64], draws: usize, seed: u64) -> Vec<f64> {
        let table = AliasTable::new(weights);
        let mut rng = Rng::new(seed);
        let mut counts = vec![0usize; weights.len()];
        for _ in 0..draws {
            counts[table.sample_with(rng.f64())] += 1;
        }
        counts.iter().map(|&c| c as f64 / draws as f64).collect()
    }

    #[test]
    fn matches_target_distribution() {
        let w = [1.0, 2.0, 3.0, 4.0];
        let emp = empirical(&w, 200_000, 42);
        let total: f64 = w.iter().sum();
        for (e, t) in emp.iter().zip(w.iter().map(|x| x / total)) {
            assert!((e - t).abs() < 0.01, "emp={e} target={t}");
        }
    }

    #[test]
    fn sample_with_matches_skewed_distribution() {
        // The single-uniform path must reproduce a strongly skewed
        // target: two decades of dynamic range across eight buckets.
        let w = [100.0, 0.5, 30.0, 1.0, 8.0, 0.1, 55.0, 4.0];
        let total: f64 = w.iter().sum();
        let emp = empirical_with(&w, 400_000, 17);
        for (i, (e, t)) in emp.iter().zip(w.iter().map(|x| x / total)).enumerate() {
            assert!((e - t).abs() < 0.005, "bucket {i}: emp={e} target={t}");
        }
    }

    #[test]
    fn sample_with_clamps_unit_input() {
        let table = AliasTable::new(&[1.0, 2.0]);
        // u == 1.0 (upstream rounding) must not index out of bounds.
        let t = table.sample_with(1.0);
        assert!(t < 2);
    }

    #[test]
    fn rebuild_reuses_table_and_tracks_new_weights() {
        let mut table = AliasTable::new(&[1.0, 1.0, 1.0, 1.0]);
        table.rebuild(&[0.0, 10.0, 0.0, 0.0]);
        assert_eq!(table.len(), 4);
        let mut rng = Rng::new(9);
        for _ in 0..200 {
            assert_eq!(table.sample(&mut rng), 1);
            assert_eq!(table.sample_with(rng.f64()), 1);
        }
    }

    #[test]
    fn zero_weight_never_drawn() {
        let w = [0.0, 1.0, 0.0, 1.0];
        let emp = empirical(&w, 50_000, 7);
        assert_eq!(emp[0], 0.0);
        assert_eq!(emp[2], 0.0);
        let emp = empirical_with(&w, 50_000, 8);
        assert_eq!(emp[0], 0.0);
        assert_eq!(emp[2], 0.0);
    }

    #[test]
    fn singleton_always_zero() {
        let table = AliasTable::new(&[3.5]);
        let mut rng = Rng::new(1);
        for _ in 0..100 {
            assert_eq!(table.sample(&mut rng), 0);
            assert_eq!(table.sample_with(rng.f64()), 0);
        }
    }

    #[test]
    fn heavy_tail_head_dominates() {
        // Zipf-ish: first element should absorb most draws.
        let w: Vec<f64> = (1..=1000).map(|i| 1.0 / (i as f64).powf(1.5)).collect();
        let emp = empirical(&w, 100_000, 3);
        assert!(emp[0] > 0.3, "head mass {}", emp[0]);
    }

    #[test]
    #[should_panic(expected = "positive finite")]
    fn all_zero_panics() {
        AliasTable::new(&[0.0, 0.0]);
    }
}
