//! Deterministic fault injection (failpoints).
//!
//! Compiled-in probes (`fire`) sit at the runtime's fault surfaces — task
//! execution in the executors, block IO in the shard store — and are
//! inert unless the `failpoints` cargo feature is enabled *and* a test
//! has installed a [`Fault`] plan. A fault is addressed by a site name
//! plus a 3-component key (site-specific coordinates, e.g.
//! `(seed, sweep, partition)` for tasks) so a test can schedule "worker
//! panic at sweep 2, partition 5" and nothing else; [`ANY`] wildcards a
//! component. Each fault fires exactly once, in installation order, which
//! is what lets retry paths be tested deterministically: the first
//! attempt hits the fault, the retry finds it already consumed and
//! succeeds.
//!
//! The registry is process-global (the worker pool's long-lived threads
//! preclude thread-locals), so `install` also serializes: the returned
//! [`FaultGuard`] holds a global lock for its lifetime, keeping
//! concurrently running fault tests from consuming each other's plans.
//! Sites key themselves with values that are unique per test anyway
//! (RNG seeds, per-store path tokens), so fault-oblivious tests running
//! in parallel with an armed plan cannot match it by accident.

use std::path::Path;

/// Wildcard key component: matches any value at its position.
pub const ANY: u64 = u64::MAX;

/// The registered probe sites, as constants so tests and probes spell
/// them identically (a typo'd site name would silently never fire).
pub mod sites {
    /// Start of a sampling task, keyed `(seed, sweep, partition)` —
    /// fires before the first token is sampled.
    pub const TASK: &str = "task";
    /// End of a sampling task, keyed `(seed, sweep, partition)` — fires
    /// after the kernel finished but before the task's delta is
    /// committed, modeling a worker that crashes between execution and
    /// commit (the ticketed committer must revoke the ticket; see
    /// `docs/executor.md`).
    pub const COMMIT: &str = "commit";
    /// Spill-block read, keyed `(store path token, partition, ANY)`.
    pub const SHARD_READ: &str = "shard.read";
    /// Spill write-back of a block's `z` payload, keyed by store path
    /// token.
    pub const SHARD_WRITE_Z: &str = "shard.write_z";
    /// Spill write-back of a whole block, keyed by store path token.
    pub const SHARD_WRITE_BLOCK: &str = "shard.write_block";
    /// Model-snapshot read on the serve path, keyed
    /// `(snapshot path token, ANY, ANY)` — fires before the file is
    /// opened, modeling a failing or torn snapshot read.
    pub const SNAPSHOT_READ: &str = "snapshot.read";
    /// One serve request's fold-in execution, keyed
    /// `(snapshot seed, request id, attempt)` — fires before the first
    /// token is sampled, modeling a crashing worker mid-request.
    pub const SERVE_REQUEST: &str = "serve.request";
    /// Snapshot hot-reload on the serve path, keyed
    /// `(candidate path token, ANY, ANY)` — fires before the candidate
    /// is validated, modeling a reload racing a torn publish.
    pub const SERVE_RELOAD: &str = "serve.reload";
    /// Coordinator→worker task send, keyed `(node, sweep, ticket)` —
    /// fires before the frame is written. `TornWrite` sends a truncated
    /// frame then breaks the connection; `IoError` fails the write
    /// outright. Either way the worker connection is lost and the
    /// coordinator must reassign (see `docs/distributed.md`).
    pub const DIST_SEND: &str = "dist.send";
    /// Coordinator-side delta receive, keyed `(node, sweep, ticket)` —
    /// fires when a worker's delta arrives, before it is applied.
    /// Models a corrupt/undecodable frame from that node: the delta is
    /// discarded, the node declared dead, its in-flight work reassigned.
    pub const DIST_RECV: &str = "dist.recv";
    /// Worker-side task execution, keyed `(node, sweep, partition)` —
    /// fires before the kernel runs. `Panic` kills the worker (thread or
    /// process) mid-sweep, modeling a crash; the coordinator sees the
    /// connection drop and replays the task elsewhere.
    pub const DIST_WORKER: &str = "dist.worker";
    /// Worker-side heartbeat answer, keyed `(node, ANY, ANY)` — firing
    /// latches the worker *frozen*: it stops answering pings and stops
    /// taking tasks (but keeps the socket open), modeling a stalled
    /// process the liveness timeout / speculation machinery must detect.
    pub const DIST_HEARTBEAT: &str = "dist.heartbeat";
}

/// What an armed fault does when its site fires.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// Panic at the probe — simulates a crashing worker task.
    Panic,
    /// Return a transient IO error — simulates a failed read/write.
    IoError,
    /// Write only part of the payload, then fail — simulates a torn
    /// write (only meaningful at write probes).
    TornWrite,
}

/// One scheduled fault: fires at `site` when the probe's key matches
/// `key` component-wise (with [`ANY`] wildcards), then is consumed.
#[derive(Clone, Copy, Debug)]
pub struct Fault {
    pub site: &'static str,
    pub key: [u64; 3],
    pub kind: FaultKind,
}

/// Stable token for a filesystem path (FNV-1a over its UTF-8 form) —
/// lets store-scoped fault sites key themselves by *which* store is
/// doing IO, so a fault aimed at one trainer's spill store can never be
/// consumed by another store that happens to reuse a partition id.
pub fn path_token(path: &Path) -> u64 {
    let mut h: u64 = 0xCBF2_9CE4_8422_2325;
    for &b in path.to_string_lossy().as_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

#[cfg(feature = "failpoints")]
mod registry {
    use super::Fault;
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::{Mutex, MutexGuard, PoisonError};

    /// Fast path: probes check this before touching the plan lock.
    pub(super) static ARMED: AtomicBool = AtomicBool::new(false);
    pub(super) static PLAN: Mutex<Vec<Fault>> = Mutex::new(Vec::new());
    /// Serializes fault tests (held by the guard, not just `install`).
    static INSTALL: Mutex<()> = Mutex::new(());

    /// Disarms and clears the plan when the installing test finishes;
    /// holds the global install lock so fault tests run one at a time.
    pub struct FaultGuard {
        _serial: MutexGuard<'static, ()>,
    }

    impl Drop for FaultGuard {
        fn drop(&mut self) {
            ARMED.store(false, Ordering::SeqCst);
            PLAN.lock().unwrap_or_else(PoisonError::into_inner).clear();
        }
    }

    /// Arm `faults`; the plan stays armed until the guard drops.
    pub fn install(faults: Vec<Fault>) -> FaultGuard {
        // A previous fault test that panicked (they do, by design)
        // poisons these mutexes; the state itself is always coherent.
        let serial = INSTALL.lock().unwrap_or_else(PoisonError::into_inner);
        *PLAN.lock().unwrap_or_else(PoisonError::into_inner) = faults;
        ARMED.store(true, Ordering::SeqCst);
        FaultGuard { _serial: serial }
    }
}

#[cfg(feature = "failpoints")]
pub use registry::{install, FaultGuard};

/// Probe: consume and return the first armed fault matching
/// `(site, key)`, if any. Inert (always `None`) without the
/// `failpoints` feature.
#[cfg(feature = "failpoints")]
pub fn fire(site: &str, key: [u64; 3]) -> Option<FaultKind> {
    use std::sync::atomic::Ordering;
    use std::sync::PoisonError;
    if !registry::ARMED.load(Ordering::Relaxed) {
        return None;
    }
    let mut plan = registry::PLAN.lock().unwrap_or_else(PoisonError::into_inner);
    let hit = plan.iter().position(|f| {
        f.site == site
            && f.key.iter().zip(key.iter()).all(|(&p, &k)| p == ANY || p == k)
    })?;
    Some(plan.remove(hit).kind)
}

/// Probe stub: the default build carries no registry and no branches.
#[cfg(not(feature = "failpoints"))]
#[inline(always)]
pub fn fire(_site: &str, _key: [u64; 3]) -> Option<FaultKind> {
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn path_token_distinguishes_paths() {
        let a = path_token(Path::new("/tmp/store-a"));
        let b = path_token(Path::new("/tmp/store-b"));
        assert_ne!(a, b);
        assert_eq!(a, path_token(Path::new("/tmp/store-a")));
    }

    #[cfg(not(feature = "failpoints"))]
    #[test]
    fn stub_probe_never_fires() {
        assert_eq!(fire("task", [1, 2, 3]), None);
    }

    #[cfg(feature = "failpoints")]
    #[test]
    fn faults_match_consume_and_disarm() {
        {
            let _g = install(vec![
                Fault { site: "task", key: [7, 2, ANY], kind: FaultKind::Panic },
                Fault { site: "shard.read", key: [ANY, 5, 0], kind: FaultKind::IoError },
            ]);
            // Wrong site / wrong key: no fire, plan intact.
            assert_eq!(fire("task", [7, 3, 0]), None);
            assert_eq!(fire("shard.read", [9, 6, 0]), None);
            // Wildcard match fires once, then is consumed.
            assert_eq!(fire("task", [7, 2, 99]), Some(FaultKind::Panic));
            assert_eq!(fire("task", [7, 2, 99]), None);
            assert_eq!(fire("shard.read", [123, 5, 0]), Some(FaultKind::IoError));
        }
        // Guard dropped: disarmed even for keys that would have matched.
        assert_eq!(fire("task", [7, 2, 0]), None);
    }

    #[cfg(feature = "failpoints")]
    #[test]
    fn duplicate_faults_fire_in_installation_order() {
        let _g = install(vec![
            Fault { site: "x", key: [ANY; 3], kind: FaultKind::IoError },
            Fault { site: "x", key: [ANY; 3], kind: FaultKind::TornWrite },
        ]);
        assert_eq!(fire("x", [0, 0, 0]), Some(FaultKind::IoError));
        assert_eq!(fire("x", [0, 0, 0]), Some(FaultKind::TornWrite));
        assert_eq!(fire("x", [0, 0, 0]), None);
    }
}
