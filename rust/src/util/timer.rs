//! Wall-clock timing helpers used by the bench harness and trainers.

use std::time::{Duration, Instant};

/// Measure one closure invocation.
pub fn time_once<T>(f: impl FnOnce() -> T) -> (T, Duration) {
    let start = Instant::now();
    let out = f();
    (out, start.elapsed())
}

/// A stopwatch accumulating named phases — the presentation form of the
/// training phase breakdown. Since the obs registry landed, trainers no
/// longer accumulate into this by hand: the canonical accounts live in
/// `obs::Registry` and this type is built as a *view* over them
/// (`Registry::phase_timer` / [`PhaseTimer::from_secs`]). Benches and
/// ad-hoc callers still use it directly as a stopwatch.
#[derive(Debug, Default)]
pub struct PhaseTimer {
    phases: Vec<(String, Duration)>,
}

impl PhaseTimer {
    pub fn new() -> Self {
        Self::default()
    }

    /// Build a timer from `(name, seconds)` pairs, preserving order —
    /// the inverse of [`PhaseTimer::phases_secs`], used to present
    /// registry accounts through the existing report path.
    pub fn from_secs(phases: Vec<(String, f64)>) -> Self {
        Self {
            phases: phases
                .into_iter()
                .map(|(n, s)| (n, Duration::from_secs_f64(s.max(0.0))))
                .collect(),
        }
    }

    pub fn record<T>(&mut self, name: &str, f: impl FnOnce() -> T) -> T {
        let (out, dt) = time_once(f);
        self.add(name, dt);
        out
    }

    pub fn add(&mut self, name: &str, dt: Duration) {
        if let Some((_, total)) = self.phases.iter_mut().find(|(n, _)| n == name) {
            *total += dt;
        } else {
            self.phases.push((name.to_string(), dt));
        }
    }

    pub fn get(&self, name: &str) -> Duration {
        self.phases
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, d)| *d)
            .unwrap_or_default()
    }

    pub fn total(&self) -> Duration {
        self.phases.iter().map(|(_, d)| *d).sum()
    }

    /// The recorded phases as `(name, seconds)` in insertion order —
    /// the serializable form the train reports embed.
    pub fn phases_secs(&self) -> Vec<(String, f64)> {
        self.phases
            .iter()
            .map(|(n, d)| (n.clone(), d.as_secs_f64()))
            .collect()
    }

    pub fn report(&self) -> String {
        let total = self.total().as_secs_f64().max(1e-12);
        self.phases
            .iter()
            .map(|(n, d)| {
                format!(
                    "{n}: {:.3}s ({:.1}%)",
                    d.as_secs_f64(),
                    100.0 * d.as_secs_f64() / total
                )
            })
            .collect::<Vec<_>>()
            .join(", ")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_once_returns_value() {
        let (v, dt) = time_once(|| 21 * 2);
        assert_eq!(v, 42);
        assert!(dt.as_nanos() > 0);
    }

    #[test]
    fn phase_timer_accumulates() {
        let mut t = PhaseTimer::new();
        t.add("sample", Duration::from_millis(10));
        t.add("sample", Duration::from_millis(5));
        t.add("barrier", Duration::from_millis(1));
        assert_eq!(t.get("sample"), Duration::from_millis(15));
        assert_eq!(t.total(), Duration::from_millis(16));
        assert!(t.report().contains("sample"));
    }

    #[test]
    fn from_secs_inverts_phases_secs() {
        let mut t = PhaseTimer::new();
        t.add("sample", Duration::from_millis(20));
        t.add("barrier", Duration::from_millis(5));
        let view = PhaseTimer::from_secs(t.phases_secs());
        assert_eq!(view.phases_secs(), t.phases_secs());
    }

    #[test]
    fn missing_phase_is_zero() {
        let t = PhaseTimer::new();
        assert_eq!(t.get("nope"), Duration::ZERO);
    }

    #[test]
    fn phases_secs_preserves_insertion_order() {
        let mut t = PhaseTimer::new();
        t.add("sample", Duration::from_millis(20));
        t.add("barrier", Duration::from_millis(5));
        t.add("sample", Duration::from_millis(10));
        let ph = t.phases_secs();
        assert_eq!(ph.len(), 2);
        assert_eq!(ph[0].0, "sample");
        assert!((ph[0].1 - 0.030).abs() < 1e-9);
        assert_eq!(ph[1].0, "barrier");
    }
}
