//! Tiny JSON value + writer — enough to emit structured experiment
//! reports (no serde facade crate in the offline cache).

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Self {
        Json::Obj(BTreeMap::new())
    }

    /// Insert into an object; panics if `self` is not an object.
    pub fn set(&mut self, key: &str, value: impl Into<Json>) -> &mut Self {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), value.into());
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    // An inherent `to_string` (rather than a `Display` impl) is
    // deliberate: compact JSON is an encoding, not a display format.
    #[allow(clippy::inherent_to_string)]
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, false);
        s
    }

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, true);
        s
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.is_finite() {
                    if n.fract() == 0.0 && n.abs() < 1e15 {
                        let _ = write!(out, "{}", *n as i64);
                    } else {
                        let _ = write!(out, "{n}");
                    }
                } else {
                    out.push_str("null"); // JSON has no NaN/Inf
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        out.push_str(&"  ".repeat(indent + 1));
                    }
                    item.write(out, indent + 1, pretty);
                }
                if pretty && !items.is_empty() {
                    out.push('\n');
                    out.push_str(&"  ".repeat(indent));
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        out.push_str(&"  ".repeat(indent + 1));
                    }
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                if pretty && !map.is_empty() {
                    out.push('\n');
                    out.push_str(&"  ".repeat(indent));
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Self {
        Json::Num(n)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Self {
        Json::Num(n as f64)
    }
}
impl From<u64> for Json {
    fn from(n: u64) -> Self {
        Json::Num(n as f64)
    }
}
impl From<i64> for Json {
    fn from(n: i64) -> Self {
        Json::Num(n as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Self {
        Json::Str(s)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Self {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic_object() {
        let mut j = Json::obj();
        j.set("eta", 0.95).set("procs", 10usize).set("algo", "A3");
        assert_eq!(j.to_string(), r#"{"algo":"A3","eta":0.95,"procs":10}"#);
    }

    #[test]
    fn arrays_and_nesting() {
        let mut inner = Json::obj();
        inner.set("p", 10usize);
        let j = Json::Arr(vec![inner, Json::Num(1.5)]);
        assert_eq!(j.to_string(), r#"[{"p":10},1.5]"#);
    }

    #[test]
    fn escapes_strings() {
        let j = Json::Str("a\"b\\c\nd".to_string());
        assert_eq!(j.to_string(), r#""a\"b\\c\nd""#);
    }

    #[test]
    fn integral_floats_render_as_ints() {
        assert_eq!(Json::Num(30.0).to_string(), "30");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }

    #[test]
    fn nan_renders_null() {
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
    }

    #[test]
    fn pretty_prints() {
        let mut j = Json::obj();
        j.set("a", 1usize);
        assert_eq!(j.to_string_pretty(), "{\n  \"a\": 1\n}");
    }
}
