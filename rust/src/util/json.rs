//! Tiny JSON value + writer + parser — enough to emit structured
//! experiment reports and read traces back (no serde facade crate in
//! the offline cache).

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Self {
        Json::Obj(BTreeMap::new())
    }

    /// Insert into an object; panics if `self` is not an object.
    pub fn set(&mut self, key: &str, value: impl Into<Json>) -> &mut Self {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), value.into());
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    /// Parse a JSON document (recursive descent). Errors carry a byte
    /// offset. Numbers parse as `f64` — integers above 2^53 lose
    /// precision, which is fine for the nanosecond timestamps and
    /// counters we read back.
    pub fn parse(s: &str) -> Result<Json, String> {
        let b = s.as_bytes();
        let mut p = Parser { b, pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != b.len() {
            return Err(format!("trailing data at byte {}", p.pos));
        }
        Ok(v)
    }

    /// Accessors for parsed documents: `None` on type mismatch.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as u64),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Object field lookup; `None` for non-objects or missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }

    // An inherent `to_string` (rather than a `Display` impl) is
    // deliberate: compact JSON is an encoding, not a display format.
    #[allow(clippy::inherent_to_string)]
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, false);
        s
    }

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, true);
        s
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.is_finite() {
                    if n.fract() == 0.0 && n.abs() < 1e15 {
                        let _ = write!(out, "{}", *n as i64);
                    } else {
                        let _ = write!(out, "{n}");
                    }
                } else {
                    out.push_str("null"); // JSON has no NaN/Inf
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        out.push_str(&"  ".repeat(indent + 1));
                    }
                    item.write(out, indent + 1, pretty);
                }
                if pretty && !items.is_empty() {
                    out.push('\n');
                    out.push_str(&"  ".repeat(indent));
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        out.push_str(&"  ".repeat(indent + 1));
                    }
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                if pretty && !map.is_empty() {
                    out.push('\n');
                    out.push_str(&"  ".repeat(indent));
                }
                out.push('}');
            }
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl Parser<'_> {
    fn skip_ws(&mut self) {
        while let Some(&c) = self.b.get(self.pos) {
            if matches!(c, b' ' | b'\t' | b'\n' | b'\r') {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.pos))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(format!("invalid literal at byte {}", self.pos))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected input at byte {}", self.pos)),
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.pos)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            map.insert(key, self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.pos)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".to_string()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let esc = self.peek().ok_or("unterminated escape")?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            let hex = self
                                .b
                                .get(self.pos..self.pos + 4)
                                .ok_or("truncated \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|e| e.to_string())?,
                                16,
                            )
                            .map_err(|e| e.to_string())?;
                            self.pos += 4;
                            // Surrogate pairs are not emitted by our
                            // writer; map lone surrogates to U+FFFD.
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(format!("bad escape at byte {}", self.pos)),
                    }
                }
                Some(_) => {
                    // Consume one UTF-8 scalar (input is valid UTF-8 —
                    // it came from a &str).
                    let rest = &self.b[self.pos..];
                    let s = unsafe { std::str::from_utf8_unchecked(rest) };
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).map_err(|e| e.to_string())?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| format!("bad number at byte {start}"))
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Self {
        Json::Num(n)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Self {
        Json::Num(n as f64)
    }
}
impl From<u64> for Json {
    fn from(n: u64) -> Self {
        Json::Num(n as f64)
    }
}
impl From<i64> for Json {
    fn from(n: i64) -> Self {
        Json::Num(n as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Self {
        Json::Str(s)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Self {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic_object() {
        let mut j = Json::obj();
        j.set("eta", 0.95).set("procs", 10usize).set("algo", "A3");
        assert_eq!(j.to_string(), r#"{"algo":"A3","eta":0.95,"procs":10}"#);
    }

    #[test]
    fn arrays_and_nesting() {
        let mut inner = Json::obj();
        inner.set("p", 10usize);
        let j = Json::Arr(vec![inner, Json::Num(1.5)]);
        assert_eq!(j.to_string(), r#"[{"p":10},1.5]"#);
    }

    #[test]
    fn escapes_strings() {
        let j = Json::Str("a\"b\\c\nd".to_string());
        assert_eq!(j.to_string(), r#""a\"b\\c\nd""#);
    }

    #[test]
    fn integral_floats_render_as_ints() {
        assert_eq!(Json::Num(30.0).to_string(), "30");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }

    #[test]
    fn nan_renders_null() {
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
    }

    #[test]
    fn pretty_prints() {
        let mut j = Json::obj();
        j.set("a", 1usize);
        assert_eq!(j.to_string_pretty(), "{\n  \"a\": 1\n}");
    }

    #[test]
    fn parse_round_trips_writer_output() {
        let mut inner = Json::obj();
        inner.set("eta", 0.95).set("n", 12345usize).set("s", "a\"b\\c\nd");
        let doc = Json::Arr(vec![
            inner,
            Json::Null,
            Json::Bool(true),
            Json::Num(-1.5e3),
            Json::Arr(vec![]),
            Json::obj(),
        ]);
        for text in [doc.to_string(), doc.to_string_pretty()] {
            let back = Json::parse(&text).unwrap();
            assert_eq!(back, doc);
        }
    }

    #[test]
    fn parse_accessors() {
        let j = Json::parse(r#"{"a":[1,2],"b":"x","c":3.5,"n":7}"#).unwrap();
        assert_eq!(j.get("n").and_then(Json::as_u64), Some(7));
        assert_eq!(j.get("c").and_then(Json::as_f64), Some(3.5));
        assert_eq!(j.get("c").and_then(Json::as_u64), None, "non-integer");
        assert_eq!(j.get("b").and_then(Json::as_str), Some("x"));
        assert_eq!(j.get("a").and_then(Json::as_arr).map(|a| a.len()), Some(2));
        assert!(j.get("zzz").is_none());
    }

    #[test]
    fn parse_rejects_malformed() {
        for bad in ["", "{", "[1,", "{\"a\":}", "tru", "1 2", "\"abc", "{\"a\" 1}"] {
            assert!(Json::parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn parse_unicode_and_escapes() {
        let j = Json::parse(r#""Aµ\t""#).unwrap();
        assert_eq!(j, Json::Str("Aµ\t".to_string()));
    }
}
