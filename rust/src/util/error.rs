//! Minimal error substrate with an `anyhow`-compatible surface.
//!
//! The offline build environment has no crates.io cache, so the fallible
//! edges of the system (corpus loaders, the optional PJRT runtime) use
//! this in-tree shim instead of `anyhow`: a string-backed [`Error`], a
//! [`Result`] alias with a defaulted error type, a [`Context`] extension
//! trait (`.context(..)` / `.with_context(|| ..)` on `Result` and
//! `Option`), and a [`bail!`] macro. Swapping back to `anyhow` would be a
//! one-line import change at each use site.

use std::fmt;

/// A boxed-string error: message-only, context accreted by prefixing.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg(m: impl fmt::Display) -> Self {
        Self { msg: m.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `expect`/`unwrap` print Debug; show the human-readable chain.
        f.write_str(&self.msg)
    }
}

// Like `anyhow::Error`, this type deliberately does NOT implement
// `std::error::Error` — that keeps the blanket `?`-conversion below
// coherent (no overlap with `impl From<T> for T`).
impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Self {
        Self::msg(e)
    }
}

/// `Result` with the error type defaulted to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to failures, `anyhow`-style.
pub trait Context<T> {
    /// Wrap the error (or `None`) with a fixed message.
    fn context(self, msg: impl fmt::Display) -> Result<T>;
    /// Wrap the error (or `None`) with a lazily-built message.
    fn with_context<D: fmt::Display>(self, f: impl FnOnce() -> D) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context(self, msg: impl fmt::Display) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{msg}: {e}")))
    }

    fn with_context<D: fmt::Display>(self, f: impl FnOnce() -> D) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context(self, msg: impl fmt::Display) -> Result<T> {
        self.ok_or_else(|| Error::msg(msg))
    }

    fn with_context<D: fmt::Display>(self, f: impl FnOnce() -> D) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Early-return with a formatted [`Error`].
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::util::error::Error::msg(format!($($arg)*)))
    };
}
pub(crate) use bail;

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Result<u32> {
        let n: u32 = s.parse().with_context(|| format!("bad number {s:?}"))?;
        if n > 100 {
            bail!("{n} out of range");
        }
        Ok(n)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        assert_eq!(parse("42").unwrap(), 42);
        let e = parse("x").unwrap_err();
        assert!(e.to_string().starts_with("bad number \"x\":"), "{e}");
    }

    #[test]
    fn bail_formats() {
        let e = parse("999").unwrap_err();
        assert_eq!(e.to_string(), "999 out of range");
    }

    #[test]
    fn context_on_option_and_result() {
        let none: Option<u8> = None;
        assert_eq!(none.context("missing").unwrap_err().to_string(), "missing");
        let io: std::io::Result<u8> =
            Err(std::io::Error::new(std::io::ErrorKind::NotFound, "gone"));
        let e = io.context("open file").unwrap_err();
        assert!(e.to_string().starts_with("open file:"), "{e}");
    }

    #[test]
    fn io_error_converts_via_question_mark() {
        fn open() -> Result<String> {
            Ok(std::fs::read_to_string("/definitely/not/a/real/path/pplda")?)
        }
        assert!(open().is_err());
    }
}
