//! Cooperative SIGINT/SIGTERM handling.
//!
//! The trainers' sweep loops and the serve accept loop poll
//! [`requested`] at safe points (end of sweep, between accepts) and wind
//! down cleanly — finish the unit of work in flight, write a final
//! checkpoint or drain the queue, exit 0 — instead of dying mid-write.
//! [`install`] registers the process-wide handler; it only sets a flag,
//! so everything observable happens on the polling thread.
//!
//! Two latches feed [`requested`]:
//!
//! - a process-global `AtomicBool` set by the real signal handler (a
//!   signal can land on any thread, so this must be global), and
//! - a thread-local test latch set by [`trigger`], so tests can simulate
//!   Ctrl-C without a global flag bleeding into *other* tests' trainer
//!   loops running concurrently — the sweep loop under test runs on the
//!   test's own thread, which is exactly the thread-local's scope.
//!
//! Note the glibc `signal(2)` binding gives BSD semantics (`SA_RESTART`):
//! blocking syscalls resume after the handler runs, so loops must poll —
//! the serve listener runs nonblocking with a sleep for this reason.

use std::cell::Cell;
use std::sync::atomic::{AtomicBool, Ordering};

/// Set (only) by the installed signal handler.
static SIGNALED: AtomicBool = AtomicBool::new(false);

thread_local! {
    /// Test-only latch, scoped to the triggering thread.
    static TEST_LATCH: Cell<bool> = const { Cell::new(false) };
}

#[cfg(unix)]
extern "C" {
    /// Hand-declared to avoid a libc dependency; `usize` for the handler
    /// slot covers both `SIG_DFL`-style constants and function pointers.
    fn signal(signum: i32, handler: usize) -> usize;
}

#[cfg(unix)]
const SIGINT: i32 = 2;
/// Container orchestrators (Kubernetes, docker stop, systemd) signal
/// shutdown with SIGTERM, not Ctrl-C — it must reach the same graceful
/// checkpoint-and-exit / serve-drain path.
#[cfg(unix)]
const SIGTERM: i32 = 15;

#[cfg(unix)]
extern "C" fn on_signal(_signum: i32) {
    // Async-signal-safe: a single atomic store, nothing else.
    SIGNALED.store(true, Ordering::SeqCst);
}

/// Install the SIGINT and SIGTERM handlers. Idempotent; call once at
/// process start for any subcommand that wants graceful wind-down.
pub fn install() {
    #[cfg(unix)]
    unsafe {
        signal(SIGINT, on_signal as extern "C" fn(i32) as usize);
        signal(SIGTERM, on_signal as extern "C" fn(i32) as usize);
    }
}

/// Has an interrupt been requested (real SIGINT/SIGTERM on any thread,
/// or a [`trigger`] on this thread)?
pub fn requested() -> bool {
    SIGNALED.load(Ordering::Relaxed) || TEST_LATCH.with(Cell::get)
}

/// Test hook: simulate Ctrl-C for code running on *this* thread.
pub fn trigger() {
    TEST_LATCH.with(|l| l.set(true));
}

/// Clear both latches (test teardown, or after a handled interrupt).
pub fn reset() {
    SIGNALED.store(false, Ordering::SeqCst);
    TEST_LATCH.with(|l| l.set(false));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trigger_is_thread_local() {
        reset();
        assert!(!requested());
        trigger();
        assert!(requested());
        // Another thread must not observe this thread's test latch.
        let seen = std::thread::spawn(requested).join().unwrap();
        assert!(!seen);
        reset();
        assert!(!requested());
    }

    #[test]
    fn install_is_idempotent() {
        install();
        install();
        assert!(!TEST_LATCH.with(Cell::get));
    }

    /// Pin that [`install`] latches SIGTERM (and still SIGINT) through
    /// the same handler. `signal(2)` returns the previously registered
    /// handler, so re-registering and inspecting the return value
    /// verifies registration without raising a real signal (which would
    /// race the global latch against unrelated concurrent tests).
    #[test]
    #[cfg(unix)]
    fn sigterm_and_sigint_share_the_graceful_handler() {
        install();
        let ours = on_signal as extern "C" fn(i32) as usize;
        unsafe {
            assert_eq!(signal(SIGTERM, ours), ours, "SIGTERM handler installed");
            assert_eq!(signal(SIGINT, ours), ours, "SIGINT handler installed");
        }
    }
}
