//! Descriptive statistics over f64 samples — used by the bench harness
//! (ns/op distributions) and by corpus/partition diagnostics.

/// Summary of a sample: count, mean, stddev, min/median/p95/max.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub stddev: f64,
    pub min: f64,
    pub median: f64,
    pub p95: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(samples: &[f64]) -> Self {
        assert!(!samples.is_empty(), "Summary::of on empty sample");
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            samples.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Self {
            n,
            mean,
            stddev: var.sqrt(),
            min: sorted[0],
            median: percentile_sorted(&sorted, 50.0),
            p95: percentile_sorted(&sorted, 95.0),
            max: sorted[n - 1],
        }
    }
}

/// Linear-interpolated percentile of an ascending-sorted slice.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=100.0).contains(&p));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Gini coefficient of a non-negative workload vector — a compact skewness
/// measure we report for row/column workload distributions (the thing that
/// makes balancing hard).
pub fn gini(values: &[f64]) -> f64 {
    assert!(!values.is_empty());
    let mut sorted = values.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = sorted.len() as f64;
    let sum: f64 = sorted.iter().sum();
    if sum == 0.0 {
        return 0.0;
    }
    let weighted: f64 = sorted
        .iter()
        .enumerate()
        .map(|(i, v)| (i as f64 + 1.0) * v)
        .sum();
    (2.0 * weighted) / (n * sum) - (n + 1.0) / n
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_constant() {
        let s = Summary::of(&[5.0; 10]);
        assert_eq!(s.mean, 5.0);
        assert_eq!(s.stddev, 0.0);
        assert_eq!(s.min, 5.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.median, 5.0);
    }

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.mean, 3.0);
        assert_eq!(s.median, 3.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.stddev - 1.5811388).abs() < 1e-6);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert_eq!(percentile_sorted(&xs, 0.0), 0.0);
        assert_eq!(percentile_sorted(&xs, 50.0), 5.0);
        assert_eq!(percentile_sorted(&xs, 100.0), 10.0);
    }

    #[test]
    fn gini_uniform_is_zero() {
        assert!(gini(&[3.0; 50]).abs() < 1e-12);
    }

    #[test]
    fn gini_concentrated_near_one() {
        let mut v = vec![0.0; 1000];
        v[0] = 1e6;
        assert!(gini(&v) > 0.99);
    }

    #[test]
    fn gini_increases_with_skew() {
        let mild: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let steep: Vec<f64> = (1..=100).map(|i| (i as f64).powi(3)).collect();
        assert!(gini(&steep) > gini(&mild));
    }
}
