//! In-tree utility substrates.
//!
//! The build environment is offline with a fixed crate cache, so the
//! pieces a project would normally pull from crates.io (PRNG, CLI parser,
//! descriptive statistics, JSON/TSV emitters, wall-clock timing helpers)
//! are implemented here from scratch.

pub mod alias;
pub mod cli;
pub mod crc;
pub mod error;
pub mod fault;
pub mod interrupt;
pub mod json;
pub mod net;
pub mod rng;
pub mod stats;
pub mod timer;
pub mod tsv;

/// Integer ceiling division.
#[inline]
pub fn div_ceil(a: usize, b: usize) -> usize {
    debug_assert!(b > 0);
    a.div_ceil(b)
}

/// Format a token/second style rate with SI-ish suffixes.
pub fn human_rate(per_sec: f64) -> String {
    if per_sec >= 1e9 {
        format!("{:.2}G", per_sec / 1e9)
    } else if per_sec >= 1e6 {
        format!("{:.2}M", per_sec / 1e6)
    } else if per_sec >= 1e3 {
        format!("{:.2}k", per_sec / 1e3)
    } else {
        format!("{per_sec:.1}")
    }
}

/// Format a byte count.
pub fn human_bytes(bytes: usize) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = bytes as f64;
    let mut u = 0;
    while v >= 1024.0 && u + 1 < UNITS.len() {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{bytes}B")
    } else {
        format!("{v:.2}{}", UNITS[u])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn div_ceil_rounds_up() {
        assert_eq!(div_ceil(10, 3), 4);
        assert_eq!(div_ceil(9, 3), 3);
        assert_eq!(div_ceil(1, 128), 1);
        assert_eq!(div_ceil(0, 4), 0);
    }

    #[test]
    fn human_rate_suffixes() {
        assert_eq!(human_rate(1.5e9), "1.50G");
        assert_eq!(human_rate(2.5e6), "2.50M");
        assert_eq!(human_rate(3.0e3), "3.00k");
        assert_eq!(human_rate(12.0), "12.0");
    }

    #[test]
    fn human_bytes_units() {
        assert_eq!(human_bytes(512), "512B");
        assert_eq!(human_bytes(2048), "2.00KiB");
        assert_eq!(human_bytes(3 * 1024 * 1024), "3.00MiB");
    }
}
