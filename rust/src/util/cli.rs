//! Minimal command-line parsing: `--key value` flags, `--switch` booleans,
//! and positional arguments, with typed accessors and defaults.
//!
//! In-tree replacement for `clap` (unavailable offline). Used by the
//! `pplda` binary, the examples and the bench harness.

use std::collections::BTreeMap;
use std::str::FromStr;

#[derive(Clone, Debug, Default)]
pub struct Args {
    positional: Vec<String>,
    flags: BTreeMap<String, String>,
    switches: Vec<String>,
}

impl Args {
    /// Parse `std::env::args()` (skipping argv[0]).
    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    /// Parse an explicit iterator — used by tests and the bench harness.
    pub fn parse<I, S>(args: I) -> Self
    where
        I: IntoIterator<Item = S>,
        S: Into<String>,
    {
        let mut out = Args::default();
        let mut it = args.into_iter().map(Into::into).peekable();
        while let Some(arg) = it.next() {
            if let Some(name) = arg.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.flags.insert(name.to_string(), v);
                } else {
                    out.switches.push(name.to_string());
                }
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    pub fn positional(&self, idx: usize) -> Option<&str> {
        self.positional.get(idx).map(String::as_str)
    }

    /// Typed flag lookup; returns `default` when absent. Panics with a
    /// clear message on unparseable values (CLI misuse, not a bug).
    pub fn get<T: FromStr>(&self, key: &str, default: T) -> T
    where
        T::Err: std::fmt::Debug,
    {
        match self.flags.get(key) {
            Some(v) => v
                .parse()
                .unwrap_or_else(|e| panic!("--{key}={v}: bad value ({e:?})")),
            None => default,
        }
    }

    pub fn get_str(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(String::as_str)
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }

    /// Comma-separated list flag, e.g. `--procs 1,10,30,60`.
    pub fn get_list<T: FromStr>(&self, key: &str, default: &[T]) -> Vec<T>
    where
        T: Clone,
        T::Err: std::fmt::Debug,
    {
        match self.flags.get(key) {
            Some(v) => v
                .split(',')
                .map(|x| {
                    x.trim()
                        .parse()
                        .unwrap_or_else(|e| panic!("--{key}: bad item {x:?} ({e:?})"))
                })
                .collect(),
            None => default.to_vec(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_flags_switches_positional() {
        let a = Args::parse(["train", "--procs", "10", "--xla", "--seed=42"]);
        assert_eq!(a.positional(0), Some("train"));
        assert_eq!(a.get::<usize>("procs", 1), 10);
        assert_eq!(a.get::<u64>("seed", 0), 42);
        assert!(a.has("xla"));
        assert!(!a.has("verbose"));
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse(Vec::<String>::new());
        assert_eq!(a.get::<usize>("procs", 8), 8);
        assert_eq!(a.positional(0), None);
    }

    #[test]
    fn list_flag() {
        let a = Args::parse(["--procs", "1,10,30,60"]);
        assert_eq!(a.get_list::<usize>("procs", &[]), vec![1, 10, 30, 60]);
        assert_eq!(a.get_list::<usize>("missing", &[5]), vec![5]);
    }

    #[test]
    fn negative_numbers_as_values() {
        let a = Args::parse(["--offset", "-3"]);
        assert_eq!(a.get::<i64>("offset", 0), -3);
    }

    #[test]
    #[should_panic(expected = "bad value")]
    fn bad_value_panics() {
        let a = Args::parse(["--procs", "ten"]);
        let _ = a.get::<usize>("procs", 1);
    }
}
