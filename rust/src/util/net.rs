//! Shared JSON-lines TCP framing.
//!
//! Two subsystems speak newline-delimited JSON over TCP — the serve
//! path's query protocol ([`crate::serve::net`]) and the distributed
//! control plane ([`crate::dist`]) — so the line primitives live here
//! once: connect with `TCP_NODELAY` (messages are line-sized; Nagle only
//! adds latency), write one object per `\n`-terminated line, read one
//! trimmed line with clean-EOF detection, and classify read-timeout
//! errors (both protocols poll with socket read timeouts so shutdown
//! latches stay responsive).
//!
//! The distributed *data* plane (task and delta payloads) is binary and
//! CRC-framed — see [`crate::dist::wire`] — but shares the same stream:
//! a JSON control line always starts with `{`, a binary frame with its
//! magic byte, so a reader can sniff the first byte and parse either.

use std::io::{self, BufRead, Write};
use std::net::{SocketAddr, TcpStream};

/// Connect with `TCP_NODELAY` set.
pub fn connect(addr: &SocketAddr) -> io::Result<TcpStream> {
    let stream = TcpStream::connect(addr)?;
    stream.set_nodelay(true)?;
    Ok(stream)
}

/// Serialize `msg` and send it as one `\n`-terminated line.
pub fn send_line<W: Write>(w: &mut W, msg: &crate::util::json::Json) -> io::Result<()> {
    let mut text = msg.to_string();
    text.push('\n');
    w.write_all(text.as_bytes())?;
    w.flush()
}

/// Read one line into `buf` (cleared first), stripping the trailing
/// newline. `Ok(false)` means clean EOF.
pub fn recv_line<R: BufRead>(r: &mut R, buf: &mut String) -> io::Result<bool> {
    buf.clear();
    if r.read_line(buf)? == 0 {
        return Ok(false);
    }
    while buf.ends_with('\n') || buf.ends_with('\r') {
        buf.pop();
    }
    Ok(true)
}

/// True when `e` is a socket read-timeout (the poll tick of a loop with
/// a read timeout set), not a real failure. Both `WouldBlock` and
/// `TimedOut` appear in the wild depending on platform.
pub fn is_timeout(e: &io::Error) -> bool {
    matches!(e.kind(), io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;
    use std::io::BufReader;
    use std::net::TcpListener;

    #[test]
    fn line_round_trip_over_tcp() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let mut writer = stream.try_clone().unwrap();
            let mut reader = BufReader::new(stream);
            let mut line = String::new();
            assert!(recv_line(&mut reader, &mut line).unwrap());
            let req = Json::parse(&line).unwrap();
            let mut reply = Json::obj();
            reply.set("echo", req.get("x").and_then(Json::as_u64).unwrap());
            send_line(&mut writer, &reply).unwrap();
            // Client hangs up: clean EOF, not an error.
            assert!(!recv_line(&mut reader, &mut line).unwrap());
        });
        let stream = connect(&addr).unwrap();
        let mut writer = stream.try_clone().unwrap();
        let mut reader = BufReader::new(stream);
        let mut msg = Json::obj();
        msg.set("x", 7u64);
        send_line(&mut writer, &msg).unwrap();
        let mut line = String::new();
        assert!(recv_line(&mut reader, &mut line).unwrap());
        let reply = Json::parse(&line).unwrap();
        assert_eq!(reply.get("echo").and_then(Json::as_u64), Some(7));
        drop(writer);
        drop(reader);
        server.join().unwrap();
    }

    #[test]
    fn timeout_classification() {
        let to = io::Error::new(io::ErrorKind::WouldBlock, "t");
        assert!(is_timeout(&to));
        let to = io::Error::new(io::ErrorKind::TimedOut, "t");
        assert!(is_timeout(&to));
        let real = io::Error::new(io::ErrorKind::ConnectionReset, "r");
        assert!(!is_timeout(&real));
    }
}
