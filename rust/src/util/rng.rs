//! Deterministic pseudo-random number generation.
//!
//! `SplitMix64` expands a seed into stream states; `Xoshiro256++`
//! (Blackman & Vigna) is the workhorse generator. Both are implemented
//! from the reference C sources. Determinism matters doubly here: the
//! paper's A3/baseline partitioners are restart-and-keep-best randomized
//! searches (reproducible experiments need fixed streams), and the XLA
//! sampler path feeds *coordinator-generated* uniforms into the
//! Gumbel-max kernel so native and offloaded backends can be compared
//! draw-for-draw.

/// SplitMix64: used to seed Xoshiro and to derive independent substreams.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// Xoshiro256++ — fast, high-quality, 256-bit state.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 per the xoshiro authors' recommendation.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let mut s = [0u64; 4];
        for v in &mut s {
            *v = sm.next_u64();
        }
        // All-zero state is invalid (fixed point); seed 0 via splitmix is
        // fine, but guard anyway.
        if s == [0, 0, 0, 0] {
            s[0] = 0x9E3779B97F4A7C15;
        }
        Self { s }
    }

    /// Derive an independent substream (e.g. one per worker thread or per
    /// restart of a randomized partitioner).
    pub fn stream(seed: u64, stream_id: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let base = sm.next_u64();
        Self::new(base ^ stream_id.wrapping_mul(0xD2B74407B1CE6E93))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, bound)` via Lemire's multiply-shift with rejection
    /// (unbiased).
    #[inline]
    pub fn gen_range(&mut self, bound: usize) -> usize {
        debug_assert!(bound > 0);
        let bound = bound as u64;
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(bound as u128);
            let lo = m as u64;
            if lo >= bound {
                return (m >> 64) as usize;
            }
            // Rejection zone: recompute threshold once.
            let t = bound.wrapping_neg() % bound;
            if lo >= t {
                return (m >> 64) as usize;
            }
        }
    }

    /// Uniform f64 in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `(0, 1)` — open at both ends, safe for `log`.
    #[inline]
    pub fn f32_open(&mut self) -> f32 {
        let v = ((self.next_u64() >> 40) as f32 + 0.5) * (1.0 / (1u64 << 24) as f32);
        v
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i + 1);
            xs.swap(i, j);
        }
    }

    /// Standard normal via Box–Muller (one value per call; simple and
    /// adequate for corpus generation, which is off the hot path).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 <= f64::EPSILON {
                continue;
            }
            let u2 = self.f64();
            return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
        }
    }

    /// Gamma(shape, 1) via Marsaglia–Tsang, with the shape<1 boost.
    pub fn gamma(&mut self, shape: f64) -> f64 {
        debug_assert!(shape > 0.0);
        if shape < 1.0 {
            // Boost: Gamma(a) = Gamma(a+1) * U^{1/a}
            let u = loop {
                let u = self.f64();
                if u > 0.0 {
                    break u;
                }
            };
            return self.gamma(shape + 1.0) * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.f64();
            if u < 1.0 - 0.0331 * x.powi(4) {
                return d * v;
            }
            if u > 0.0 && u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln()) {
                return d * v;
            }
        }
    }

    /// Symmetric Dirichlet(alpha) of dimension `k`, written into `out`.
    pub fn dirichlet_sym(&mut self, alpha: f64, out: &mut [f64]) {
        let mut sum = 0.0;
        for v in out.iter_mut() {
            let g = self.gamma(alpha);
            *v = g;
            sum += g;
        }
        if sum <= 0.0 {
            // Degenerate draw (all gammas underflowed): fall back to uniform.
            let u = 1.0 / out.len() as f64;
            out.iter_mut().for_each(|v| *v = u);
            return;
        }
        out.iter_mut().for_each(|v| *v /= sum);
    }

    /// Draw from a discrete distribution given *unnormalized* weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0);
        let mut r = self.f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            r -= w;
            if r <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..32).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn streams_are_independent() {
        let mut a = Rng::stream(9, 0);
        let mut b = Rng::stream(9, 1);
        let same = (0..32).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn gen_range_in_bounds_and_covers() {
        let mut r = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.gen_range(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_unit_interval_mean() {
        let mut r = Rng::new(3);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn f32_open_never_zero_or_one() {
        let mut r = Rng::new(5);
        for _ in 0..100_000 {
            let v = r.f32_open();
            assert!(v > 0.0 && v < 1.0);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(11);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn gamma_moments() {
        let mut r = Rng::new(13);
        let shape = 2.5;
        let n = 50_000;
        let samples: Vec<f64> = (0..n).map(|_| r.gamma(shape)).collect();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!((mean - shape).abs() < 0.05, "mean={mean}");
        assert!((var - shape).abs() < 0.15, "var={var}");
    }

    #[test]
    fn gamma_small_shape_positive() {
        let mut r = Rng::new(17);
        for _ in 0..10_000 {
            assert!(r.gamma(0.1) >= 0.0);
        }
    }

    #[test]
    fn dirichlet_sums_to_one() {
        let mut r = Rng::new(19);
        let mut out = vec![0.0; 16];
        for _ in 0..100 {
            r.dirichlet_sym(0.5, &mut out);
            let s: f64 = out.iter().sum();
            assert!((s - 1.0).abs() < 1e-9);
            assert!(out.iter().all(|&v| v >= 0.0));
        }
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(23);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[r.categorical(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.3, "ratio={ratio}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(29);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }
}
