//! TSV table builder + aligned console rendering — the output format of
//! every bench (one table per paper table/figure) and of EXPERIMENTS.md
//! data dumps.

use std::fmt::Write as _;
use std::io;
use std::path::Path;

#[derive(Clone, Debug, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>>(header: impl IntoIterator<Item = S>) -> Self {
        Self {
            header: header.into_iter().map(Into::into).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row<S: Into<String>>(&mut self, cells: impl IntoIterator<Item = S>) -> &mut Self {
        let row: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(
            row.len(),
            self.header.len(),
            "row width {} != header width {}",
            row.len(),
            self.header.len()
        );
        self.rows.push(row);
        self
    }

    pub fn num_rows(&self) -> usize {
        self.rows.len()
    }

    pub fn cell(&self, row: usize, col: usize) -> &str {
        &self.rows[row][col]
    }

    /// Raw tab-separated form (header + rows).
    pub fn to_tsv(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "{}", self.header.join("\t"));
        for row in &self.rows {
            let _ = writeln!(out, "{}", row.join("\t"));
        }
        out
    }

    /// Column-aligned form for terminal output / markdown-ish logs.
    pub fn to_aligned(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:>w$}", w = w))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let _ = writeln!(out, "{}", fmt_row(&self.header, &widths));
        let _ = writeln!(
            out,
            "{}",
            widths
                .iter()
                .map(|w| "-".repeat(*w))
                .collect::<Vec<_>>()
                .join("  ")
        );
        for row in &self.rows {
            let _ = writeln!(out, "{}", fmt_row(row, &widths));
        }
        out
    }

    pub fn write_tsv(&self, path: impl AsRef<Path>) -> io::Result<()> {
        std::fs::write(path, self.to_tsv())
    }
}

/// Format an f64 with fixed decimals — the tables in the paper use 4.
pub fn f(v: f64, decimals: usize) -> String {
    format!("{v:.decimals$}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_tsv() {
        let mut t = Table::new(["P", "eta"]);
        t.row(["10", "0.98"]).row(["30", "0.89"]);
        assert_eq!(t.to_tsv(), "P\teta\n10\t0.98\n30\t0.89\n");
        assert_eq!(t.num_rows(), 2);
        assert_eq!(t.cell(1, 1), "0.89");
    }

    #[test]
    fn aligned_output_pads() {
        let mut t = Table::new(["algo", "eta"]);
        t.row(["baseline", "0.9500"]);
        let s = t.to_aligned();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 3);
        assert!(lines[0].contains("algo"));
        assert!(lines[1].starts_with('-'));
        assert!(lines[2].contains("baseline"));
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn mismatched_row_panics() {
        let mut t = Table::new(["a", "b"]);
        t.row(["only-one"]);
    }

    #[test]
    fn f_formats() {
        assert_eq!(f(0.95, 4), "0.9500");
        assert_eq!(f(1.0, 1), "1.0");
    }
}
