//! Training configuration (paper §V-C defaults: K=256, α=0.5, β=0.1,
//! γ=0.1, ≤200 burn-in iterations).

use crate::corpus::shard::Residency;
use crate::kernel::KernelKind;
use crate::scheduler::adaptive::BalanceMode;
use crate::scheduler::exec::{CommitMode, ExecMode};
use crate::scheduler::schedule::ScheduleKind;

/// Which sampler/perplexity implementation runs the hot path.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// Pure-rust collapsed Gibbs (exact, fastest on CPU).
    Native,
    /// AOT-compiled JAX/Pallas kernels via PJRT (batched; demonstrates
    /// the three-layer bridge). Requires `make artifacts` and a binary
    /// built with the `xla` cargo feature.
    Xla,
}

#[derive(Clone, Copy, Debug)]
pub struct TrainConfig {
    pub topics: usize,
    pub alpha: f32,
    pub beta: f32,
    /// BoT timestamp prior.
    pub gamma: f32,
    pub iters: usize,
    /// Evaluate perplexity every this many sweeps (0 = final only).
    pub eval_every: usize,
    pub seed: u64,
    /// Diagonal-epoch executor: `Sequential` (determinism oracle),
    /// `Threaded` (per-epoch spawns), or `Pooled` (persistent worker
    /// pool — preferred for multi-core runs). All three produce
    /// identical counts; see `docs/executor.md`.
    pub mode: ExecMode,
    /// Executor worker count `W` (0 = auto: derived from the plan's grid
    /// and the schedule's grid factor — see [`Self::resolved_workers`]).
    pub workers: usize,
    /// How the partition grid maps onto the workers: the legacy
    /// `Diagonal` coupling (`P == W`) or `Packed` over-decomposition
    /// (`P = g·W`, LPT per diagonal); see `docs/scheduling.md`.
    pub schedule: ScheduleKind,
    /// Per-token sampling kernel for the parallel native path: `Dense`
    /// O(K) scan (reference; default), `Sparse` s/r/q buckets, or
    /// `Alias` tables with MH correction; see `docs/kernels.md`. The
    /// serial (`P == 1`) reference and the XLA backend always run dense.
    pub kernel: KernelKind,
    /// Load balancing for the parallel native path: `Static` token-LPT
    /// (default), `Adaptive` measured-cost re-packing between sweeps, or
    /// `Steal` within-epoch work stealing. Result-invariant — all three
    /// train bit-identical counts; see `docs/scheduling.md`.
    pub balance: BalanceMode,
    /// Delta-commit protocol for the parallel native path: `Barrier`
    /// (default) gathers every epoch's deltas at a full merge barrier;
    /// `Ticketed` folds them in ticket order while later tasks are still
    /// sampling, hiding the gather and the spill IO behind sampling.
    /// Result-invariant — both train bit-identical counts; see
    /// `docs/executor.md`.
    pub commit: CommitMode,
    /// Token-block residency for the parallel native path: `InCore`
    /// (default) keeps every block in RAM; `Spill` streams diagonals
    /// through a bounded working set backed by per-partition spill files
    /// (out-of-core corpora — see `docs/out_of_core.md`).
    /// Result-invariant; the serial reference and the XLA backend are
    /// always in-core.
    pub residency: Residency,
    /// Commit an atomic on-disk checkpoint every this many sweeps
    /// (0 = never). Only meaningful when the driver is given a
    /// checkpoint root; see `crate::coordinator::checkpoint` and
    /// `docs/fault_tolerance.md`.
    pub checkpoint_every: usize,
    pub backend: Backend,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self {
            topics: 256,
            alpha: 0.5,
            beta: 0.1,
            gamma: 0.1,
            iters: 200,
            eval_every: 0,
            seed: 42,
            mode: ExecMode::Sequential,
            workers: 0,
            schedule: ScheduleKind::Diagonal,
            kernel: KernelKind::Dense,
            balance: BalanceMode::Static,
            commit: CommitMode::Barrier,
            residency: Residency::InCore,
            checkpoint_every: 0,
            backend: Backend::Native,
        }
    }
}

impl TrainConfig {
    /// Small-scale config for tests and quick examples.
    pub fn quick(topics: usize, iters: usize) -> Self {
        Self {
            topics,
            iters,
            ..Default::default()
        }
    }

    /// The executor worker count for a grid of size `p`: the explicit
    /// `workers` when set, otherwise derived so the schedule is
    /// compatible with the grid (`W = P` diagonal, `W = P / g` packed).
    /// Panics with a config-level message when the grid cannot be
    /// scheduled (`g` does not divide `P`) rather than handing an
    /// impossible pair to the executor.
    pub fn resolved_workers(&self, p: usize) -> usize {
        if self.workers > 0 {
            return self.workers;
        }
        match self.schedule {
            ScheduleKind::Diagonal => p,
            ScheduleKind::Packed { grid_factor } => {
                let g = grid_factor.max(1);
                assert!(
                    p % g == 0,
                    "packed schedule needs the grid factor to divide the grid \
                     (P={p}, g={g}); partition with P = g*W or set workers explicitly"
                );
                (p / g).max(1)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper() {
        let c = TrainConfig::default();
        assert_eq!(c.topics, 256);
        assert_eq!(c.alpha, 0.5);
        assert_eq!(c.beta, 0.1);
        assert_eq!(c.gamma, 0.1);
        assert_eq!(c.iters, 200);
        assert_eq!(c.workers, 0);
        assert_eq!(c.schedule, ScheduleKind::Diagonal);
        assert_eq!(c.kernel, KernelKind::Dense);
        assert_eq!(c.balance, BalanceMode::Static);
        assert_eq!(c.commit, CommitMode::Barrier);
        assert_eq!(c.residency, Residency::InCore);
        assert_eq!(c.checkpoint_every, 0);
    }

    #[test]
    fn quick_overrides() {
        let c = TrainConfig::quick(8, 10);
        assert_eq!(c.topics, 8);
        assert_eq!(c.iters, 10);
        assert_eq!(c.alpha, 0.5);
    }

    #[test]
    fn workers_resolve_from_schedule() {
        let mut c = TrainConfig::default();
        assert_eq!(c.resolved_workers(8), 8);
        c.schedule = ScheduleKind::Packed { grid_factor: 4 };
        assert_eq!(c.resolved_workers(32), 8);
        c.workers = 2;
        assert_eq!(c.resolved_workers(32), 2);
    }

    #[test]
    #[should_panic(expected = "divide the grid")]
    fn indivisible_grid_factor_fails_at_config_level() {
        let mut c = TrainConfig::default();
        c.schedule = ScheduleKind::Packed { grid_factor: 3 };
        c.resolved_workers(8);
    }
}
