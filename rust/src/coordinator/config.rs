//! Training configuration (paper §V-C defaults: K=256, α=0.5, β=0.1,
//! γ=0.1, ≤200 burn-in iterations).

use crate::scheduler::exec::ExecMode;

/// Which sampler/perplexity implementation runs the hot path.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// Pure-rust collapsed Gibbs (exact, fastest on CPU).
    Native,
    /// AOT-compiled JAX/Pallas kernels via PJRT (batched; demonstrates
    /// the three-layer bridge). Requires `make artifacts` and a binary
    /// built with the `xla` cargo feature.
    Xla,
}

#[derive(Clone, Copy, Debug)]
pub struct TrainConfig {
    pub topics: usize,
    pub alpha: f32,
    pub beta: f32,
    /// BoT timestamp prior.
    pub gamma: f32,
    pub iters: usize,
    /// Evaluate perplexity every this many sweeps (0 = final only).
    pub eval_every: usize,
    pub seed: u64,
    /// Diagonal-epoch executor: `Sequential` (determinism oracle),
    /// `Threaded` (legacy per-epoch spawns), or `Pooled` (persistent
    /// worker pool — preferred for multi-core runs). All three produce
    /// identical counts; see `docs/executor.md`.
    pub mode: ExecMode,
    pub backend: Backend,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self {
            topics: 256,
            alpha: 0.5,
            beta: 0.1,
            gamma: 0.1,
            iters: 200,
            eval_every: 0,
            seed: 42,
            mode: ExecMode::Sequential,
            backend: Backend::Native,
        }
    }
}

impl TrainConfig {
    /// Small-scale config for tests and quick examples.
    pub fn quick(topics: usize, iters: usize) -> Self {
        Self {
            topics,
            iters,
            ..Default::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper() {
        let c = TrainConfig::default();
        assert_eq!(c.topics, 256);
        assert_eq!(c.alpha, 0.5);
        assert_eq!(c.beta, 0.1);
        assert_eq!(c.gamma, 0.1);
        assert_eq!(c.iters, 200);
    }

    #[test]
    fn quick_overrides() {
        let c = TrainConfig::quick(8, 10);
        assert_eq!(c.topics, 8);
        assert_eq!(c.iters, 10);
        assert_eq!(c.alpha, 0.5);
    }
}
