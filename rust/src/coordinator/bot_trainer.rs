//! BoT training driver (paper §IV-C + Table IV): serial or parallel with
//! independent DW/DTS partition plans.

use std::time::Instant;

use crate::bot::parallel::ParallelBot;
use crate::bot::serial::{BotHyper, SerialBot};
use crate::bot::timeline::{self, TopicTimeline};
use crate::coordinator::config::TrainConfig;
use crate::corpus::timestamps::TimestampedCorpus;
use crate::partition::{self, Algorithm, Plan};
use crate::util::json::Json;

#[derive(Clone, Debug)]
pub struct BotTrainReport {
    pub p: usize,
    pub topics: usize,
    pub iters: usize,
    pub final_perplexity: f64,
    /// η of the DW plan (1.0 for serial).
    pub eta_dw: f64,
    /// η of the DTS plan (1.0 for serial).
    pub eta_dts: f64,
    /// Combined speedup model over both phases: total tokens / combined
    /// epoch cost.
    pub speedup_model: f64,
    pub train_secs: f64,
    pub timelines: Vec<TopicTimeline>,
}

impl BotTrainReport {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("p", self.p)
            .set("topics", self.topics)
            .set("iters", self.iters)
            .set("final_perplexity", self.final_perplexity)
            .set("eta_dw", self.eta_dw)
            .set("eta_dts", self.eta_dts)
            .set("speedup_model", self.speedup_model)
            .set("train_secs", self.train_secs);
        j
    }
}

/// Partition both matrices with `algo` and train parallel BoT (`p == 1`
/// runs the serial reference).
pub fn train_bot(
    tc: &TimestampedCorpus,
    p: usize,
    algo: Algorithm,
    cfg: &TrainConfig,
) -> BotTrainReport {
    let h = BotHyper::new(
        cfg.topics,
        cfg.alpha,
        cfg.beta,
        cfg.gamma,
        tc.bow.num_words(),
        tc.num_stamps,
    );
    let started = Instant::now();

    if p == 1 {
        let mut bot = SerialBot::init(tc, h, cfg.seed);
        bot.train(tc, cfg.iters, 0);
        let final_perplexity = bot.perplexity(tc);
        return BotTrainReport {
            p: 1,
            topics: cfg.topics,
            iters: cfg.iters,
            final_perplexity,
            eta_dw: 1.0,
            eta_dts: 1.0,
            speedup_model: 1.0,
            train_secs: started.elapsed().as_secs_f64(),
            timelines: timeline::timelines(&bot.counts, &h),
        };
    }

    let plan_dw = partition::partition(&tc.bow, p, algo, cfg.seed);
    let plan_dts = partition::partition(&tc.dts, p, algo, cfg.seed ^ 0xD75);
    let speedup = combined_speedup(&plan_dw, &plan_dts);

    let mut bot = ParallelBot::init(tc, &plan_dw, &plan_dts, h, cfg.seed);
    bot.train(tc, cfg.iters, 0, cfg.mode);
    let final_perplexity = bot.perplexity(tc);
    BotTrainReport {
        p,
        topics: cfg.topics,
        iters: cfg.iters,
        final_perplexity,
        eta_dw: plan_dw.eta,
        eta_dts: plan_dts.eta,
        speedup_model: speedup,
        train_secs: started.elapsed().as_secs_f64(),
        timelines: timeline::timelines(&bot.counts, &h),
    }
}

/// Speedup of a BoT sweep: both phases contribute epoch costs; the serial
/// cost is the total token count of both matrices.
pub fn combined_speedup(plan_dw: &Plan, plan_dts: &Plan) -> f64 {
    let serial = (plan_dw.costs.total() + plan_dts.costs.total()) as f64;
    let parallel = (plan_dw.costs.sweep_cost() + plan_dts.costs.sweep_cost()) as f64;
    serial / parallel.max(1.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::synthetic::{generate_timestamped, Profile, TimeProfile};

    fn tiny_tc(seed: u64) -> TimestampedCorpus {
        let mut p = Profile::tiny();
        p.time = Some(TimeProfile {
            first_year: 2000,
            last_year: 2009,
            growth: 0.1,
            stamps_per_doc: 4,
        });
        generate_timestamped(&p, seed)
    }

    #[test]
    fn serial_vs_parallel_table_iv_shape() {
        let tc = tiny_tc(91);
        let cfg = TrainConfig::quick(8, 20);
        let serial = train_bot(&tc, 1, Algorithm::A1, &cfg);
        let parallel = train_bot(&tc, 4, Algorithm::A3 { restarts: 3 }, &cfg);
        let rel = (parallel.final_perplexity - serial.final_perplexity).abs()
            / serial.final_perplexity;
        assert!(
            rel < 0.06,
            "Table IV: serial {} vs parallel {}",
            serial.final_perplexity,
            parallel.final_perplexity
        );
        assert!(parallel.speedup_model > 1.0);
        assert!(parallel.eta_dw > 0.0 && parallel.eta_dts > 0.0);
        assert_eq!(parallel.timelines.len(), 8);
    }

    #[test]
    fn report_serializes() {
        let tc = tiny_tc(92);
        let cfg = TrainConfig::quick(4, 3);
        let r = train_bot(&tc, 2, Algorithm::A2, &cfg);
        let s = r.to_json().to_string();
        assert!(s.contains("eta_dw"));
    }
}
