//! BoT training driver (paper §IV-C + Table IV): serial or parallel with
//! independent DW/DTS partition plans.

use std::time::Instant;

use crate::bot::parallel::ParallelBot;
use crate::bot::serial::{BotHyper, SerialBot};
use crate::bot::timeline::{self, TopicTimeline};
use crate::coordinator::config::TrainConfig;
use crate::corpus::timestamps::TimestampedCorpus;
use crate::partition::{self, Algorithm, Plan};
use crate::util::json::Json;

#[derive(Clone, Debug)]
pub struct BotTrainReport {
    pub p: usize,
    /// Worker count `W` both phases executed on (1 for serial).
    pub workers: usize,
    /// Schedule label: "serial", "diagonal", or "packed(xg)".
    pub schedule: String,
    /// Sampling kernel label ("dense" for the serial reference).
    pub kernel: String,
    pub topics: usize,
    pub iters: usize,
    pub final_perplexity: f64,
    /// η of the DW plan (1.0 for serial).
    pub eta_dw: f64,
    /// η of the DTS plan (1.0 for serial).
    pub eta_dts: f64,
    /// Combined speedup model over both phases: total tokens / combined
    /// epoch cost.
    pub speedup_model: f64,
    pub train_secs: f64,
    pub timelines: Vec<TopicTimeline>,
}

impl BotTrainReport {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("p", self.p)
            .set("workers", self.workers)
            .set("schedule", self.schedule.as_str())
            .set("kernel", self.kernel.as_str())
            .set("topics", self.topics)
            .set("iters", self.iters)
            .set("final_perplexity", self.final_perplexity)
            .set("eta_dw", self.eta_dw)
            .set("eta_dts", self.eta_dts)
            .set("speedup_model", self.speedup_model)
            .set("train_secs", self.train_secs);
        j
    }
}

/// Partition both matrices with `algo` and train parallel BoT (`p == 1`
/// runs the serial reference).
pub fn train_bot(
    tc: &TimestampedCorpus,
    p: usize,
    algo: Algorithm,
    cfg: &TrainConfig,
) -> BotTrainReport {
    let h = BotHyper::new(
        cfg.topics,
        cfg.alpha,
        cfg.beta,
        cfg.gamma,
        tc.bow.num_words(),
        tc.num_stamps,
    );
    let started = Instant::now();

    if p == 1 {
        let mut bot = SerialBot::init(tc, h, cfg.seed);
        bot.train(tc, cfg.iters, 0);
        let final_perplexity = bot.perplexity(tc);
        return BotTrainReport {
            p: 1,
            workers: 1,
            schedule: "serial".to_string(),
            kernel: "dense".to_string(),
            topics: cfg.topics,
            iters: cfg.iters,
            final_perplexity,
            eta_dw: 1.0,
            eta_dts: 1.0,
            speedup_model: 1.0,
            train_secs: started.elapsed().as_secs_f64(),
            timelines: timeline::timelines(&bot.counts, &h),
        };
    }

    let plan_dw = partition::partition(&tc.bow, p, algo, cfg.seed);
    let plan_dts = partition::partition(&tc.dts, p, algo, cfg.seed ^ 0xD75);
    let workers = cfg.resolved_workers(p);

    let mut bot = ParallelBot::init_scheduled(
        tc,
        &plan_dw,
        &plan_dts,
        h,
        cfg.seed,
        cfg.schedule,
        workers,
    );
    bot.set_kernel(cfg.kernel);
    let speedup = {
        let (sdw, sdts) = bot.schedules();
        combined_speedup_scheduled(&plan_dw, &plan_dts, sdw, sdts)
    };
    bot.train(tc, cfg.iters, 0, cfg.mode);
    let final_perplexity = bot.perplexity(tc);
    BotTrainReport {
        p,
        workers,
        schedule: cfg.schedule.label(),
        kernel: cfg.kernel.name().to_string(),
        topics: cfg.topics,
        iters: cfg.iters,
        final_perplexity,
        eta_dw: plan_dw.eta,
        eta_dts: plan_dts.eta,
        speedup_model: speedup,
        train_secs: started.elapsed().as_secs_f64(),
        timelines: timeline::timelines(&bot.counts, &h),
    }
}

/// Speedup of a BoT sweep: both phases contribute epoch costs (each
/// phase's parallel cost is its schedule's critical path, `Σ_l max_w`,
/// which is the plan's Eq. 1 cost under the diagonal schedule); the
/// serial cost is the total token count of both matrices.
pub fn combined_speedup_scheduled(
    plan_dw: &Plan,
    plan_dts: &Plan,
    sched_dw: &crate::scheduler::schedule::Schedule,
    sched_dts: &crate::scheduler::schedule::Schedule,
) -> f64 {
    let serial = (plan_dw.costs.total() + plan_dts.costs.total()) as f64;
    let parallel =
        (sched_dw.cost(&plan_dw.costs) + sched_dts.cost(&plan_dts.costs)) as f64;
    serial / parallel.max(1.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::synthetic::{generate_timestamped, Profile, TimeProfile};

    fn tiny_tc(seed: u64) -> TimestampedCorpus {
        let mut p = Profile::tiny();
        p.time = Some(TimeProfile {
            first_year: 2000,
            last_year: 2009,
            growth: 0.1,
            stamps_per_doc: 4,
        });
        generate_timestamped(&p, seed)
    }

    #[test]
    fn serial_vs_parallel_table_iv_shape() {
        let tc = tiny_tc(91);
        let cfg = TrainConfig::quick(8, 20);
        let serial = train_bot(&tc, 1, Algorithm::A1, &cfg);
        let parallel = train_bot(&tc, 4, Algorithm::A3 { restarts: 3 }, &cfg);
        let rel = (parallel.final_perplexity - serial.final_perplexity).abs()
            / serial.final_perplexity;
        assert!(
            rel < 0.06,
            "Table IV: serial {} vs parallel {}",
            serial.final_perplexity,
            parallel.final_perplexity
        );
        assert!(parallel.speedup_model > 1.0);
        assert!(parallel.eta_dw > 0.0 && parallel.eta_dts > 0.0);
        assert_eq!(parallel.timelines.len(), 8);
    }

    #[test]
    fn packed_bot_through_driver_matches_diagonal() {
        use crate::scheduler::exec::ExecMode;
        use crate::scheduler::schedule::ScheduleKind;

        let tc = tiny_tc(93);
        let mut cfg = TrainConfig::quick(4, 4);
        let diag = train_bot(&tc, 4, Algorithm::A3 { restarts: 2 }, &cfg);

        cfg.schedule = ScheduleKind::Packed { grid_factor: 2 };
        cfg.workers = 2;
        cfg.mode = ExecMode::Pooled;
        let packed = train_bot(&tc, 4, Algorithm::A3 { restarts: 2 }, &cfg);

        assert_eq!(diag.final_perplexity, packed.final_perplexity);
        assert_eq!(packed.workers, 2);
        assert_eq!(packed.schedule, "packed(x2)");
        // Combined speedup is against W under packing, so it can at most
        // reach the worker count.
        assert!(packed.speedup_model <= 2.0 + 1e-9);
        assert_eq!(diag.workers, 4);
    }

    #[test]
    fn report_serializes() {
        let tc = tiny_tc(92);
        let cfg = TrainConfig::quick(4, 3);
        let r = train_bot(&tc, 2, Algorithm::A2, &cfg);
        let s = r.to_json().to_string();
        assert!(s.contains("eta_dw"));
    }
}
