//! BoT training driver (paper §IV-C + Table IV): serial or parallel with
//! independent DW/DTS partition plans.

use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use crate::bot::parallel::ParallelBot;
use crate::bot::serial::{BotHyper, SerialBot};
use crate::bot::timeline::{self, TopicTimeline};
use crate::coordinator::checkpoint::{self, Manifest};
use crate::coordinator::config::TrainConfig;
use crate::corpus::timestamps::TimestampedCorpus;
use crate::obs::metrics::{Family, Phase};
use crate::obs::trace::{Event, EventKind, Tracer};
use crate::partition::{self, Algorithm, Plan};
use crate::scheduler::cost_model::MeasuredReport;
use crate::util::json::Json;
use crate::util::timer::time_once;

#[derive(Clone, Debug)]
pub struct BotTrainReport {
    pub p: usize,
    /// Worker count `W` both phases executed on (1 for serial).
    pub workers: usize,
    /// Schedule label: "serial", "diagonal", or "packed(xg)".
    pub schedule: String,
    /// Sampling kernel label ("dense" for the serial reference).
    pub kernel: String,
    /// Balance-mode label ("static" for the serial reference).
    pub balance: String,
    /// Commit-protocol label ("barrier" for the serial reference).
    pub commit: String,
    /// Residency label ("in-core" for the serial reference).
    pub residency: String,
    pub topics: usize,
    pub iters: usize,
    pub final_perplexity: f64,
    /// η of the DW plan (1.0 for serial).
    pub eta_dw: f64,
    /// η of the DTS plan (1.0 for serial).
    pub eta_dts: f64,
    /// Measured (wallclock) η of the DW phase over all sweeps (1.0 for
    /// serial) — next to the token `eta_dw` so the non-uniform-cost gap
    /// is visible.
    pub measured_eta_dw: f64,
    /// Measured (wallclock) η of the DTS phase (1.0 for serial).
    pub measured_eta_dts: f64,
    /// Combined speedup model over both phases: total tokens / combined
    /// epoch cost.
    pub speedup_model: f64,
    pub train_secs: f64,
    /// Phase breakdown `(name, seconds)` —
    /// sample/barrier/update/perplexity buckets over both phases (empty
    /// for serial runs).
    pub phases: Vec<(String, f64)>,
    /// Sampling tasks re-executed after a contained worker panic over
    /// both phases of the whole run (0 in a fault-free run) — see
    /// `docs/fault_tolerance.md`.
    pub task_retries: u64,
    /// Transient spill-IO retries absorbed over the whole run (0 when
    /// in-core or fault-free).
    pub io_retries: u64,
    /// `Some(sweep)` when the run stopped early at a graceful-interrupt
    /// checkpoint (SIGINT with `--checkpoint-every` set) — see
    /// `crate::util::interrupt`.
    pub interrupted_at: Option<usize>,
    pub timelines: Vec<TopicTimeline>,
}

impl BotTrainReport {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("p", self.p)
            .set("workers", self.workers)
            .set("schedule", self.schedule.as_str())
            .set("kernel", self.kernel.as_str())
            .set("balance", self.balance.as_str())
            .set("commit", self.commit.as_str())
            .set("residency", self.residency.as_str())
            .set("topics", self.topics)
            .set("iters", self.iters)
            .set("final_perplexity", self.final_perplexity)
            .set("eta_dw", self.eta_dw)
            .set("eta_dts", self.eta_dts)
            .set("measured_eta_dw", self.measured_eta_dw)
            .set("measured_eta_dts", self.measured_eta_dts)
            .set("speedup_model", self.speedup_model)
            .set("train_secs", self.train_secs)
            .set("task_retries", self.task_retries)
            .set("io_retries", self.io_retries)
            .set("interrupted_at", match self.interrupted_at {
                Some(it) => Json::from(it),
                None => Json::Null,
            })
            .set("phases", {
                let mut ph = Json::obj();
                for (name, secs) in &self.phases {
                    ph.set(name, *secs);
                }
                ph
            });
        j
    }
}

/// Partition both matrices with `algo` and train parallel BoT (`p == 1`
/// runs the serial reference).
pub fn train_bot(
    tc: &TimestampedCorpus,
    p: usize,
    algo: Algorithm,
    cfg: &TrainConfig,
) -> BotTrainReport {
    train_bot_checkpointed(tc, p, algo, cfg, None, None)
}

/// [`train_bot`] with checkpoint/resume wired in: when `checkpoint_root`
/// is set and `cfg.checkpoint_every > 0`, an atomic checkpoint is
/// committed under the root every N sweeps; when `resume` is set, the
/// run restarts from that checkpoint (a `ckpt-N` directory or a root to
/// scan) and finishes bit-identically to the uninterrupted run. See
/// `docs/fault_tolerance.md`.
pub fn train_bot_checkpointed(
    tc: &TimestampedCorpus,
    p: usize,
    algo: Algorithm,
    cfg: &TrainConfig,
    checkpoint_root: Option<&Path>,
    resume: Option<&Path>,
) -> BotTrainReport {
    train_bot_traced(tc, p, algo, cfg, checkpoint_root, resume, None)
}

/// As [`train_bot_checkpointed`], with a [`Tracer`] attached to the
/// parallel engine: both phase families (word = 0, stamp = 1) land their
/// task/commit/IO events in the tracer's ring buffers. Tracing is
/// strictly observational — results are bit-identical with and without.
pub fn train_bot_traced(
    tc: &TimestampedCorpus,
    p: usize,
    algo: Algorithm,
    cfg: &TrainConfig,
    checkpoint_root: Option<&Path>,
    resume: Option<&Path>,
    tracer: Option<&Arc<Tracer>>,
) -> BotTrainReport {
    if (checkpoint_root.is_some() || resume.is_some()) && p == 1 {
        panic!("checkpoint/resume requires the partitioned native backend (P > 1)");
    }
    let h = BotHyper::new(
        cfg.topics,
        cfg.alpha,
        cfg.beta,
        cfg.gamma,
        tc.bow.num_words(),
        tc.num_stamps,
    );
    let started = Instant::now();

    if p == 1 {
        let mut bot = SerialBot::init(tc, h, cfg.seed);
        bot.train(tc, cfg.iters, 0);
        let final_perplexity = bot.perplexity(tc);
        return BotTrainReport {
            p: 1,
            workers: 1,
            schedule: "serial".to_string(),
            kernel: "dense".to_string(),
            balance: "static".to_string(),
            commit: "barrier".to_string(),
            residency: "in-core".to_string(),
            topics: cfg.topics,
            iters: cfg.iters,
            final_perplexity,
            eta_dw: 1.0,
            eta_dts: 1.0,
            measured_eta_dw: 1.0,
            measured_eta_dts: 1.0,
            speedup_model: 1.0,
            train_secs: started.elapsed().as_secs_f64(),
            phases: Vec::new(),
            task_retries: 0,
            io_retries: 0,
            interrupted_at: None,
            timelines: timeline::timelines(&bot.counts, &h),
        };
    }

    let plan_dw = partition::partition(&tc.bow, p, algo, cfg.seed);
    let plan_dts = partition::partition(&tc.dts, p, algo, cfg.seed ^ 0xD75);
    let workers = cfg.resolved_workers(p);

    let (mut bot, start) = match resume {
        Some(path) => {
            let (bot, sweeps) = checkpoint::resume_bot(tc, &plan_dw, &plan_dts, h, cfg, path)
                .unwrap_or_else(|e| panic!("resume failed: {e}"));
            (bot, sweeps)
        }
        None => {
            let bot = ParallelBot::init_resident(
                tc,
                &plan_dw,
                &plan_dts,
                h,
                cfg.seed,
                cfg.schedule,
                workers,
                cfg.residency,
            )
            .unwrap_or_else(|e| panic!("out-of-core init failed: {e}"));
            (bot, 0)
        }
    };
    bot.set_kernel(cfg.kernel);
    bot.set_balance(cfg.balance);
    bot.set_commit(cfg.commit);
    bot.set_tracer(tracer.cloned());
    let speedup = {
        let (sdw, sdts) = bot.schedules();
        combined_speedup_scheduled(&plan_dw, &plan_dts, sdw, sdts)
    };
    // The sweep loop lives here so the driver can meter eval/checkpoint
    // phases and accumulate per-phase measured-η telemetry. Per-phase
    // seconds live in the engine's metrics registry (word + stamp
    // families summed); the report's phase list is a view over it.
    let (mut dw_serial, mut dw_crit) = (0u64, 0u64);
    let (mut dts_serial, mut dts_crit) = (0u64, 0u64);
    let (mut task_retries, mut io_retries) = (0u64, 0u64);
    let mut interrupted_at = None;
    for it in start + 1..=cfg.iters {
        let (ws, ss) = bot.sweep(cfg.mode);
        dw_serial += ws.busy_total_nanos();
        dw_crit += ws.crit_nanos();
        dts_serial += ss.busy_total_nanos();
        dts_crit += ss.crit_nanos();
        task_retries += ws.task_retries + ss.task_retries;
        io_retries += ws.io_retries + ss.io_retries;
        let mut checkpointed = false;
        if cfg.checkpoint_every > 0 && it % cfg.checkpoint_every == 0 {
            if let Some(root) = checkpoint_root {
                let ((), dt) = time_once(|| {
                    let m = Manifest::bot(tc, p, cfg, it);
                    checkpoint::write_bot(&bot, &m, root)
                        .unwrap_or_else(|e| panic!("checkpoint failed: {e}"));
                });
                let m = bot.metrics();
                m.add_phase(Family::Word, Phase::Checkpoint, dt);
                m.checkpoints.inc();
                checkpointed = true;
                if let Some(tr) = tracer {
                    let dur = (dt.as_secs_f64() * 1e9) as u64;
                    tr.emit(Event {
                        lane: tr.coord_lane(),
                        sweep: it as u32,
                        t0_ns: tr.now().saturating_sub(dur),
                        dur_ns: dur,
                        ..Event::of(EventKind::Checkpoint)
                    });
                }
            }
        }
        // Graceful interrupt: the in-flight sweep finished above;
        // commit a final checkpoint at this sweep (unless the periodic
        // cadence just wrote one) and stop.
        if it < cfg.iters && cfg.checkpoint_every > 0 && crate::util::interrupt::requested() {
            if let Some(root) = checkpoint_root {
                if !checkpointed {
                    let m = Manifest::bot(tc, p, cfg, it);
                    checkpoint::write_bot(&bot, &m, root)
                        .unwrap_or_else(|e| panic!("checkpoint failed: {e}"));
                    bot.metrics().checkpoints.inc();
                }
                interrupted_at = Some(it);
                break;
            }
        }
    }
    let (final_perplexity, dt) = time_once(|| bot.perplexity(tc));
    bot.metrics().add_phase(Family::Word, Phase::Perplexity, dt);
    BotTrainReport {
        p,
        workers,
        schedule: cfg.schedule.label(),
        kernel: cfg.kernel.name().to_string(),
        balance: cfg.balance.name().to_string(),
        commit: cfg.commit.name().to_string(),
        residency: cfg.residency.label(),
        topics: cfg.topics,
        iters: cfg.iters,
        final_perplexity,
        eta_dw: plan_dw.eta,
        eta_dts: plan_dts.eta,
        measured_eta_dw: MeasuredReport::of_nanos(workers, dw_serial, dw_crit).eta,
        measured_eta_dts: MeasuredReport::of_nanos(workers, dts_serial, dts_crit).eta,
        speedup_model: speedup,
        train_secs: started.elapsed().as_secs_f64(),
        phases: bot.metrics().phases_secs(),
        task_retries,
        io_retries,
        interrupted_at,
        timelines: timeline::timelines(&bot.counts, &h),
    }
}

/// Speedup of a BoT sweep: both phases contribute epoch costs (each
/// phase's parallel cost is its schedule's critical path, `Σ_l max_w`,
/// which is the plan's Eq. 1 cost under the diagonal schedule); the
/// serial cost is the total token count of both matrices.
pub fn combined_speedup_scheduled(
    plan_dw: &Plan,
    plan_dts: &Plan,
    sched_dw: &crate::scheduler::schedule::Schedule,
    sched_dts: &crate::scheduler::schedule::Schedule,
) -> f64 {
    let serial = (plan_dw.costs.total() + plan_dts.costs.total()) as f64;
    let parallel =
        (sched_dw.cost(&plan_dw.costs) + sched_dts.cost(&plan_dts.costs)) as f64;
    serial / parallel.max(1.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::synthetic::{generate_timestamped, Profile, TimeProfile};

    fn tiny_tc(seed: u64) -> TimestampedCorpus {
        let mut p = Profile::tiny();
        p.time = Some(TimeProfile {
            first_year: 2000,
            last_year: 2009,
            growth: 0.1,
            stamps_per_doc: 4,
        });
        generate_timestamped(&p, seed)
    }

    #[test]
    fn serial_vs_parallel_table_iv_shape() {
        let tc = tiny_tc(91);
        let cfg = TrainConfig::quick(8, 20);
        let serial = train_bot(&tc, 1, Algorithm::A1, &cfg);
        let parallel = train_bot(&tc, 4, Algorithm::A3 { restarts: 3 }, &cfg);
        let rel = (parallel.final_perplexity - serial.final_perplexity).abs()
            / serial.final_perplexity;
        assert!(
            rel < 0.06,
            "Table IV: serial {} vs parallel {}",
            serial.final_perplexity,
            parallel.final_perplexity
        );
        assert!(parallel.speedup_model > 1.0);
        assert!(parallel.eta_dw > 0.0 && parallel.eta_dts > 0.0);
        assert_eq!(parallel.timelines.len(), 8);
    }

    #[test]
    fn packed_bot_through_driver_matches_diagonal() {
        use crate::scheduler::exec::ExecMode;
        use crate::scheduler::schedule::ScheduleKind;

        let tc = tiny_tc(93);
        let mut cfg = TrainConfig::quick(4, 4);
        let diag = train_bot(&tc, 4, Algorithm::A3 { restarts: 2 }, &cfg);

        cfg.schedule = ScheduleKind::Packed { grid_factor: 2 };
        cfg.workers = 2;
        cfg.mode = ExecMode::Pooled;
        let packed = train_bot(&tc, 4, Algorithm::A3 { restarts: 2 }, &cfg);

        assert_eq!(diag.final_perplexity, packed.final_perplexity);
        assert_eq!(packed.workers, 2);
        assert_eq!(packed.schedule, "packed(x2)");
        // Combined speedup is against W under packing, so it can at most
        // reach the worker count.
        assert!(packed.speedup_model <= 2.0 + 1e-9);
        assert_eq!(diag.workers, 4);
    }

    #[test]
    fn report_serializes() {
        let tc = tiny_tc(92);
        let cfg = TrainConfig::quick(4, 3);
        let r = train_bot(&tc, 2, Algorithm::A2, &cfg);
        let s = r.to_json().to_string();
        assert!(s.contains("eta_dw"));
        assert!(s.contains("measured_eta_dts"));
        assert!(s.contains("\"balance\":\"static\""));
        assert!(s.contains("\"commit\":\"barrier\""));
        assert!(s.contains("\"residency\":\"in-core\""));
        assert!(s.contains("\"phases\":{"));
        assert!(s.contains("\"task_retries\":0"));
        assert!(s.contains("\"io_retries\":0"));
        assert!(s.contains("\"interrupted_at\":null"));
    }

    #[test]
    fn checkpointed_bot_run_resumes_bit_identically() {
        let tc = tiny_tc(96);
        let algo = Algorithm::A3 { restarts: 2 };
        let mut cfg = TrainConfig::quick(4, 6);
        let oracle = train_bot(&tc, 4, algo, &cfg);
        assert_eq!(oracle.task_retries, 0);
        assert_eq!(oracle.io_retries, 0);

        // Run 4 of 6 sweeps with checkpoints every 2, as if interrupted.
        let root = std::env::temp_dir().join(format!("pplda-bot-ckpt-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&root);
        cfg.iters = 4;
        cfg.checkpoint_every = 2;
        train_bot_checkpointed(&tc, 4, algo, &cfg, Some(&root), None);
        assert!(root.join("ckpt-2").is_dir(), "periodic checkpoint at sweep 2");
        assert!(root.join("ckpt-4").is_dir(), "periodic checkpoint at sweep 4");

        // Resume picks the latest checkpoint and finishes the run.
        cfg.iters = 6;
        cfg.checkpoint_every = 0;
        let resumed = train_bot_checkpointed(&tc, 4, algo, &cfg, None, Some(&root));
        assert_eq!(
            resumed.final_perplexity, oracle.final_perplexity,
            "resumed BoT run is bit-identical to the uninterrupted one"
        );
        std::fs::remove_dir_all(&root).unwrap();
    }

    #[test]
    fn sigint_latch_checkpoints_bot_and_stops_early() {
        let tc = tiny_tc(98);
        let algo = Algorithm::A3 { restarts: 2 };
        let mut cfg = TrainConfig::quick(4, 6);
        let oracle = train_bot(&tc, 4, algo, &cfg);
        assert_eq!(oracle.interrupted_at, None);

        let root = std::env::temp_dir().join(format!("pplda-bot-int-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&root);
        cfg.checkpoint_every = 2;
        crate::util::interrupt::trigger();
        let stopped = train_bot_checkpointed(&tc, 4, algo, &cfg, Some(&root), None);
        crate::util::interrupt::reset();
        assert_eq!(stopped.interrupted_at, Some(1));
        assert!(root.join("ckpt-1").is_dir(), "final interrupt checkpoint");

        // Resuming from the interrupt checkpoint completes the run
        // bit-identically to one that was never interrupted.
        cfg.checkpoint_every = 0;
        let resumed = train_bot_checkpointed(&tc, 4, algo, &cfg, None, Some(&root));
        assert_eq!(resumed.interrupted_at, None);
        assert_eq!(resumed.final_perplexity, oracle.final_perplexity);
        std::fs::remove_dir_all(&root).unwrap();
    }

    #[test]
    fn bot_balance_modes_through_driver_are_bit_identical() {
        use crate::scheduler::adaptive::BalanceMode;
        use crate::scheduler::exec::ExecMode;
        use crate::scheduler::schedule::ScheduleKind;

        let tc = tiny_tc(95);
        let mut cfg = TrainConfig::quick(4, 3);
        cfg.schedule = ScheduleKind::Packed { grid_factor: 2 };
        cfg.workers = 2;
        cfg.mode = ExecMode::Pooled;
        let baseline = train_bot(&tc, 4, Algorithm::A3 { restarts: 2 }, &cfg);
        assert_eq!(baseline.balance, "static");
        for (balance, label) in [
            (BalanceMode::Adaptive, "adaptive"),
            (BalanceMode::Steal, "steal"),
        ] {
            cfg.balance = balance;
            let r = train_bot(&tc, 4, Algorithm::A3 { restarts: 2 }, &cfg);
            assert_eq!(r.balance, label);
            assert_eq!(r.final_perplexity, baseline.final_perplexity, "{label}");
            assert!(
                r.measured_eta_dw > 0.0 && r.measured_eta_dw <= 1.0 + 1e-9,
                "{label}: {}",
                r.measured_eta_dw
            );
            assert!(
                r.measured_eta_dts > 0.0 && r.measured_eta_dts <= 1.0 + 1e-9,
                "{label}: {}",
                r.measured_eta_dts
            );
            let names: Vec<&str> = r.phases.iter().map(|(n, _)| n.as_str()).collect();
            assert!(names.contains(&"sample"), "{names:?}");
            assert!(names.contains(&"perplexity"), "{names:?}");
        }
    }

    #[test]
    fn bot_commit_modes_through_driver_are_bit_identical() {
        use crate::scheduler::exec::{CommitMode, ExecMode};
        use crate::scheduler::schedule::ScheduleKind;

        let tc = tiny_tc(97);
        let mut cfg = TrainConfig::quick(4, 3);
        cfg.schedule = ScheduleKind::Packed { grid_factor: 2 };
        cfg.workers = 2;
        cfg.mode = ExecMode::Pooled;
        let barrier = train_bot(&tc, 4, Algorithm::A3 { restarts: 2 }, &cfg);
        assert_eq!(barrier.commit, "barrier");

        cfg.commit = CommitMode::Ticketed;
        let ticketed = train_bot(&tc, 4, Algorithm::A3 { restarts: 2 }, &cfg);
        assert_eq!(ticketed.commit, "ticketed");
        // The commit protocol moves work in time, never results.
        assert_eq!(ticketed.final_perplexity, barrier.final_perplexity);
        let names: Vec<&str> = ticketed.phases.iter().map(|(n, _)| n.as_str()).collect();
        assert!(
            names.contains(&"commit") || names.contains(&"runahead"),
            "{names:?}"
        );
    }
}
