//! Training drivers: corpus + plan + config → trained model + report.
//!
//! This is the layer the CLI, the examples and the benches call. It wires
//! partitioning ([`crate::partition`]), the engines ([`crate::gibbs`],
//! [`crate::scheduler`], [`crate::bot`]) and the optional XLA backend
//! ([`crate::runtime`]) together and emits structured reports.

pub mod bot_trainer;
pub mod checkpoint;
pub mod config;
pub mod report;
pub mod trainer;

pub use bot_trainer::{train_bot, train_bot_checkpointed, train_bot_traced, BotTrainReport};
pub use config::{Backend, TrainConfig};
pub use report::TrainReport;
pub use trainer::{train_lda, train_lda_checkpointed, train_lda_traced, train_lda_with_snapshot};
