//! LDA training driver: serial (`P == 1`) or partitioned-parallel, with
//! native or XLA backends.

use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use crate::coordinator::checkpoint::{self, Manifest};
use crate::coordinator::config::{Backend, TrainConfig};
use crate::coordinator::report::TrainReport;
use crate::corpus::bow::BagOfWords;
use crate::gibbs::counts::LdaCounts;
use crate::gibbs::serial::SerialLda;
use crate::obs::metrics::{Family, Phase};
use crate::obs::trace::{Event, EventKind, Tracer};
use crate::partition::eta::EtaComparison;
use crate::partition::Plan;
#[cfg(feature = "xla")]
use crate::runtime::executor::Artifacts;
#[cfg(feature = "xla")]
use crate::runtime::sampler_xla::{XlaPerplexity, XlaSampler};
use crate::scheduler::cost_model::MeasuredReport;
use crate::scheduler::exec::ParallelLda;
use crate::serve::snapshot::ModelSnapshot;
use crate::util::interrupt;
#[cfg(feature = "xla")]
use crate::util::rng::Rng;
use crate::util::timer::{time_once, PhaseTimer};

/// Train LDA on `bow` under `plan`. `plan.p == 1` runs the serial
/// reference; `p > 1` the diagonal-epoch parallel engine, scheduled onto
/// `cfg.resolved_workers(plan.p)` workers under `cfg.schedule`. The XLA
/// backend requires artifacts compiled for `(batch, cfg.topics)` and
/// runs the batched serial-semantics sweep (it demonstrates the L3↔L1
/// bridge; partition-parallel execution uses the native kernel).
pub fn train_lda(bow: &BagOfWords, plan: &Plan, cfg: &TrainConfig) -> TrainReport {
    train_lda_checkpointed(bow, plan, cfg, None, None)
}

/// As [`train_lda`], with first-class checkpoint/resume: when
/// `checkpoint_root` is set and `cfg.checkpoint_every > 0`, an atomic
/// on-disk checkpoint is committed under the root every
/// `checkpoint_every` sweeps; when `resume` is set, training restarts
/// from that checkpoint (a `ckpt-*` directory or a root, in which case
/// the latest checkpoint wins) and continues bit-identically to a run
/// that never stopped. Checkpointing requires the partitioned native
/// backend (`plan.p > 1`); see `crate::coordinator::checkpoint` and
/// `docs/fault_tolerance.md`.
pub fn train_lda_checkpointed(
    bow: &BagOfWords,
    plan: &Plan,
    cfg: &TrainConfig,
    checkpoint_root: Option<&Path>,
    resume: Option<&Path>,
) -> TrainReport {
    train_lda_traced(bow, plan, cfg, checkpoint_root, resume, None)
}

/// As [`train_lda_checkpointed`], with a [`Tracer`] attached to the
/// parallel engine: every task/steal/commit/IO event of the run lands in
/// the tracer's ring buffers, ready for `obs::export::write_trace` +
/// `pplda analyze-trace`. Tracing is strictly observational — the
/// trained model is bit-identical with and without it.
pub fn train_lda_traced(
    bow: &BagOfWords,
    plan: &Plan,
    cfg: &TrainConfig,
    checkpoint_root: Option<&Path>,
    resume: Option<&Path>,
    tracer: Option<&Arc<Tracer>>,
) -> TrainReport {
    train_lda_with_snapshot(bow, plan, cfg, checkpoint_root, resume, tracer, None)
}

/// As [`train_lda_traced`], optionally exporting a serve-ready
/// [`ModelSnapshot`] (`PPSNAP1`, see `docs/serving.md`) to
/// `snapshot_out` when training finishes. Export is supported on both
/// native arms (serial and partitioned); the XLA backend does not
/// export. Two robustness behaviours live here as well:
///
/// * **Graceful interrupt**: when `cfg.checkpoint_every > 0` and a
///   checkpoint root is set, a SIGINT latched via
///   [`crate::util::interrupt`] finishes the in-flight sweep, commits a
///   final checkpoint at that sweep, and returns early with
///   `interrupted_at = Some(sweep)` instead of tearing the process
///   down mid-write.
/// * An interrupted run still exports its snapshot: the model written
///   is the one the final checkpoint describes.
pub fn train_lda_with_snapshot(
    bow: &BagOfWords,
    plan: &Plan,
    cfg: &TrainConfig,
    checkpoint_root: Option<&Path>,
    resume: Option<&Path>,
    tracer: Option<&Arc<Tracer>>,
    snapshot_out: Option<&Path>,
) -> TrainReport {
    if (checkpoint_root.is_some() || resume.is_some())
        && (plan.p == 1 || cfg.backend == Backend::Xla)
    {
        panic!("checkpoint/resume requires the partitioned native backend (P > 1)");
    }
    let started = Instant::now();
    // Serial-equivalent defaults, overwritten by the parallel arm.
    let mut workers = 1;
    let mut schedule = "serial".to_string();
    let mut schedule_eta = 1.0;
    let mut measured_eta = 1.0;
    // The serial reference and the XLA backend are dense-only,
    // single-worker, and in-core; the parallel native arm runs the
    // configured kernel, balance mode, and residency.
    let mut kernel = "dense".to_string();
    let mut balance = "static".to_string();
    let mut commit = "barrier".to_string();
    let mut residency = "in-core".to_string();
    let mut timer = PhaseTimer::new();
    // Fault-tolerance telemetry (parallel native arm only).
    let (mut task_retries, mut io_retries) = (0u64, 0u64);
    // Sweeps actually executed this process (differs from `cfg.iters`
    // only when resuming) — the throughput denominator.
    let mut executed_sweeps = cfg.iters;
    // `Some(sweep)` when a latched SIGINT stopped the run early at a
    // final checkpoint (parallel native arm only).
    let mut interrupted_at = None;
    let (curve, final_perplexity) = match (cfg.backend, plan.p) {
        (Backend::Native, 1) => {
            let mut lda = SerialLda::init(bow, cfg.topics, cfg.alpha, cfg.beta, cfg.seed);
            let mut curve = lda.train(bow, cfg.iters, cfg.eval_every);
            let fin = lda.perplexity(bow);
            if curve.is_empty() {
                curve.push((cfg.iters, fin));
            }
            export_snapshot(snapshot_out, &lda.counts, cfg);
            (curve, fin)
        }
        (Backend::Native, _) => {
            let w = cfg.resolved_workers(plan.p);
            let (mut lda, start) = match resume {
                Some(path) => {
                    let (lda, sweeps) = checkpoint::resume_lda(bow, plan, cfg, path)
                        .unwrap_or_else(|e| panic!("resume failed: {e}"));
                    (lda, sweeps)
                }
                None => {
                    let lda = ParallelLda::init_resident(
                        bow,
                        plan,
                        cfg.topics,
                        cfg.alpha,
                        cfg.beta,
                        cfg.seed,
                        cfg.schedule,
                        w,
                        cfg.residency,
                    )
                    .unwrap_or_else(|e| panic!("out-of-core init failed: {e}"));
                    (lda, 0)
                }
            };
            executed_sweeps = cfg.iters.saturating_sub(start);
            lda.set_kernel(cfg.kernel);
            lda.set_balance(cfg.balance);
            lda.set_commit(cfg.commit);
            lda.set_tracer(tracer.cloned());
            workers = w;
            schedule = cfg.schedule.label();
            schedule_eta = EtaComparison::of(plan, lda.schedule()).schedule.eta;
            kernel = cfg.kernel.name().to_string();
            balance = cfg.balance.name().to_string();
            commit = cfg.commit.name().to_string();
            residency = cfg.residency.label();
            // The sweep loop lives here (not in `ParallelLda::train`) so
            // the driver can meter eval/checkpoint phases and accumulate
            // the measured-η telemetry per sweep. Per-phase seconds live
            // in the engine's metrics registry; the report's PhaseTimer
            // is a view over it, built after the loop.
            let mut curve = Vec::new();
            let (mut serial_nanos, mut crit_nanos) = (0u64, 0u64);
            for it in start + 1..=cfg.iters {
                let stats = lda.sweep(cfg.mode);
                serial_nanos += stats.busy_total_nanos();
                crit_nanos += stats.crit_nanos();
                task_retries += stats.task_retries;
                io_retries += stats.io_retries;
                if cfg.eval_every > 0 && (it % cfg.eval_every == 0 || it == cfg.iters) {
                    let (pp, dt) = time_once(|| lda.perplexity(bow));
                    lda.metrics().add_phase(Family::Word, Phase::Perplexity, dt);
                    curve.push((it, pp));
                }
                let mut checkpointed = false;
                if cfg.checkpoint_every > 0 && it % cfg.checkpoint_every == 0 {
                    if let Some(root) = checkpoint_root {
                        let ((), dt) = time_once(|| {
                            let m = Manifest::lda(bow, plan, cfg, it);
                            checkpoint::write_lda(&lda, &m, root)
                                .unwrap_or_else(|e| panic!("checkpoint failed: {e}"));
                        });
                        let m = lda.metrics();
                        m.add_phase(Family::Word, Phase::Checkpoint, dt);
                        m.checkpoints.inc();
                        checkpointed = true;
                        if let Some(tr) = tracer {
                            let dur = (dt.as_secs_f64() * 1e9) as u64;
                            tr.emit(Event {
                                lane: tr.coord_lane(),
                                sweep: it as u32,
                                t0_ns: tr.now().saturating_sub(dur),
                                dur_ns: dur,
                                ..Event::of(EventKind::Checkpoint)
                            });
                        }
                    }
                }
                // Graceful interrupt: the in-flight sweep finished
                // above; commit a final checkpoint at this sweep (if
                // the periodic cadence didn't just write one) and stop.
                if it < cfg.iters && cfg.checkpoint_every > 0 && interrupt::requested() {
                    if let Some(root) = checkpoint_root {
                        if !checkpointed {
                            let m = Manifest::lda(bow, plan, cfg, it);
                            checkpoint::write_lda(&lda, &m, root)
                                .unwrap_or_else(|e| panic!("checkpoint failed: {e}"));
                            lda.metrics().checkpoints.inc();
                        }
                        interrupted_at = Some(it);
                        executed_sweeps = it.saturating_sub(start);
                        break;
                    }
                }
            }
            measured_eta = MeasuredReport::of_nanos(w, serial_nanos, crit_nanos).eta;
            // The eval cadence always records the final sweep when it
            // records anything; reuse that value rather than paying a
            // second full-corpus evaluation for `fin`.
            let fin = match curve.last() {
                Some(&(it, pp)) if it == cfg.iters => pp,
                _ => {
                    let (pp, dt) = time_once(|| lda.perplexity(bow));
                    lda.metrics().add_phase(Family::Word, Phase::Perplexity, dt);
                    pp
                }
            };
            if curve.is_empty() {
                curve.push((cfg.iters, fin));
            }
            timer = lda.metrics().phase_timer();
            export_snapshot(snapshot_out, &lda.counts, cfg);
            (curve, fin)
        }
        (Backend::Xla, _) => {
            assert!(
                snapshot_out.is_none(),
                "snapshot export requires the native backend"
            );
            train_xla(bow, cfg)
        }
    };
    let train_secs = started.elapsed().as_secs_f64();
    let sampled_tokens = bow.num_tokens() as f64 * executed_sweeps as f64;

    TrainReport {
        algorithm: plan.algorithm.to_string(),
        backend: match cfg.backend {
            Backend::Native => "native".into(),
            Backend::Xla => "xla".into(),
        },
        p: plan.p,
        workers,
        schedule,
        kernel,
        balance,
        commit,
        residency,
        topics: cfg.topics,
        iters: cfg.iters,
        curve,
        final_perplexity,
        eta: plan.eta,
        schedule_eta,
        measured_eta,
        speedup_model: schedule_eta * workers as f64,
        train_secs,
        tokens_per_sec: sampled_tokens / train_secs.max(1e-12),
        phases: timer.phases_secs(),
        task_retries,
        io_retries,
        interrupted_at,
    }
}

/// Export the trained counts as a serve snapshot when requested.
fn export_snapshot(path: Option<&Path>, counts: &LdaCounts, cfg: &TrainConfig) {
    if let Some(path) = path {
        ModelSnapshot::from_counts(counts, cfg.alpha, cfg.beta, cfg.seed)
            .write(path)
            .unwrap_or_else(|e| panic!("snapshot export failed: {e}"));
    }
}

#[cfg(not(feature = "xla"))]
fn train_xla(_bow: &BagOfWords, _cfg: &TrainConfig) -> (Vec<(usize, f64)>, f64) {
    panic!(
        "Backend::Xla requires building with `--features xla` \
         (and the external `xla` bindings crate; see Cargo.toml)"
    );
}

#[cfg(feature = "xla")]
fn train_xla(bow: &BagOfWords, cfg: &TrainConfig) -> (Vec<(usize, f64)>, f64) {
    let arts = Artifacts::discover(Artifacts::default_dir())
        .expect("XLA backend requires `make artifacts`");
    // Pick the first compiled batch size for this K.
    let batch = arts
        .variants("sampler")
        .into_iter()
        .find(|&(_, k)| k == cfg.topics)
        .unwrap_or_else(|| {
            panic!(
                "no sampler artifact for K={}; available {:?}",
                cfg.topics,
                arts.variants("sampler")
            )
        })
        .0;
    let mut sampler = XlaSampler::new(arts.sampler(batch, cfg.topics).unwrap());
    let mut perp = XlaPerplexity::new(arts.loglik(batch, cfg.topics).unwrap());

    let mut rng = Rng::stream(cfg.seed, 0x1A);
    let mut block =
        crate::gibbs::tokens::TokenBlock::from_corpus(bow, cfg.topics, &mut rng);
    let mut counts =
        crate::gibbs::counts::LdaCounts::zeros(bow.num_docs(), bow.num_words(), cfg.topics);
    counts.absorb(&block);
    let h = crate::gibbs::sampler::Hyper::new(cfg.topics, cfg.alpha, cfg.beta, bow.num_words());

    let mut curve = Vec::new();
    for it in 1..=cfg.iters {
        sampler
            .sweep(&mut block, &mut counts, &h, &mut rng)
            .expect("XLA sweep");
        if cfg.eval_every > 0 && (it % cfg.eval_every == 0 || it == cfg.iters) {
            curve.push((it, perp.perplexity(bow, &counts, &h).expect("XLA perplexity")));
        }
    }
    let fin = perp.perplexity(bow, &counts, &h).expect("XLA perplexity");
    if curve.is_empty() {
        curve.push((cfg.iters, fin));
    }
    (curve, fin)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::synthetic::{generate, Profile};
    use crate::partition::{partition, Algorithm};

    #[test]
    fn serial_and_parallel_reports() {
        let bow = generate(&Profile::tiny(), 81);
        let cfg = TrainConfig::quick(8, 15);

        let serial_plan = partition(&bow, 1, Algorithm::A1, 81);
        let rs = train_lda(&bow, &serial_plan, &cfg);
        assert_eq!(rs.p, 1);
        assert!((rs.eta - 1.0).abs() < 1e-12);

        let plan = partition(&bow, 4, Algorithm::A3 { restarts: 2 }, 81);
        let rp = train_lda(&bow, &plan, &cfg);
        assert_eq!(rp.p, 4);
        assert!(rp.speedup_model <= 4.0);
        // Perplexities comparable (Table IV behaviour).
        let rel = (rp.final_perplexity - rs.final_perplexity).abs() / rs.final_perplexity;
        assert!(rel < 0.1, "serial {} vs parallel {}", rs.final_perplexity, rp.final_perplexity);
        assert!(rp.tokens_per_sec > 0.0);
    }

    #[test]
    fn packed_schedule_through_driver_matches_diagonal() {
        use crate::scheduler::exec::ExecMode;
        use crate::scheduler::schedule::ScheduleKind;

        let bow = generate(&Profile::tiny(), 83);
        let plan = partition(&bow, 4, Algorithm::A3 { restarts: 2 }, 83);
        let mut cfg = TrainConfig::quick(8, 6);
        cfg.eval_every = 3;
        let diag = train_lda(&bow, &plan, &cfg);

        cfg.schedule = ScheduleKind::Packed { grid_factor: 2 };
        cfg.workers = 2;
        cfg.mode = ExecMode::Pooled;
        let packed = train_lda(&bow, &plan, &cfg);

        // Bit-identical training across schedules, modes, and W.
        assert_eq!(diag.final_perplexity, packed.final_perplexity);
        assert_eq!(diag.curve, packed.curve);
        assert_eq!(packed.workers, 2);
        assert_eq!(packed.schedule, "packed(x2)");
        assert!(packed.schedule_eta > 0.0 && packed.schedule_eta <= 1.0 + 1e-12);
        assert!(packed.speedup_model <= 2.0 + 1e-9, "bounded by W, not P");
        assert_eq!(diag.workers, 4);
        assert_eq!(diag.schedule, "diagonal");
        assert!((diag.schedule_eta - diag.eta).abs() < 1e-12);
    }

    #[test]
    fn kernels_through_driver_converge_together() {
        use crate::kernel::KernelKind;

        let bow = generate(&Profile::tiny(), 85);
        let plan = partition(&bow, 4, Algorithm::A3 { restarts: 2 }, 85);
        let mut cfg = TrainConfig::quick(8, 20);
        let dense = train_lda(&bow, &plan, &cfg);
        assert_eq!(dense.kernel, "dense");
        for kernel in [KernelKind::Sparse, KernelKind::Alias] {
            cfg.kernel = kernel;
            let r = train_lda(&bow, &plan, &cfg);
            assert_eq!(r.kernel, kernel.name());
            let rel = (r.final_perplexity - dense.final_perplexity).abs()
                / dense.final_perplexity;
            assert!(
                rel < 0.1,
                "{}: dense {} vs {} (rel {rel})",
                kernel.name(),
                dense.final_perplexity,
                r.final_perplexity
            );
        }
    }

    #[test]
    fn balance_modes_through_driver_are_bit_identical() {
        use crate::scheduler::adaptive::BalanceMode;
        use crate::scheduler::exec::ExecMode;
        use crate::scheduler::schedule::ScheduleKind;

        let bow = generate(&Profile::tiny(), 87);
        let plan = partition(&bow, 4, Algorithm::A3 { restarts: 2 }, 87);
        let mut cfg = TrainConfig::quick(8, 6);
        cfg.eval_every = 3;
        cfg.schedule = ScheduleKind::Packed { grid_factor: 2 };
        cfg.workers = 2;
        cfg.mode = ExecMode::Pooled;
        let baseline = train_lda(&bow, &plan, &cfg);
        assert_eq!(baseline.balance, "static");

        for (balance, label) in [
            (BalanceMode::Adaptive, "adaptive"),
            (BalanceMode::Steal, "steal"),
        ] {
            cfg.balance = balance;
            let r = train_lda(&bow, &plan, &cfg);
            assert_eq!(r.balance, label);
            // Balance modes move work between workers, never results.
            assert_eq!(r.final_perplexity, baseline.final_perplexity, "{label}");
            assert_eq!(r.curve, baseline.curve, "{label}");
            // Measured-η is a real Eq. 2 ratio on wallclock.
            assert!(
                r.measured_eta > 0.0 && r.measured_eta <= 1.0 + 1e-9,
                "{label}: measured_eta {}",
                r.measured_eta
            );
        }
    }

    #[test]
    fn commit_modes_through_driver_are_bit_identical() {
        use crate::scheduler::exec::{CommitMode, ExecMode};
        use crate::scheduler::schedule::ScheduleKind;

        let bow = generate(&Profile::tiny(), 91);
        let plan = partition(&bow, 4, Algorithm::A3 { restarts: 2 }, 91);
        let mut cfg = TrainConfig::quick(8, 6);
        cfg.eval_every = 3;
        cfg.schedule = ScheduleKind::Packed { grid_factor: 2 };
        cfg.workers = 2;
        cfg.mode = ExecMode::Pooled;
        let barrier = train_lda(&bow, &plan, &cfg);
        assert_eq!(barrier.commit, "barrier");
        let names: Vec<&str> = barrier.phases.iter().map(|(n, _)| n.as_str()).collect();
        assert!(!names.contains(&"commit"), "{names:?}");
        assert!(!names.contains(&"runahead"), "{names:?}");

        cfg.commit = CommitMode::Ticketed;
        let ticketed = train_lda(&bow, &plan, &cfg);
        assert_eq!(ticketed.commit, "ticketed");
        // The commit protocol moves work in time, never results.
        assert_eq!(ticketed.final_perplexity, barrier.final_perplexity);
        assert_eq!(ticketed.curve, barrier.curve);
        // Folds are metered into the new buckets instead of the barrier.
        let names: Vec<&str> = ticketed.phases.iter().map(|(n, _)| n.as_str()).collect();
        assert!(
            names.contains(&"commit") || names.contains(&"runahead"),
            "{names:?}"
        );
    }

    #[test]
    fn phase_breakdown_is_reported_for_parallel_runs() {
        let bow = generate(&Profile::tiny(), 88);
        let plan = partition(&bow, 3, Algorithm::A2, 88);
        let mut cfg = TrainConfig::quick(4, 4);
        cfg.eval_every = 2;
        let r = train_lda(&bow, &plan, &cfg);
        let names: Vec<&str> = r.phases.iter().map(|(n, _)| n.as_str()).collect();
        assert!(names.contains(&"sample"), "{names:?}");
        assert!(names.contains(&"barrier"), "{names:?}");
        assert!(names.contains(&"update"), "{names:?}");
        assert!(names.contains(&"perplexity"), "{names:?}");
        let sample_secs = r.phases.iter().find(|(n, _)| n == "sample").unwrap().1;
        assert!(sample_secs > 0.0);
        assert!(!r.phase_summary().is_empty());
        // Serial runs have no parallel phase machinery.
        let serial_plan = partition(&bow, 1, Algorithm::A1, 88);
        let rs = train_lda(&bow, &serial_plan, &cfg);
        assert!(rs.phases.is_empty());
        assert_eq!(rs.measured_eta, 1.0);
        assert_eq!(rs.balance, "static");
    }

    #[test]
    fn checkpointed_driver_run_resumes_bit_identically() {
        let bow = generate(&Profile::tiny(), 89);
        let plan = partition(&bow, 4, Algorithm::A3 { restarts: 2 }, 89);
        let mut cfg = TrainConfig::quick(8, 6);
        cfg.eval_every = 3;
        let oracle = train_lda(&bow, &plan, &cfg);
        assert_eq!(oracle.task_retries, 0);
        assert_eq!(oracle.io_retries, 0);

        // Run 4 of 6 sweeps with checkpoints every 2, as if interrupted.
        let root =
            std::env::temp_dir().join(format!("pplda-trainer-ckpt-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&root);
        cfg.iters = 4;
        cfg.checkpoint_every = 2;
        train_lda_checkpointed(&bow, &plan, &cfg, Some(&root), None);
        assert!(root.join("ckpt-2").is_dir(), "periodic checkpoint at sweep 2");
        assert!(root.join("ckpt-4").is_dir(), "periodic checkpoint at sweep 4");

        // Resume picks the latest checkpoint and finishes the run.
        cfg.iters = 6;
        cfg.checkpoint_every = 0;
        let resumed = train_lda_checkpointed(&bow, &plan, &cfg, None, Some(&root));
        assert_eq!(
            resumed.final_perplexity, oracle.final_perplexity,
            "resumed run is bit-identical to the uninterrupted one"
        );
        assert_eq!(resumed.curve.last(), oracle.curve.last());
        std::fs::remove_dir_all(&root).unwrap();
    }

    #[test]
    fn sigint_latch_checkpoints_and_stops_early() {
        let bow = generate(&Profile::tiny(), 93);
        let plan = partition(&bow, 4, Algorithm::A3 { restarts: 2 }, 93);
        let mut cfg = TrainConfig::quick(8, 6);
        cfg.eval_every = 3;
        let oracle = train_lda(&bow, &plan, &cfg);
        assert_eq!(oracle.interrupted_at, None);

        // Latch the (test-scoped) interrupt before training: the run
        // finishes exactly one sweep, commits a final checkpoint at it
        // (off the periodic cadence — checkpoint_every is 2), and
        // reports where it stopped.
        let root = std::env::temp_dir().join(format!("pplda-trainer-int-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&root);
        cfg.checkpoint_every = 2;
        interrupt::trigger();
        let stopped = train_lda_checkpointed(&bow, &plan, &cfg, Some(&root), None);
        interrupt::reset();
        assert_eq!(stopped.interrupted_at, Some(1));
        assert!(root.join("ckpt-1").is_dir(), "final interrupt checkpoint");

        // Resuming from the interrupt checkpoint completes the run
        // bit-identically to one that was never interrupted.
        cfg.checkpoint_every = 0;
        let resumed = train_lda_checkpointed(&bow, &plan, &cfg, None, Some(&root));
        assert_eq!(resumed.interrupted_at, None);
        assert_eq!(resumed.final_perplexity, oracle.final_perplexity);
        assert_eq!(resumed.curve.last(), oracle.curve.last());
        std::fs::remove_dir_all(&root).unwrap();
    }

    #[test]
    fn without_checkpointing_the_latch_is_ignored() {
        let bow = generate(&Profile::tiny(), 94);
        let plan = partition(&bow, 3, Algorithm::A2, 94);
        let cfg = TrainConfig::quick(4, 4);
        interrupt::trigger();
        let r = train_lda(&bow, &plan, &cfg);
        interrupt::reset();
        // No checkpoint cadence configured: the run completes normally.
        assert_eq!(r.interrupted_at, None);
    }

    #[test]
    fn train_end_snapshot_export_round_trips() {
        let bow = generate(&Profile::tiny(), 95);
        let cfg = TrainConfig::quick(8, 5);
        let dir = std::env::temp_dir();
        let pid = std::process::id();

        let serial_plan = partition(&bow, 1, Algorithm::A1, 95);
        let spath = dir.join(format!("pplda-trainer-snap-serial-{pid}.ppsnap"));
        train_lda_with_snapshot(&bow, &serial_plan, &cfg, None, None, None, Some(&spath));
        let snap = ModelSnapshot::load(&spath).expect("serial snapshot loads");
        assert_eq!(snap.k, cfg.topics);
        assert_eq!(snap.v, bow.num_words());
        assert_eq!(snap.seed, cfg.seed);
        std::fs::remove_file(&spath).unwrap();

        let plan = partition(&bow, 4, Algorithm::A3 { restarts: 2 }, 95);
        let ppath = dir.join(format!("pplda-trainer-snap-par-{pid}.ppsnap"));
        train_lda_with_snapshot(&bow, &plan, &cfg, None, None, None, Some(&ppath));
        let snap = ModelSnapshot::load(&ppath).expect("parallel snapshot loads");
        assert_eq!(snap.k, cfg.topics);
        assert_eq!(snap.v, bow.num_words());
        std::fs::remove_file(&ppath).unwrap();
    }

    #[test]
    fn curve_is_recorded() {
        let bow = generate(&Profile::tiny(), 82);
        let plan = partition(&bow, 2, Algorithm::A2, 82);
        let mut cfg = TrainConfig::quick(4, 10);
        cfg.eval_every = 5;
        let r = train_lda(&bow, &plan, &cfg);
        assert_eq!(r.curve.len(), 2);
        assert_eq!(r.curve[0].0, 5);
        assert_eq!(r.curve[1].0, 10);
    }
}
