//! Versioned, atomic training checkpoints for LDA and BoT.
//!
//! A checkpoint lives at `<root>/ckpt-<sweeps>/` and contains a text
//! `MANIFEST` plus one shard directory per phase — `lda/` for LDA, or
//! `dw/` + `dts/` for BoT — each holding the CRC32-checksummed
//! `part-*.blk` files of [`crate::corpus::shard::ShardStore`], stamped
//! with the completed sweep count. The manifest pins everything a
//! resume must agree on (kind, seed, topics, grid size, corpus shape)
//! and carries its own CRC32 trailer, so a torn or edited manifest is
//! refused just like a torn block.
//!
//! Commits are atomic: the whole checkpoint is built in a
//! `.tmp-ckpt-*` sibling directory and renamed into place (a crash
//! mid-commit leaves the previous checkpoint intact plus a temp dir
//! the next commit clears — never a torn `ckpt-*`). Resume re-reads
//! every block through the verified path and rebuilds the count
//! matrices by re-absorption; task RNG streams are keyed by
//! `(seed, sweep, partition)`, so a resumed run continues bit-identically
//! to one that never stopped. See `docs/fault_tolerance.md`.

use std::collections::HashMap;
use std::fmt::Write as _;
use std::path::{Path, PathBuf};

use crate::bot::parallel::ParallelBot;
use crate::bot::serial::BotHyper;
use crate::coordinator::config::TrainConfig;
use crate::corpus::bow::BagOfWords;
use crate::corpus::shard::ShardStore;
use crate::corpus::timestamps::TimestampedCorpus;
use crate::partition::Plan;
use crate::scheduler::exec::ParallelLda;
use crate::util::crc::crc32;
use crate::util::error::{bail, Context, Result};

/// Manifest file name inside a checkpoint directory.
pub const MANIFEST: &str = "MANIFEST";

/// First manifest line; bump the version when the layout changes so old
/// readers refuse new checkpoints (and vice versa) instead of
/// misparsing them.
const MAGIC_LINE: &str = "pplda-checkpoint v1";

/// Which trainer a checkpoint belongs to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CkptKind {
    Lda,
    Bot,
}

impl CkptKind {
    fn name(self) -> &'static str {
        match self {
            Self::Lda => "lda",
            Self::Bot => "bot",
        }
    }

    fn parse(s: &str) -> Result<Self> {
        match s {
            "lda" => Ok(Self::Lda),
            "bot" => Ok(Self::Bot),
            other => bail!("checkpoint manifest: unknown kind {other:?}"),
        }
    }
}

/// Everything a resume must agree on before any block is read. The
/// corpus shape (docs/words/tokens, plus stamps/DTS tokens for BoT)
/// guards against resuming onto a different corpus, which the sweep
/// stamps alone cannot catch.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Manifest {
    pub kind: CkptKind,
    /// Completed sweeps at checkpoint time — the resume coordinate.
    pub sweeps: usize,
    pub seed: u64,
    pub topics: usize,
    /// Grid size `P` (shared by both BoT plans).
    pub p: usize,
    pub docs: usize,
    pub words: usize,
    pub tokens: u64,
    /// BoT only: distinct timestamp count (0 for LDA).
    pub stamps: usize,
    /// BoT only: DTS token count (0 for LDA).
    pub dts_tokens: u64,
}

impl Manifest {
    /// The manifest an LDA run over `(bow, plan, cfg)` writes (and the
    /// one a resume of that run expects to find).
    pub fn lda(bow: &BagOfWords, plan: &Plan, cfg: &TrainConfig, sweeps: usize) -> Self {
        Self {
            kind: CkptKind::Lda,
            sweeps,
            seed: cfg.seed,
            topics: cfg.topics,
            p: plan.p,
            docs: bow.num_docs(),
            words: bow.num_words(),
            tokens: bow.num_tokens(),
            stamps: 0,
            dts_tokens: 0,
        }
    }

    /// The manifest a BoT run over `(tc, p, cfg)` writes.
    pub fn bot(tc: &TimestampedCorpus, p: usize, cfg: &TrainConfig, sweeps: usize) -> Self {
        Self {
            kind: CkptKind::Bot,
            sweeps,
            seed: cfg.seed,
            topics: cfg.topics,
            p,
            docs: tc.bow.num_docs(),
            words: tc.bow.num_words(),
            tokens: tc.bow.num_tokens(),
            stamps: tc.num_stamps,
            dts_tokens: tc.dts.num_tokens(),
        }
    }

    /// Serialize: magic line, `key=value` lines, then a `crc=` trailer
    /// (CRC32 over every preceding byte).
    pub fn render(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "{MAGIC_LINE}");
        let _ = writeln!(s, "kind={}", self.kind.name());
        let _ = writeln!(s, "sweeps={}", self.sweeps);
        let _ = writeln!(s, "seed={}", self.seed);
        let _ = writeln!(s, "topics={}", self.topics);
        let _ = writeln!(s, "p={}", self.p);
        let _ = writeln!(s, "docs={}", self.docs);
        let _ = writeln!(s, "words={}", self.words);
        let _ = writeln!(s, "tokens={}", self.tokens);
        let _ = writeln!(s, "stamps={}", self.stamps);
        let _ = writeln!(s, "dts_tokens={}", self.dts_tokens);
        let crc = crc32(s.as_bytes());
        let _ = writeln!(s, "crc={crc:08X}");
        s
    }

    /// Parse and verify a rendered manifest: the CRC trailer and magic
    /// line are checked before any field is trusted.
    pub fn parse(text: &str) -> Result<Self> {
        let Some(pos) = text.rfind("\ncrc=") else {
            bail!("checkpoint manifest: missing crc trailer");
        };
        let (body, trailer) = text.split_at(pos + 1);
        let stored = trailer
            .trim_end()
            .strip_prefix("crc=")
            .context("checkpoint manifest: malformed crc trailer")?;
        let stored = u32::from_str_radix(stored, 16)
            .context("checkpoint manifest: malformed crc trailer")?;
        let computed = crc32(body.as_bytes());
        if stored != computed {
            bail!(
                "checkpoint manifest corrupt: stored crc {stored:08X} != computed {computed:08X}"
            );
        }
        let mut lines = body.lines();
        match lines.next() {
            Some(MAGIC_LINE) => {}
            other => bail!(
                "not a {MAGIC_LINE:?} manifest (found {:?})",
                other.unwrap_or("")
            ),
        }
        let mut map = HashMap::new();
        for line in lines {
            if line.is_empty() {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .with_context(|| format!("checkpoint manifest: malformed line {line:?}"))?;
            map.insert(k, v);
        }
        let field = |k: &str| -> Result<&str> {
            map.get(k)
                .copied()
                .with_context(|| format!("checkpoint manifest: missing {k}"))
        };
        let num = |k: &str| -> Result<u64> {
            field(k)?
                .parse()
                .with_context(|| format!("checkpoint manifest: bad {k}"))
        };
        Ok(Self {
            kind: CkptKind::parse(field("kind")?)?,
            sweeps: num("sweeps")? as usize,
            seed: num("seed")?,
            topics: num("topics")? as usize,
            p: num("p")? as usize,
            docs: num("docs")? as usize,
            words: num("words")? as usize,
            tokens: num("tokens")?,
            stamps: num("stamps")? as usize,
            dts_tokens: num("dts_tokens")?,
        })
    }

    /// Load and verify the manifest inside checkpoint directory `dir`.
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join(MANIFEST);
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read checkpoint manifest {}", path.display()))?;
        Self::parse(&text)
    }

    /// Refuse a resume whose run parameters disagree with the
    /// checkpoint's. Every field except `sweeps` (the resume coordinate
    /// itself) must match.
    pub fn validate(&self, expected: &Self) -> Result<()> {
        fn check<T: PartialEq + std::fmt::Display>(
            name: &str,
            stored: T,
            expected: T,
        ) -> Result<()> {
            if stored != expected {
                bail!("checkpoint {name} {stored} does not match the run's {name} {expected}");
            }
            Ok(())
        }
        check("kind", self.kind.name(), expected.kind.name())?;
        check("seed", self.seed, expected.seed)?;
        check("topics", self.topics, expected.topics)?;
        check("p", self.p, expected.p)?;
        check("docs", self.docs, expected.docs)?;
        check("words", self.words, expected.words)?;
        check("tokens", self.tokens, expected.tokens)?;
        check("stamps", self.stamps, expected.stamps)?;
        check("dts_tokens", self.dts_tokens, expected.dts_tokens)?;
        Ok(())
    }
}

/// The directory a checkpoint at `sweeps` completed sweeps commits to.
pub fn dir_for(root: &Path, sweeps: usize) -> PathBuf {
    root.join(format!("ckpt-{sweeps}"))
}

/// The highest-sweep committed checkpoint under `root`, if any.
/// Directories without a manifest (including `.tmp-ckpt-*` leftovers a
/// crash abandoned) are ignored.
pub fn latest(root: &Path) -> Option<PathBuf> {
    let entries = std::fs::read_dir(root).ok()?;
    let mut best: Option<(usize, PathBuf)> = None;
    for e in entries.flatten() {
        let name = e.file_name();
        let Some(name) = name.to_str() else { continue };
        let Some(n) = name.strip_prefix("ckpt-").and_then(|s| s.parse::<usize>().ok()) else {
            continue;
        };
        let path = e.path();
        if !path.join(MANIFEST).is_file() {
            continue;
        }
        let better = match &best {
            Some((b, _)) => n > *b,
            None => true,
        };
        if better {
            best = Some((n, path));
        }
    }
    best.map(|(_, p)| p)
}

/// Resolve a user-supplied resume path: a checkpoint directory itself
/// (contains a manifest), or a checkpoint *root*, in which case the
/// latest committed checkpoint under it is picked.
pub fn resolve(path: &Path) -> Result<PathBuf> {
    if path.join(MANIFEST).is_file() {
        return Ok(path.to_path_buf());
    }
    latest(path).with_context(|| format!("no checkpoint found under {}", path.display()))
}

/// Removes the in-progress temp directory on every error path, so a
/// failed commit never leaves a half-built checkpoint for `latest` (or
/// a human) to trip over.
struct TmpDir {
    path: PathBuf,
    armed: bool,
}

impl Drop for TmpDir {
    fn drop(&mut self) {
        if self.armed {
            let _ = std::fs::remove_dir_all(&self.path);
        }
    }
}

/// Build a checkpoint in a temp sibling via `build`, then rename it
/// into `ckpt-<sweeps>` — the one atomic-commit implementation both
/// trainers share.
fn commit(root: &Path, sweeps: usize, build: impl FnOnce(&Path) -> Result<()>) -> Result<PathBuf> {
    std::fs::create_dir_all(root)
        .with_context(|| format!("create checkpoint root {}", root.display()))?;
    let tmp = root.join(format!(".tmp-ckpt-{sweeps}"));
    if tmp.exists() {
        std::fs::remove_dir_all(&tmp).context("clear stale checkpoint temp dir")?;
    }
    std::fs::create_dir_all(&tmp)?;
    let mut guard = TmpDir { path: tmp.clone(), armed: true };
    build(&tmp)?;
    let dst = dir_for(root, sweeps);
    if dst.exists() {
        // Re-checkpointing the same sweep (e.g. a rerun) replaces it.
        std::fs::remove_dir_all(&dst).context("replace existing checkpoint")?;
    }
    std::fs::rename(&tmp, &dst)
        .with_context(|| format!("commit checkpoint {}", dst.display()))?;
    guard.armed = false;
    Ok(dst)
}

/// Commit an LDA checkpoint of `lda`'s current state under `root`.
/// `manifest.sweeps` must equal the trainer's completed sweep count
/// (checkpoints are taken between sweeps, where the at-rest block
/// stamps equal it). Returns the committed directory.
pub fn write_lda(lda: &ParallelLda, manifest: &Manifest, root: &Path) -> Result<PathBuf> {
    assert_eq!(manifest.kind, CkptKind::Lda);
    assert_eq!(manifest.sweeps, lda.sweeps_done(), "checkpoint between sweeps only");
    commit(root, manifest.sweeps, |tmp| {
        let mut store = ShardStore::create(tmp.join("lda"))?;
        lda.export_blocks(&store)?;
        store.keep();
        std::fs::write(tmp.join(MANIFEST), manifest.render())
            .context("write checkpoint manifest")?;
        Ok(())
    })
}

/// Commit a BoT checkpoint (both phases) under `root` — the BoT
/// counterpart of [`write_lda`], with `dw/` and `dts/` shard dirs.
pub fn write_bot(bot: &ParallelBot, manifest: &Manifest, root: &Path) -> Result<PathBuf> {
    assert_eq!(manifest.kind, CkptKind::Bot);
    assert_eq!(manifest.sweeps, bot.sweeps_done(), "checkpoint between sweeps only");
    commit(root, manifest.sweeps, |tmp| {
        let mut dw = ShardStore::create(tmp.join("dw"))?;
        let mut dts = ShardStore::create(tmp.join("dts"))?;
        bot.export_blocks(&dw, &dts)?;
        dw.keep();
        dts.keep();
        std::fs::write(tmp.join(MANIFEST), manifest.render())
            .context("write checkpoint manifest")?;
        Ok(())
    })
}

/// Resume an LDA trainer from `path` (a checkpoint directory or a
/// checkpoint root — see [`resolve`]): verify the manifest against the
/// run's parameters, verified-read every block, and return the rebuilt
/// trainer plus its completed sweep count.
pub fn resume_lda(
    bow: &BagOfWords,
    plan: &Plan,
    cfg: &TrainConfig,
    path: &Path,
) -> Result<(ParallelLda, usize)> {
    let dir = resolve(path)?;
    let m = Manifest::load(&dir)?;
    m.validate(&Manifest::lda(bow, plan, cfg, m.sweeps))?;
    let store = ShardStore::open(dir.join("lda"))?;
    let lda = ParallelLda::resume_from_store(
        bow,
        plan,
        cfg.topics,
        cfg.alpha,
        cfg.beta,
        cfg.seed,
        cfg.schedule,
        cfg.resolved_workers(plan.p),
        &store,
        m.sweeps,
        cfg.residency,
    )?;
    Ok((lda, m.sweeps))
}

/// Resume a BoT trainer from `path` — the BoT counterpart of
/// [`resume_lda`] (the caller rebuilds the DW/DTS plans, which are
/// deterministic in the corpus and seed).
pub fn resume_bot(
    tc: &TimestampedCorpus,
    plan_dw: &Plan,
    plan_dts: &Plan,
    h: BotHyper,
    cfg: &TrainConfig,
    path: &Path,
) -> Result<(ParallelBot, usize)> {
    let dir = resolve(path)?;
    let m = Manifest::load(&dir)?;
    m.validate(&Manifest::bot(tc, plan_dw.p, cfg, m.sweeps))?;
    let dw = ShardStore::open(dir.join("dw"))?;
    let dts = ShardStore::open(dir.join("dts"))?;
    let bot = ParallelBot::resume_from_store(
        tc,
        plan_dw,
        plan_dts,
        h,
        cfg.seed,
        cfg.schedule,
        cfg.resolved_workers(plan_dw.p),
        &dw,
        &dts,
        m.sweeps,
        cfg.residency,
    )?;
    Ok((bot, m.sweeps))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::synthetic::{generate, Profile};
    use crate::partition::{partition, Algorithm};
    use crate::scheduler::exec::ExecMode;

    fn sample_manifest() -> Manifest {
        Manifest {
            kind: CkptKind::Bot,
            sweeps: 12,
            seed: 42,
            topics: 8,
            p: 4,
            docs: 120,
            words: 300,
            tokens: 4567,
            stamps: 10,
            dts_tokens: 480,
        }
    }

    fn temp_root(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("pplda-ckpt-test-{}-{tag}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn manifest_roundtrips() {
        let m = sample_manifest();
        assert_eq!(Manifest::parse(&m.render()).unwrap(), m);
        let lda = Manifest {
            kind: CkptKind::Lda,
            stamps: 0,
            dts_tokens: 0,
            ..m
        };
        assert_eq!(Manifest::parse(&lda.render()).unwrap(), lda);
    }

    #[test]
    fn tampered_manifests_are_refused() {
        let good = sample_manifest().render();
        // Any edited field breaks the crc trailer.
        let tampered = good.replace("sweeps=12", "sweeps=13");
        let e = Manifest::parse(&tampered).unwrap_err().to_string();
        assert!(e.contains("corrupt"), "{e}");
        // Wrong magic/version is refused even with a valid crc shape.
        let other = good.replace("pplda-checkpoint v1", "pplda-checkpoint v9");
        let e = Manifest::parse(&other).unwrap_err().to_string();
        assert!(e.contains("corrupt") || e.contains("manifest"), "{e}");
        // Truncation loses the trailer.
        let e = Manifest::parse(&good[..good.len() / 2]).unwrap_err().to_string();
        assert!(e.contains("crc"), "{e}");
    }

    #[test]
    fn validate_refuses_mismatched_runs() {
        let m = sample_manifest();
        assert!(m.validate(&m).is_ok());
        let mut sweeps_only = m.clone();
        sweeps_only.sweeps = 99;
        assert!(m.validate(&sweeps_only).is_ok(), "sweeps is the resume coordinate, not pinned");
        let mut wrong = m.clone();
        wrong.topics = 16;
        let e = m.validate(&wrong).unwrap_err().to_string();
        assert!(e.contains("topics"), "{e}");
        let mut wrong = m.clone();
        wrong.kind = CkptKind::Lda;
        let e = m.validate(&wrong).unwrap_err().to_string();
        assert!(e.contains("kind"), "{e}");
        let mut wrong = m;
        wrong.seed = 7;
        let e = wrong.validate(&sample_manifest()).unwrap_err().to_string();
        assert!(e.contains("seed"), "{e}");
    }

    #[test]
    fn latest_scans_committed_checkpoints_only() {
        let root = temp_root("latest");
        assert!(latest(&root).is_none(), "missing root has no checkpoints");
        for sweeps in [2usize, 10, 4] {
            let dir = dir_for(&root, sweeps);
            std::fs::create_dir_all(&dir).unwrap();
            std::fs::write(dir.join(MANIFEST), sample_manifest().render()).unwrap();
        }
        // Abandoned temp dirs and junk are ignored.
        std::fs::create_dir_all(root.join(".tmp-ckpt-99")).unwrap();
        std::fs::create_dir_all(root.join("ckpt-77")).unwrap(); // no manifest
        std::fs::create_dir_all(root.join("notes")).unwrap();
        assert_eq!(latest(&root).unwrap(), dir_for(&root, 10));
        assert_eq!(resolve(&root).unwrap(), dir_for(&root, 10));
        // A checkpoint dir resolves to itself.
        assert_eq!(resolve(&dir_for(&root, 2)).unwrap(), dir_for(&root, 2));
        let empty = root.join("notes");
        let e = resolve(&empty).unwrap_err().to_string();
        assert!(e.contains("no checkpoint"), "{e}");
        std::fs::remove_dir_all(&root).unwrap();
    }

    #[test]
    fn lda_checkpoint_write_resume_roundtrip() {
        let root = temp_root("lda-rt");
        let bow = generate(&Profile::tiny(), 125);
        let plan = partition(&bow, 4, Algorithm::A3 { restarts: 2 }, 125);
        let mut cfg = TrainConfig::quick(8, 4);
        cfg.seed = 125;
        let mut oracle = ParallelLda::init(&bow, &plan, 8, 0.5, 0.1, 125);
        for _ in 0..4 {
            oracle.sweep(ExecMode::Sequential);
        }
        let mut lda = ParallelLda::init(&bow, &plan, 8, 0.5, 0.1, 125);
        for _ in 0..2 {
            lda.sweep(ExecMode::Sequential);
        }
        let dir = write_lda(&lda, &Manifest::lda(&bow, &plan, &cfg, 2), &root).unwrap();
        assert_eq!(dir, dir_for(&root, 2));
        assert!(root.join(".tmp-ckpt-2").metadata().is_err(), "temp dir committed away");
        drop(lda);

        // Wrong run parameters are refused up front.
        let mut wrong = cfg;
        wrong.topics = 16;
        let e = resume_lda(&bow, &plan, &wrong, &root).unwrap_err().to_string();
        assert!(e.contains("topics"), "{e}");

        let (mut resumed, sweeps) = resume_lda(&bow, &plan, &cfg, &root).unwrap();
        assert_eq!(sweeps, 2);
        for _ in 0..2 {
            resumed.sweep(ExecMode::Sequential);
        }
        assert_eq!(resumed.counts.doc_topic, oracle.counts.doc_topic);
        assert_eq!(resumed.counts.word_topic, oracle.counts.word_topic);
        assert_eq!(resumed.counts.topic, oracle.counts.topic);
        // The checkpoint survives the resume (re-resumable).
        assert!(dir_for(&root, 2).join(MANIFEST).is_file());
        std::fs::remove_dir_all(&root).unwrap();
    }

    #[test]
    fn failed_commit_leaves_no_temp_dir() {
        let root = temp_root("fail");
        let e = commit(&root, 5, |_tmp| bail!("boom")).unwrap_err().to_string();
        assert_eq!(e, "boom");
        assert!(root.join(".tmp-ckpt-5").metadata().is_err(), "temp dir cleaned up");
        assert!(latest(&root).is_none());
        std::fs::remove_dir_all(&root).unwrap();
    }

    #[test]
    fn bot_checkpoint_write_resume_roundtrip() {
        use crate::corpus::synthetic::{generate_timestamped, TimeProfile};
        let root = temp_root("bot-rt");
        let mut prof = Profile::tiny();
        prof.time = Some(TimeProfile {
            first_year: 2000,
            last_year: 2009,
            growth: 0.1,
            stamps_per_doc: 4,
        });
        let tc = generate_timestamped(&prof, 126);
        let plan_dw = partition(&tc.bow, 4, Algorithm::A3 { restarts: 2 }, 126);
        let plan_dts = partition(&tc.dts, 4, Algorithm::A3 { restarts: 2 }, 127);
        let h = BotHyper::new(8, 0.5, 0.1, 0.1, tc.bow.num_words(), tc.num_stamps);
        let mut cfg = TrainConfig::quick(8, 4);
        cfg.seed = 126;
        let mut oracle = ParallelBot::init(&tc, &plan_dw, &plan_dts, h, 126);
        for _ in 0..4 {
            oracle.sweep(ExecMode::Sequential);
        }
        let mut bot = ParallelBot::init(&tc, &plan_dw, &plan_dts, h, 126);
        for _ in 0..2 {
            bot.sweep(ExecMode::Sequential);
        }
        write_bot(&bot, &Manifest::bot(&tc, 4, &cfg, 2), &root).unwrap();
        drop(bot);

        let (mut resumed, sweeps) = resume_bot(&tc, &plan_dw, &plan_dts, h, &cfg, &root).unwrap();
        assert_eq!(sweeps, 2);
        for _ in 0..2 {
            resumed.sweep(ExecMode::Sequential);
        }
        assert_eq!(resumed.counts.doc_topic, oracle.counts.doc_topic);
        assert_eq!(resumed.counts.word_topic, oracle.counts.word_topic);
        assert_eq!(resumed.counts.stamp_topic, oracle.counts.stamp_topic);
        assert_eq!(resumed.counts.topic_words, oracle.counts.topic_words);
        assert_eq!(resumed.counts.topic_stamps, oracle.counts.topic_stamps);
        std::fs::remove_dir_all(&root).unwrap();
    }
}
