//! Structured training reports (JSON/TSV emitters for EXPERIMENTS.md).

use crate::util::json::Json;
use crate::util::tsv::Table;

#[derive(Clone, Debug)]
pub struct TrainReport {
    pub algorithm: String,
    pub backend: String,
    /// Grid size `P` of the partition plan.
    pub p: usize,
    /// Worker count `W` the sweeps executed on (1 for serial; == `p` for
    /// pure diagonal execution).
    pub workers: usize,
    /// Schedule label: "serial", "diagonal", or "packed(xg)".
    pub schedule: String,
    /// Sampling kernel label: "dense", "sparse", or "alias" ("dense"
    /// for the serial reference and the XLA backend).
    pub kernel: String,
    /// Balance-mode label: "static", "adaptive", or "steal" ("static"
    /// for the serial reference and the XLA backend).
    pub balance: String,
    /// Commit-protocol label: "barrier" or "ticketed" ("barrier" for the
    /// serial reference and the XLA backend).
    pub commit: String,
    /// Residency label: "in-core" or "spill(<budget>)" ("in-core" for
    /// the serial reference and the XLA backend).
    pub residency: String,
    pub topics: usize,
    pub iters: usize,
    /// (iteration, perplexity) curve.
    pub curve: Vec<(usize, f64)>,
    pub final_perplexity: f64,
    /// Load-balancing ratio of the plan at `P` workers (1.0 for serial).
    pub eta: f64,
    /// Schedule-aware η against `workers` (== `eta` for diagonal runs).
    pub schedule_eta: f64,
    /// Measured (wallclock) η over all executed sweeps at `workers`
    /// (1.0 for serial/XLA). Reported next to the token-count
    /// `schedule_eta` so the non-uniform-cost gap is visible — see
    /// `crate::scheduler::cost_model::MeasuredReport`.
    pub measured_eta: f64,
    /// η·W model speedup against the workers actually used.
    pub speedup_model: f64,
    /// Total train wall seconds.
    pub train_secs: f64,
    /// Native serial-equivalent sampling throughput (tokens/sec over all
    /// sampled tokens and wall time).
    pub tokens_per_sec: f64,
    /// Phase breakdown `(name, seconds)` —
    /// sample/barrier/update/perplexity buckets from the trainer's
    /// `PhaseTimer` (empty for serial/XLA runs).
    pub phases: Vec<(String, f64)>,
    /// Sampling tasks re-executed after a contained worker panic over
    /// the whole run (0 in a fault-free run) — see
    /// `docs/fault_tolerance.md`.
    pub task_retries: u64,
    /// Transient spill-IO retries absorbed over the whole run (0 when
    /// in-core or fault-free).
    pub io_retries: u64,
    /// `Some(sweep)` when the run stopped early at a graceful-interrupt
    /// checkpoint (SIGINT with `--checkpoint-every` set) instead of
    /// completing all `iters` sweeps — see `crate::util::interrupt`.
    pub interrupted_at: Option<usize>,
}

impl TrainReport {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("algorithm", self.algorithm.as_str())
            .set("backend", self.backend.as_str())
            .set("p", self.p)
            .set("workers", self.workers)
            .set("schedule", self.schedule.as_str())
            .set("kernel", self.kernel.as_str())
            .set("balance", self.balance.as_str())
            .set("commit", self.commit.as_str())
            .set("residency", self.residency.as_str())
            .set("topics", self.topics)
            .set("iters", self.iters)
            .set("final_perplexity", self.final_perplexity)
            .set("eta", self.eta)
            .set("schedule_eta", self.schedule_eta)
            .set("measured_eta", self.measured_eta)
            .set("speedup_model", self.speedup_model)
            .set("train_secs", self.train_secs)
            .set("tokens_per_sec", self.tokens_per_sec)
            .set("task_retries", self.task_retries)
            .set("io_retries", self.io_retries)
            .set("interrupted_at", match self.interrupted_at {
                Some(it) => Json::from(it),
                None => Json::Null,
            })
            .set("phases", {
                let mut ph = Json::obj();
                for (name, secs) in &self.phases {
                    ph.set(name, *secs);
                }
                ph
            })
            .set(
                "curve",
                Json::Arr(
                    self.curve
                        .iter()
                        .map(|&(it, p)| {
                            let mut o = Json::obj();
                            o.set("iter", it).set("perplexity", p);
                            o
                        })
                        .collect(),
                ),
            );
        j
    }

    /// Perplexity curve as a two-column table.
    pub fn curve_table(&self) -> Table {
        let mut t = Table::new(["iter", "perplexity"]);
        for &(it, p) in &self.curve {
            t.row([it.to_string(), format!("{p:.4}")]);
        }
        t
    }

    /// Human-readable phase breakdown, e.g.
    /// `sample: 1.200s (80.0%), barrier: 0.300s (20.0%)` (empty string
    /// when no phases were recorded).
    pub fn phase_summary(&self) -> String {
        let total: f64 = self.phases.iter().map(|(_, s)| s).sum();
        let total = total.max(1e-12);
        self.phases
            .iter()
            .map(|(n, s)| format!("{n}: {s:.3}s ({:.1}%)", 100.0 * s / total))
            .collect::<Vec<_>>()
            .join(", ")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> TrainReport {
        TrainReport {
            algorithm: "A3".into(),
            backend: "native".into(),
            p: 10,
            workers: 10,
            schedule: "diagonal".into(),
            kernel: "sparse".into(),
            balance: "adaptive".into(),
            commit: "ticketed".into(),
            residency: "in-core".into(),
            topics: 64,
            iters: 50,
            curve: vec![(25, 700.0), (50, 600.5)],
            final_perplexity: 600.5,
            eta: 0.98,
            schedule_eta: 0.98,
            measured_eta: 0.91,
            speedup_model: 9.8,
            train_secs: 1.25,
            tokens_per_sec: 1e7,
            phases: vec![("sample".into(), 1.0), ("barrier".into(), 0.25)],
            task_retries: 1,
            io_retries: 2,
            interrupted_at: None,
        }
    }

    #[test]
    fn json_contains_key_fields() {
        let s = sample().to_json().to_string();
        assert!(s.contains("\"algorithm\":\"A3\""));
        assert!(s.contains("\"eta\":0.98"));
        assert!(s.contains("\"workers\":10"));
        assert!(s.contains("\"schedule\":\"diagonal\""));
        assert!(s.contains("\"kernel\":\"sparse\""));
        assert!(s.contains("\"balance\":\"adaptive\""));
        assert!(s.contains("\"commit\":\"ticketed\""));
        assert!(s.contains("\"residency\":\"in-core\""));
        assert!(s.contains("\"schedule_eta\":0.98"));
        assert!(s.contains("\"measured_eta\":0.91"));
        assert!(s.contains("\"phases\":{"));
        assert!(s.contains("\"sample\":1"));
        assert!(s.contains("\"curve\":[{"));
        assert!(s.contains("\"task_retries\":1"));
        assert!(s.contains("\"io_retries\":2"));
        assert!(s.contains("\"interrupted_at\":null"));
    }

    #[test]
    fn phase_summary_formats_percentages() {
        let s = sample().phase_summary();
        assert!(s.contains("sample: 1.000s (80.0%)"), "{s}");
        assert!(s.contains("barrier: 0.250s (20.0%)"), "{s}");
        let empty = TrainReport {
            phases: Vec::new(),
            ..sample()
        };
        assert_eq!(empty.phase_summary(), "");
    }

    #[test]
    fn curve_table_rows() {
        let t = sample().curve_table();
        assert_eq!(t.num_rows(), 2);
        assert_eq!(t.cell(1, 1), "600.5000");
    }
}
