//! The serve path: production-facing inference over a trained model.
//!
//! - [`snapshot`] — the crash-safe `PPSNAP1` immutable model format
//!   (CRC'd sections, temp-then-rename publish, typed
//!   [`snapshot::SnapshotError`] rejection, atomic hot-reload support).
//! - [`engine`] — exact O(1)-per-token fold-in Gibbs sampling against a
//!   frozen snapshot, deterministic given `(snapshot, request id)`.
//! - [`server`] — the batched [`server::QueryServer`]: bounded
//!   admission, micro-batching worker pool, deadlines, graceful
//!   degradation, panic containment, hot reload, graceful drain.
//! - [`metrics`] — serve-side latency/outcome metrics on the `obs`
//!   primitives.
//! - [`net`] — the JSON-lines TCP front end (`pplda serve`) and client.
//!
//! Design rationale and the robustness state machine are documented in
//! `docs/serving.md`.

pub mod engine;
pub mod metrics;
pub mod net;
pub mod server;
pub mod snapshot;
