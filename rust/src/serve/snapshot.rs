//! Crash-safe immutable model snapshots (`PPSNAP1`).
//!
//! A snapshot freezes a trained model's word–topic state into a single
//! versioned file the serve path can load read-only: the `n_wk`/`n_k`
//! counts plus *precomputed per-word alias tables* over the word-topic
//! conditional φ_wt = (n_wk+β)/(n_k+Vβ), so fold-in sampling is O(1) per
//! token with no per-query table construction. Because the model is
//! frozen at serve time the tables are never stale — unlike the training
//! alias kernel there is no Metropolis–Hastings correction anywhere on
//! the serve path; draws from the mixture are exact.
//!
//! Integrity follows the spill-v3 playbook ([`crate::corpus::shard`]):
//! a magic/version header, a CRC32 per section plus one over the header
//! itself, explicit length accounting (truncation is detected before any
//! section is parsed), and temp-then-rename publication so a crash
//! mid-export can never leave a half-written file at the published path.
//! Every rejection is a typed [`SnapshotError`]; the hot-reload path in
//! [`crate::serve::server`] relies on load being all-or-nothing to keep
//! the old snapshot serving when a candidate is torn or corrupt.
//!
//! ## Layout (all little-endian)
//!
//! ```text
//! offset  size      field
//! 0       8         magic  b"PPSNAP1\0"
//! 8       4         kind   (0 = LDA)
//! 12      4         K      topics
//! 16      8         V      vocabulary size
//! 24      8         seed   training seed (keys per-request RNG streams)
//! 32      4         alpha  (f32)
//! 36      4         beta   (f32)
//! 40      16        section CRC32s: n_wk, n_k, prob, alias
//! 56      4         CRC32 of bytes [0, 56)
//! 60      V*K*4     n_wk   u32, word-major
//! ..      K*4       n_k    u32
//! ..      V*K*8     prob   f64, per-word alias-table probabilities
//! ..      V*K*4     alias  u32, per-word alias-table aliases
//! ```
//!
//! `wtotal[w] = α·Σ_t φ_wt` and the per-topic denominators are *derived*
//! at load from the checksummed counts (a pure function of them), so
//! they need no bytes and cannot disagree with the counts they summarize.

use crate::gibbs::counts::LdaCounts;
use crate::util::alias::AliasTable;
use crate::util::crc::crc32;
use crate::util::fault::{self, sites, FaultKind};
use std::fmt;
use std::io::Write as _;
use std::path::{Path, PathBuf};

/// Size of the fixed header in bytes.
const HEADER_LEN: usize = 60;
const MAGIC: &[u8; 8] = b"PPSNAP1\0";
/// Magic prefix shared by all snapshot versions; a file starting with it
/// but not matching [`MAGIC`] is a version mismatch, not garbage.
const MAGIC_STEM: &[u8; 6] = b"PPSNAP";
const KIND_LDA: u32 = 0;
/// Transient-IO retry budget for loads, matching the shard store's.
const MAX_IO_ATTEMPTS: u32 = 3;

/// Typed rejection from snapshot IO — the serve path switches on these
/// to decide between "retry", "keep the old snapshot", and "refuse to
/// start".
#[derive(Debug)]
pub enum SnapshotError {
    /// Underlying IO failure (`op` names the operation).
    Io { path: PathBuf, op: &'static str, source: std::io::Error },
    /// File shorter than its header-implied size (torn write/read).
    Truncated { path: PathBuf, len: u64, expected: u64 },
    /// Leading bytes are not a snapshot magic at all.
    BadMagic { path: PathBuf },
    /// Snapshot magic stem with an unknown version marker.
    BadVersion { path: PathBuf, found: String },
    /// A section's bytes don't match their checksum, or decode to
    /// out-of-range values.
    Corrupt { path: PathBuf, section: &'static str },
    /// Valid snapshot, wrong shape for this server (hot-reload with a
    /// different K/V than the snapshot currently serving).
    Mismatch { path: PathBuf, detail: String },
}

impl fmt::Display for SnapshotError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Self::Io { path, op, source } => {
                write!(f, "snapshot {op} {}: {source}", path.display())
            }
            Self::Truncated { path, len, expected } => write!(
                f,
                "snapshot {} truncated: {len} bytes, expected {expected}",
                path.display()
            ),
            Self::BadMagic { path } => {
                write!(f, "snapshot {}: bad magic", path.display())
            }
            Self::BadVersion { path, found } => write!(
                f,
                "snapshot {}: unsupported version {found:?} (expected PPSNAP1)",
                path.display()
            ),
            Self::Corrupt { path, section } => write!(
                f,
                "snapshot {}: corrupt {section} section",
                path.display()
            ),
            Self::Mismatch { path, detail } => {
                write!(f, "snapshot {}: shape mismatch: {detail}", path.display())
            }
        }
    }
}

impl std::error::Error for SnapshotError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Self::Io { source, .. } => Some(source),
            _ => None,
        }
    }
}

impl SnapshotError {
    /// Stable lower-case tag for logs/metrics/wire replies.
    pub fn tag(&self) -> &'static str {
        match self {
            Self::Io { .. } => "io",
            Self::Truncated { .. } => "truncated",
            Self::BadMagic { .. } => "bad-magic",
            Self::BadVersion { .. } => "bad-version",
            Self::Corrupt { .. } => "corrupt",
            Self::Mismatch { .. } => "mismatch",
        }
    }
}

/// Is a load-time IO failure worth retrying? (Mirrors the shard store:
/// everything except `NotFound`, which a retry cannot fix.)
fn retryable(e: &SnapshotError) -> bool {
    matches!(
        e,
        SnapshotError::Io { source, .. }
            if source.kind() != std::io::ErrorKind::NotFound
    )
}

/// An immutable trained model, ready to answer fold-in queries.
///
/// Shared read-only behind an `Arc` by the serve worker pool; all
/// mutable per-request state lives in [`crate::serve::engine`] scratch.
pub struct ModelSnapshot {
    pub k: usize,
    pub v: usize,
    /// Training seed; keys the per-request RNG streams so replies are a
    /// pure function of (snapshot, request id).
    pub seed: u64,
    pub alpha: f32,
    pub beta: f32,
    /// Word–topic counts, word-major `[V][K]`.
    pub n_wk: Vec<u32>,
    /// Per-topic totals `[K]`.
    pub n_k: Vec<u32>,
    /// Per-word alias tables over φ_wt (one table of K buckets per word).
    pub tables: Vec<AliasTable>,
    /// Word-bucket mass `α·Σ_t φ_wt` per word, derived from the counts.
    pub wtotal: Vec<f64>,
    /// `1 / (n_k[t] + V·β)` per topic, derived — φ_wt on demand is one
    /// add and one multiply.
    pub inv_denom: Vec<f64>,
}

impl ModelSnapshot {
    /// Freeze trained counts into a snapshot. `word_topic` counts are
    /// exact integers stored as f32 (< 2^24 by the training invariant),
    /// so the u32 cast is lossless.
    pub fn from_counts(counts: &LdaCounts, alpha: f32, beta: f32, seed: u64) -> Self {
        let k = counts.k;
        let v = counts.num_words;
        let mut n_wk = Vec::with_capacity(v * k);
        for &c in &counts.word_topic {
            debug_assert!(c >= 0.0 && c.fract() == 0.0, "non-integral count {c}");
            n_wk.push(c as u32);
        }
        let n_k = counts.topic.clone();
        Self::assemble(k, v, seed, alpha, beta, n_wk, n_k)
    }

    /// Build the derived state and per-word tables from raw counts.
    fn assemble(
        k: usize,
        v: usize,
        seed: u64,
        alpha: f32,
        beta: f32,
        n_wk: Vec<u32>,
        n_k: Vec<u32>,
    ) -> Self {
        let (inv_denom, wtotal) = derive(&n_wk, &n_k, k, v, alpha, beta);
        let mut weights = vec![0.0f64; k];
        let tables = (0..v)
            .map(|w| {
                phi_row(&n_wk[w * k..(w + 1) * k], &inv_denom, beta, &mut weights);
                AliasTable::new(&weights)
            })
            .collect();
        Self { k, v, seed, alpha, beta, n_wk, n_k, tables, wtotal, inv_denom }
    }

    /// φ_wt for one (word, topic) pair.
    #[inline]
    pub fn phi(&self, w: usize, t: usize) -> f64 {
        (self.n_wk[w * self.k + t] as f64 + self.beta as f64) * self.inv_denom[t]
    }

    /// Atomically publish to `path`: write a sibling temp file, fsync,
    /// rename. A crash at any point leaves either the old file or a
    /// `.tmp` orphan — never a torn snapshot at the published path.
    pub fn write(&self, path: &Path) -> Result<(), SnapshotError> {
        let io = |op: &'static str| {
            let p = path.to_path_buf();
            move |e: std::io::Error| SnapshotError::Io { path: p, op, source: e }
        };
        let bytes = self.encode();
        let tmp = tmp_path(path);
        let guard = TmpGuard(&tmp);
        let mut f = std::fs::File::create(&tmp).map_err(io("create"))?;
        f.write_all(&bytes).map_err(io("write"))?;
        f.sync_all().map_err(io("sync"))?;
        drop(f);
        std::fs::rename(&tmp, path).map_err(io("rename"))?;
        std::mem::forget(guard);
        Ok(())
    }

    /// Serialize to the PPSNAP1 byte layout.
    fn encode(&self) -> Vec<u8> {
        let (k, v) = (self.k, self.v);
        let mut n_wk = Vec::with_capacity(v * k * 4);
        for &c in &self.n_wk {
            n_wk.extend_from_slice(&c.to_le_bytes());
        }
        let mut n_k = Vec::with_capacity(k * 4);
        for &c in &self.n_k {
            n_k.extend_from_slice(&c.to_le_bytes());
        }
        let mut prob = Vec::with_capacity(v * k * 8);
        let mut alias = Vec::with_capacity(v * k * 4);
        for table in &self.tables {
            let (p, a) = table.parts();
            for &x in p {
                prob.extend_from_slice(&x.to_le_bytes());
            }
            for &x in a {
                alias.extend_from_slice(&x.to_le_bytes());
            }
        }
        let mut out =
            Vec::with_capacity(HEADER_LEN + n_wk.len() + n_k.len() + prob.len() + alias.len());
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&KIND_LDA.to_le_bytes());
        out.extend_from_slice(&(k as u32).to_le_bytes());
        out.extend_from_slice(&(v as u64).to_le_bytes());
        out.extend_from_slice(&self.seed.to_le_bytes());
        out.extend_from_slice(&self.alpha.to_le_bytes());
        out.extend_from_slice(&self.beta.to_le_bytes());
        for sec in [&n_wk, &n_k, &prob, &alias] {
            out.extend_from_slice(&crc32(sec).to_le_bytes());
        }
        out.extend_from_slice(&crc32(&out).to_le_bytes());
        debug_assert_eq!(out.len(), HEADER_LEN);
        out.extend_from_slice(&n_wk);
        out.extend_from_slice(&n_k);
        out.extend_from_slice(&prob);
        out.extend_from_slice(&alias);
        out
    }

    /// Load and fully validate a snapshot, retrying transient IO up to
    /// the same budget as the shard store. Returns only a snapshot that
    /// passed every check — callers may pointer-swap it into service
    /// unconditionally.
    pub fn load(path: &Path) -> Result<Self, SnapshotError> {
        let token = fault::path_token(path);
        let mut attempt = 1;
        loop {
            match Self::load_once(path, token, attempt) {
                Err(e) if attempt < MAX_IO_ATTEMPTS && retryable(&e) => {
                    std::thread::sleep(std::time::Duration::from_millis(2u64 << attempt));
                    attempt += 1;
                }
                done => return done,
            }
        }
    }

    /// Cheap change token for the hot-reload watcher: the stored header
    /// CRC (a digest over kind/shape/seed/priors *and* all four section
    /// CRCs, so any republish — even within the same mtime second —
    /// moves it). Reads only the fixed-size header; `None` when the file
    /// is missing, short, or not a snapshot (the watcher then falls back
    /// to mtime alone).
    pub(crate) fn peek_header_crc(path: &Path) -> Option<u32> {
        use std::io::Read as _;
        let mut head = [0u8; HEADER_LEN];
        let mut f = std::fs::File::open(path).ok()?;
        f.read_exact(&mut head).ok()?;
        if !head.starts_with(MAGIC_STEM) {
            return None;
        }
        Some(u32::from_le_bytes([head[56], head[57], head[58], head[59]]))
    }

    fn load_once(path: &Path, token: u64, attempt: u32) -> Result<Self, SnapshotError> {
        // Chaos probe: a scheduled fault here models the read itself
        // failing (IoError), reading a torn file (TornWrite → short
        // read), or the loader crashing (Panic — the hot-reload path
        // must contain it).
        match fault::fire(sites::SNAPSHOT_READ, [token, u64::from(attempt), 0]) {
            Some(FaultKind::Panic) => panic!("injected fault: snapshot.read"),
            Some(FaultKind::IoError) => {
                return Err(SnapshotError::Io {
                    path: path.to_path_buf(),
                    op: "read",
                    source: std::io::Error::other("injected fault"),
                });
            }
            Some(FaultKind::TornWrite) => {
                return Err(SnapshotError::Truncated {
                    path: path.to_path_buf(),
                    len: 0,
                    expected: HEADER_LEN as u64,
                });
            }
            None => {}
        }
        let bytes = std::fs::read(path).map_err(|e| SnapshotError::Io {
            path: path.to_path_buf(),
            op: "read",
            source: e,
        })?;
        Self::decode(path, &bytes)
    }

    /// Validate and decode a full snapshot image.
    fn decode(path: &Path, bytes: &[u8]) -> Result<Self, SnapshotError> {
        let err_corrupt = |section| SnapshotError::Corrupt { path: path.to_path_buf(), section };
        if bytes.len() < HEADER_LEN {
            if bytes.len() >= MAGIC_STEM.len() && !bytes.starts_with(MAGIC_STEM) {
                return Err(SnapshotError::BadMagic { path: path.to_path_buf() });
            }
            return Err(SnapshotError::Truncated {
                path: path.to_path_buf(),
                len: bytes.len() as u64,
                expected: HEADER_LEN as u64,
            });
        }
        if &bytes[..8] != MAGIC {
            if bytes.starts_with(MAGIC_STEM) {
                return Err(SnapshotError::BadVersion {
                    path: path.to_path_buf(),
                    found: String::from_utf8_lossy(&bytes[6..8]).into_owned(),
                });
            }
            return Err(SnapshotError::BadMagic { path: path.to_path_buf() });
        }
        // Header CRC before trusting any header-derived offset.
        if crc32(&bytes[..56]) != read_u32(bytes, 56) {
            return Err(err_corrupt("header"));
        }
        let kind = read_u32(bytes, 8);
        let k = read_u32(bytes, 12) as usize;
        let v = read_u64(bytes, 16) as usize;
        let seed = read_u64(bytes, 24);
        let alpha = f32::from_le_bytes(bytes[32..36].try_into().unwrap());
        let beta = f32::from_le_bytes(bytes[36..40].try_into().unwrap());
        if kind != KIND_LDA || k == 0 || v == 0 || !alpha.is_finite() || !beta.is_finite() {
            return Err(err_corrupt("header"));
        }
        let sec_crc: Vec<u32> = (0..4).map(|i| read_u32(bytes, 40 + i * 4)).collect();
        let sizes = [v * k * 4, k * 4, v * k * 8, v * k * 4];
        let expected = HEADER_LEN as u64 + sizes.iter().map(|&s| s as u64).sum::<u64>();
        if bytes.len() as u64 != expected {
            return Err(SnapshotError::Truncated {
                path: path.to_path_buf(),
                len: bytes.len() as u64,
                expected,
            });
        }
        let names = ["n_wk", "n_k", "prob", "alias"];
        let mut off = HEADER_LEN;
        let mut sections = Vec::with_capacity(4);
        for (i, &size) in sizes.iter().enumerate() {
            let sec = &bytes[off..off + size];
            if crc32(sec) != sec_crc[i] {
                return Err(err_corrupt(names[i]));
            }
            sections.push(sec);
            off += size;
        }
        let n_wk: Vec<u32> = sections[0]
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        let n_k: Vec<u32> = sections[1]
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        let prob: Vec<f64> = sections[2]
            .chunks_exact(8)
            .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
            .collect();
        let alias: Vec<u32> = sections[3]
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        // Semantic validation: the CRCs prove the bytes are what the
        // writer wrote, these prove the writer wrote a usable table.
        if alias.iter().any(|&a| a as usize >= k) {
            return Err(err_corrupt("alias"));
        }
        if prob.iter().any(|p| !p.is_finite() || *p < 0.0) {
            return Err(err_corrupt("prob"));
        }
        let (inv_denom, wtotal) = derive(&n_wk, &n_k, k, v, alpha, beta);
        let tables = (0..v)
            .map(|w| {
                AliasTable::from_parts(
                    prob[w * k..(w + 1) * k].to_vec(),
                    alias[w * k..(w + 1) * k].to_vec(),
                )
            })
            .collect();
        Ok(Self { k, v, seed, alpha, beta, n_wk, n_k, tables, wtotal, inv_denom })
    }
}

/// Derived per-topic inverse denominators and per-word bucket masses —
/// a pure function of the checksummed counts, recomputed at load.
fn derive(
    n_wk: &[u32],
    n_k: &[u32],
    k: usize,
    v: usize,
    alpha: f32,
    beta: f32,
) -> (Vec<f64>, Vec<f64>) {
    let beta = beta as f64;
    let inv_denom: Vec<f64> =
        n_k.iter().map(|&c| 1.0 / (c as f64 + v as f64 * beta)).collect();
    let wtotal = (0..v)
        .map(|w| {
            let row = &n_wk[w * k..(w + 1) * k];
            alpha as f64
                * row
                    .iter()
                    .zip(&inv_denom)
                    .map(|(&c, &inv)| (c as f64 + beta) * inv)
                    .sum::<f64>()
        })
        .collect();
    (inv_denom, wtotal)
}

/// One word's φ row into `out` (alias-table weights).
fn phi_row(row: &[u32], inv_denom: &[f64], beta: f32, out: &mut [f64]) {
    for ((o, &c), &inv) in out.iter_mut().zip(row).zip(inv_denom) {
        *o = (c as f64 + beta as f64) * inv;
    }
}

fn read_u32(bytes: &[u8], off: usize) -> u32 {
    u32::from_le_bytes(bytes[off..off + 4].try_into().unwrap())
}

fn read_u64(bytes: &[u8], off: usize) -> u64 {
    u64::from_le_bytes(bytes[off..off + 8].try_into().unwrap())
}

/// Sibling temp path for atomic publication.
fn tmp_path(path: &Path) -> PathBuf {
    let mut name = path.file_name().unwrap_or_default().to_os_string();
    name.push(".tmp");
    path.with_file_name(name)
}

/// Removes the temp file if the write never reached the rename.
struct TmpGuard<'a>(&'a Path);

impl Drop for TmpGuard<'_> {
    fn drop(&mut self) {
        let _ = std::fs::remove_file(self.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// A small but non-trivial trained-count fixture.
    fn fixture(seed: u64) -> ModelSnapshot {
        let (k, v) = (8usize, 40usize);
        let mut rng = Rng::new(seed);
        let mut counts = LdaCounts::zeros(10, v, k);
        for w in 0..v {
            for t in 0..k {
                let c = rng.gen_range(20) as f32;
                counts.word_topic[w * k + t] = c;
                counts.topic[t] += c as u32;
            }
        }
        ModelSnapshot::from_counts(&counts, 0.5, 0.1, seed)
    }

    fn tmp_file(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("ppsnap-{tag}-{}", std::process::id()))
    }

    #[test]
    fn round_trip_is_byte_exact() {
        let snap = fixture(11);
        let path = tmp_file("roundtrip");
        snap.write(&path).unwrap();
        let loaded = ModelSnapshot::load(&path).unwrap();
        assert_eq!(loaded.k, snap.k);
        assert_eq!(loaded.v, snap.v);
        assert_eq!(loaded.seed, snap.seed);
        assert_eq!(loaded.n_wk, snap.n_wk);
        assert_eq!(loaded.n_k, snap.n_k);
        assert_eq!(loaded.wtotal, snap.wtotal);
        assert_eq!(loaded.inv_denom, snap.inv_denom);
        for (a, b) in loaded.tables.iter().zip(&snap.tables) {
            assert_eq!(a.parts(), b.parts());
        }
        // Re-encoding the loaded snapshot reproduces the same bytes.
        assert_eq!(loaded.encode(), snap.encode());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn write_leaves_no_temp_behind() {
        let snap = fixture(12);
        let path = tmp_file("notemp");
        snap.write(&path).unwrap();
        assert!(!tmp_path(&path).exists());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn missing_file_is_io_not_retried_forever() {
        let path = tmp_file("missing");
        match ModelSnapshot::load(&path) {
            Err(SnapshotError::Io { op, .. }) => assert_eq!(op, "read"),
            other => panic!("expected Io, got {other:?}"),
        }
    }

    /// Satellite: the corrupted-snapshot rejection matrix. One bit flip
    /// per section, truncations at several boundaries, foreign magic,
    /// future version — every case must surface the *typed* variant and
    /// never a panic or a silently-loaded model.
    #[test]
    fn corruption_matrix_rejects_with_typed_errors() {
        let snap = fixture(13);
        let path = tmp_file("matrix");
        snap.write(&path).unwrap();
        let good = std::fs::read(&path).unwrap();
        let (k, v) = (snap.k, snap.v);
        let sec_off = [
            (HEADER_LEN, "n_wk"),
            (HEADER_LEN + v * k * 4, "n_k"),
            (HEADER_LEN + v * k * 4 + k * 4, "prob"),
            (HEADER_LEN + v * k * 4 + k * 4 + v * k * 8, "alias"),
        ];
        let check = |bytes: Vec<u8>, want: &str, case: &str| {
            std::fs::write(&path, &bytes).unwrap();
            let err = ModelSnapshot::load(&path).expect_err(case);
            assert_eq!(err.tag(), want, "{case}: {err}");
        };
        // Bit flip inside each section → Corrupt naming that section.
        for &(off, name) in &sec_off {
            let mut bad = good.clone();
            bad[off + 3] ^= 0x10;
            std::fs::write(&path, &bad).unwrap();
            match ModelSnapshot::load(&path) {
                Err(SnapshotError::Corrupt { section, .. }) => {
                    assert_eq!(section, name)
                }
                other => panic!("flip in {name}: {other:?}"),
            }
        }
        // Bit flip in the header → header corruption.
        let mut bad = good.clone();
        bad[13] ^= 0x01;
        check(bad, "corrupt", "header flip");
        // Truncations: empty, mid-header, mid-section, one byte short.
        check(Vec::new(), "truncated", "empty file");
        check(good[..30].to_vec(), "truncated", "mid-header");
        check(good[..HEADER_LEN + 5].to_vec(), "truncated", "mid-section");
        check(good[..good.len() - 1].to_vec(), "truncated", "one byte short");
        // Foreign bytes → BadMagic (even when long enough to be a header).
        check(b"not a snapshot at all, sorry".to_vec(), "bad-magic", "foreign short");
        let mut foreign = good.clone();
        foreign[..8].copy_from_slice(b"SPILLv3\0");
        check(foreign, "bad-magic", "foreign full");
        // Right stem, future version → BadVersion.
        let mut future = good.clone();
        future[..8].copy_from_slice(b"PPSNAP2\0");
        match {
            std::fs::write(&path, &future).unwrap();
            ModelSnapshot::load(&path)
        } {
            Err(SnapshotError::BadVersion { found, .. }) => {
                assert!(found.starts_with('2'), "found={found:?}")
            }
            other => panic!("future version: {other:?}"),
        }
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn stale_tmp_orphan_never_shadows_published_snapshot() {
        // A crash between `create` and `rename` leaves `<name>.tmp`; the
        // published path must still load, and a fresh write must
        // atomically replace both.
        let snap = fixture(14);
        let path = tmp_file("orphan");
        snap.write(&path).unwrap();
        std::fs::write(tmp_path(&path), b"torn half-written junk").unwrap();
        let loaded = ModelSnapshot::load(&path).unwrap();
        assert_eq!(loaded.n_wk, snap.n_wk);
        let snap2 = fixture(15);
        snap2.write(&path).unwrap();
        assert!(!tmp_path(&path).exists(), "rewrite must consume the tmp slot");
        assert_eq!(ModelSnapshot::load(&path).unwrap().n_wk, snap2.n_wk);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn derived_state_matches_definition() {
        let snap = fixture(16);
        let (k, v) = (snap.k, snap.v);
        for t in 0..k {
            let denom = snap.n_k[t] as f64 + v as f64 * snap.beta as f64;
            assert!((snap.inv_denom[t] - 1.0 / denom).abs() < 1e-15);
        }
        for w in 0..v {
            let sum: f64 = (0..k).map(|t| snap.phi(w, t)).sum();
            let expect = snap.alpha as f64 * sum;
            assert!(
                (snap.wtotal[w] - expect).abs() < 1e-12,
                "w={w}: {} vs {expect}",
                snap.wtotal[w]
            );
        }
    }

    #[cfg(feature = "failpoints")]
    mod chaos {
        use super::*;
        use crate::util::fault::{install, Fault, ANY};

        #[test]
        fn injected_io_error_is_retried_and_absorbed() {
            let snap = fixture(21);
            let path = tmp_file("chaos-io");
            snap.write(&path).unwrap();
            let _g = install(vec![Fault {
                site: sites::SNAPSHOT_READ,
                key: [fault::path_token(&path), ANY, ANY],
                kind: FaultKind::IoError,
            }]);
            // One transient failure: the bounded retry absorbs it.
            let loaded = ModelSnapshot::load(&path).unwrap();
            assert_eq!(loaded.n_wk, snap.n_wk);
            std::fs::remove_file(&path).unwrap();
        }

        #[test]
        fn injected_torn_read_is_typed_truncation() {
            let snap = fixture(22);
            let path = tmp_file("chaos-torn");
            snap.write(&path).unwrap();
            // A torn file is not transient — no retry, typed error out.
            let _g = install(vec![Fault {
                site: sites::SNAPSHOT_READ,
                key: [fault::path_token(&path), ANY, ANY],
                kind: FaultKind::TornWrite,
            }]);
            match ModelSnapshot::load(&path) {
                Err(SnapshotError::Truncated { .. }) => {}
                other => panic!("expected Truncated, got {other:?}"),
            }
            std::fs::remove_file(&path).unwrap();
        }
    }
}
