//! TCP wire layer for the query server: one JSON object per line.
//!
//! Requests: `{"id":N,"words":[..],"deadline_ms":M}` (deadline
//! optional), plus control commands `{"cmd":"info"}`,
//! `{"cmd":"stats"}`, and `{"cmd":"shutdown"}` (graceful drain).
//! Replies: `{"id":N,"ok":true,"degraded":b,"iters":I,"theta":[..]}`
//! or `{"id":N,"ok":false,"error":"<tag>"}` with the typed
//! [`ServeError`] tag, so clients can tell *overloaded* (back off) from
//! *deadline* (give up) from *bad-request* (fix the query).
//!
//! The accept loop is nonblocking and polls between accepts: the glibc
//! `signal` binding has `SA_RESTART` semantics, so a blocking `accept`
//! would never observe the SIGINT latch ([`crate::util::interrupt`]).
//! The same poll drives snapshot **hot reload**: when watching is on and
//! the snapshot file's mtime moves, the candidate is fully validated and
//! atomically swapped in ([`QueryServer::reload_from`]) — a torn or
//! corrupt publish is rejected and the old model keeps serving.

use crate::serve::server::{QueryServer, ServeConfig, ServeError};
use crate::serve::snapshot::ModelSnapshot;
use crate::util::json::Json;
use std::io::{self, BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant, SystemTime};

/// Accept-loop poll period (SIGINT + shutdown-command latency bound).
const POLL: Duration = Duration::from_millis(20);
/// Snapshot watch period.
const WATCH_EVERY: Duration = Duration::from_millis(500);

pub struct NetOptions {
    /// Bind address; port 0 picks a free port (announced on stdout).
    pub addr: String,
    /// Watch the snapshot path and hot-reload on mtime change.
    pub watch: bool,
}

impl Default for NetOptions {
    fn default() -> Self {
        Self { addr: "127.0.0.1:0".into(), watch: true }
    }
}

/// Serve `snapshot_path` until SIGINT or a `shutdown` command, then
/// drain gracefully. Announces readiness as
/// `serve: listening on <addr>` and exits with a `serve: drained` line
/// plus a machine-readable `SERVE_JSON {..}` metrics summary.
pub fn serve(
    snapshot_path: &Path,
    opts: &NetOptions,
    cfg: ServeConfig,
    tracer: Option<Arc<crate::obs::trace::Tracer>>,
) -> io::Result<()> {
    let snap = ModelSnapshot::load(snapshot_path)
        .map_err(|e| io::Error::other(e.to_string()))?;
    println!(
        "serve: snapshot {} (K={} V={} seed={})",
        snapshot_path.display(),
        snap.k,
        snap.v,
        snap.seed
    );
    let server = Arc::new(QueryServer::start_traced(snap, cfg, tracer.clone()));
    let listener = TcpListener::bind(&opts.addr)?;
    listener.set_nonblocking(true)?;
    println!("serve: listening on {}", listener.local_addr()?);
    let shutdown = Arc::new(AtomicBool::new(false));
    let started = Instant::now();
    let mut watcher = Watcher::new(snapshot_path, opts.watch);
    let mut conns: Vec<std::thread::JoinHandle<()>> = Vec::new();
    loop {
        if crate::util::interrupt::requested() || shutdown.load(Ordering::SeqCst) {
            break;
        }
        match listener.accept() {
            Ok((stream, peer)) => {
                let server = Arc::clone(&server);
                let shutdown = Arc::clone(&shutdown);
                conns.push(
                    std::thread::Builder::new()
                        .name(format!("serve-conn-{peer}"))
                        .spawn(move || {
                            let _ = handle_conn(stream, &server, &shutdown, cfg);
                        })
                        .expect("spawn connection thread"),
                );
                conns.retain(|h| !h.is_finished());
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                std::thread::sleep(POLL);
                // This thread is the tracer's sole drainer (the
                // coordinator role): keep the worker rings from
                // overflowing on long serves.
                if let Some(tr) = &tracer {
                    tr.drain();
                }
                if let Some(result) = watcher.poll(&server) {
                    match result {
                        Ok(()) => println!("serve: snapshot hot-reloaded"),
                        Err(msg) => {
                            eprintln!("serve: reload rejected (old snapshot keeps serving): {msg}")
                        }
                    }
                }
            }
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
    println!("serve: draining");
    drop(listener);
    shutdown.store(true, Ordering::SeqCst);
    server.drain();
    for h in conns {
        let _ = h.join();
    }
    let elapsed = started.elapsed();
    println!("serve: drained | {}", server.metrics().render(elapsed));
    println!("SERVE_JSON {}", server.metrics().summary_json(elapsed).to_string());
    Ok(())
}

/// Polls the snapshot file's mtime and triggers hot reloads.
struct Watcher {
    path: PathBuf,
    enabled: bool,
    last_mtime: Option<SystemTime>,
    last_check: Instant,
}

impl Watcher {
    fn new(path: &Path, enabled: bool) -> Self {
        Self {
            path: path.to_path_buf(),
            enabled,
            last_mtime: mtime(path),
            last_check: Instant::now(),
        }
    }

    /// `Some(result)` when a reload was attempted.
    fn poll(&mut self, server: &QueryServer) -> Option<Result<(), String>> {
        if !self.enabled || self.last_check.elapsed() < WATCH_EVERY {
            return None;
        }
        self.last_check = Instant::now();
        let now = mtime(&self.path)?;
        if self.last_mtime == Some(now) {
            return None;
        }
        self.last_mtime = Some(now);
        Some(server.reload_from(&self.path).map_err(|e| e.to_string()))
    }
}

fn mtime(path: &Path) -> Option<SystemTime> {
    std::fs::metadata(path).and_then(|m| m.modified()).ok()
}

fn handle_conn(
    stream: TcpStream,
    server: &QueryServer,
    shutdown: &AtomicBool,
    cfg: ServeConfig,
) -> io::Result<()> {
    stream.set_read_timeout(Some(Duration::from_millis(50)))?;
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    loop {
        if shutdown.load(Ordering::SeqCst) {
            return Ok(());
        }
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) => return Ok(()), // client closed
            Ok(_) => {
                let reply = dispatch(line.trim(), server, shutdown, &cfg);
                writer.write_all(reply.to_string().as_bytes())?;
                writer.write_all(b"\n")?;
                writer.flush()?;
            }
            Err(e)
                if e.kind() == io::ErrorKind::WouldBlock
                    || e.kind() == io::ErrorKind::TimedOut => {}
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(_) => return Ok(()), // connection dropped
        }
    }
}

fn dispatch(line: &str, server: &QueryServer, shutdown: &AtomicBool, cfg: &ServeConfig) -> Json {
    if line.is_empty() {
        return error_reply(None, "bad-request");
    }
    let req = match Json::parse(line) {
        Ok(j) => j,
        Err(_) => return error_reply(None, "bad-request"),
    };
    match req.get("cmd").and_then(Json::as_str) {
        Some("info") => {
            let snap = server.snapshot();
            let mut j = Json::obj();
            j.set("ok", true)
                .set("k", snap.k)
                .set("v", snap.v)
                .set("seed", snap.seed)
                .set("fold_iters", cfg.fold_iters);
            return j;
        }
        Some("stats") => {
            let mut j = server.metrics().summary_json(Duration::from_secs(0));
            j.set("ok", true);
            return j;
        }
        Some("shutdown") => {
            shutdown.store(true, Ordering::SeqCst);
            let mut j = Json::obj();
            j.set("ok", true).set("draining", true);
            return j;
        }
        Some(_) => return error_reply(None, "bad-request"),
        None => {}
    }
    let id = match req.get("id").and_then(Json::as_u64) {
        Some(id) => id,
        None => return error_reply(None, "bad-request"),
    };
    let words: Option<Vec<u32>> = req.get("words").and_then(Json::as_arr).map(|arr| {
        arr.iter().filter_map(Json::as_u64).map(|w| w as u32).collect()
    });
    let words = match words {
        Some(w) => w,
        None => return error_reply(Some(id), "bad-request"),
    };
    let deadline =
        req.get("deadline_ms").and_then(Json::as_u64).map(Duration::from_millis);
    match server.query(id, words, deadline) {
        Ok(reply) => {
            let mut j = Json::obj();
            j.set("id", reply.id)
                .set("ok", true)
                .set("degraded", reply.degraded)
                .set("iters", reply.iters)
                .set(
                    "theta",
                    Json::Arr(reply.theta.iter().map(|&p| Json::from(p as f64)).collect()),
                );
            j
        }
        Err(e) => {
            let mut j = error_reply(Some(id), e.tag());
            if let ServeError::BadRequest(msg) = e {
                j.set("detail", msg);
            }
            j
        }
    }
}

fn error_reply(id: Option<u64>, tag: &str) -> Json {
    let mut j = Json::obj();
    if let Some(id) = id {
        j.set("id", id);
    }
    j.set("ok", false).set("error", tag);
    j
}

/// Line-protocol client, used by `pplda query-bench` and the
/// integration tests.
pub struct Client {
    writer: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    pub fn connect(addr: &SocketAddr) -> io::Result<Self> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        let writer = stream.try_clone()?;
        Ok(Self { writer, reader: BufReader::new(stream) })
    }

    fn roundtrip(&mut self, req: &Json) -> io::Result<Json> {
        self.writer.write_all(req.to_string().as_bytes())?;
        self.writer.write_all(b"\n")?;
        self.writer.flush()?;
        let mut line = String::new();
        if self.reader.read_line(&mut line)? == 0 {
            return Err(io::Error::new(io::ErrorKind::UnexpectedEof, "server closed"));
        }
        Json::parse(line.trim()).map_err(io::Error::other)
    }

    pub fn info(&mut self) -> io::Result<Json> {
        let mut j = Json::obj();
        j.set("cmd", "info");
        self.roundtrip(&j)
    }

    pub fn stats(&mut self) -> io::Result<Json> {
        let mut j = Json::obj();
        j.set("cmd", "stats");
        self.roundtrip(&j)
    }

    pub fn shutdown(&mut self) -> io::Result<Json> {
        let mut j = Json::obj();
        j.set("cmd", "shutdown");
        self.roundtrip(&j)
    }

    /// One query round-trip; the raw JSON reply (ok or typed error).
    pub fn query(
        &mut self,
        id: u64,
        words: &[u32],
        deadline_ms: Option<u64>,
    ) -> io::Result<Json> {
        let mut j = Json::obj();
        j.set("id", id).set(
            "words",
            Json::Arr(words.iter().map(|&w| Json::from(u64::from(w))).collect()),
        );
        if let Some(ms) = deadline_ms {
            j.set("deadline_ms", ms);
        }
        self.roundtrip(&j)
    }
}
