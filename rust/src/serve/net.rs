//! TCP wire layer for the query server: one JSON object per line.
//!
//! Requests: `{"id":N,"words":[..],"deadline_ms":M}` (deadline
//! optional), plus control commands `{"cmd":"info"}`,
//! `{"cmd":"stats"}`, and `{"cmd":"shutdown"}` (graceful drain).
//! Replies: `{"id":N,"ok":true,"degraded":b,"iters":I,"theta":[..]}`
//! or `{"id":N,"ok":false,"error":"<tag>"}` with the typed
//! [`ServeError`] tag, so clients can tell *overloaded* (back off) from
//! *deadline* (give up) from *bad-request* (fix the query).
//!
//! The accept loop is nonblocking and polls between accepts: the glibc
//! `signal` binding has `SA_RESTART` semantics, so a blocking `accept`
//! would never observe the SIGINT/SIGTERM latch
//! ([`crate::util::interrupt`]). The same poll drives snapshot **hot
//! reload**: when watching is on and the snapshot file changes — mtime
//! *or* header CRC; mtime alone has one-second granularity and misses
//! same-second republishes — the candidate is fully validated and
//! atomically swapped in ([`QueryServer::reload_from`]) — a torn or
//! corrupt publish is rejected and the old model keeps serving.
//!
//! The line framing itself (connect/send/recv one JSON object per line)
//! is shared with the distributed control plane via
//! [`crate::util::net`].

use crate::serve::server::{QueryServer, ServeConfig, ServeError};
use crate::serve::snapshot::ModelSnapshot;
use crate::util::json::Json;
use crate::util::net::{recv_line, send_line};
use std::io::{self, BufReader};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant, SystemTime};

/// Accept-loop poll period (SIGINT + shutdown-command latency bound).
const POLL: Duration = Duration::from_millis(20);
/// Snapshot watch period.
const WATCH_EVERY: Duration = Duration::from_millis(500);

pub struct NetOptions {
    /// Bind address; port 0 picks a free port (announced on stdout).
    pub addr: String,
    /// Watch the snapshot path and hot-reload on mtime change.
    pub watch: bool,
}

impl Default for NetOptions {
    fn default() -> Self {
        Self { addr: "127.0.0.1:0".into(), watch: true }
    }
}

/// Serve `snapshot_path` until SIGINT or a `shutdown` command, then
/// drain gracefully. Announces readiness as
/// `serve: listening on <addr>` and exits with a `serve: drained` line
/// plus a machine-readable `SERVE_JSON {..}` metrics summary.
pub fn serve(
    snapshot_path: &Path,
    opts: &NetOptions,
    cfg: ServeConfig,
    tracer: Option<Arc<crate::obs::trace::Tracer>>,
) -> io::Result<()> {
    let snap = ModelSnapshot::load(snapshot_path)
        .map_err(|e| io::Error::other(e.to_string()))?;
    println!(
        "serve: snapshot {} (K={} V={} seed={})",
        snapshot_path.display(),
        snap.k,
        snap.v,
        snap.seed
    );
    let server = Arc::new(QueryServer::start_traced(snap, cfg, tracer.clone()));
    let listener = TcpListener::bind(&opts.addr)?;
    listener.set_nonblocking(true)?;
    println!("serve: listening on {}", listener.local_addr()?);
    let shutdown = Arc::new(AtomicBool::new(false));
    let started = Instant::now();
    let mut watcher = Watcher::new(snapshot_path, opts.watch);
    let mut conns: Vec<std::thread::JoinHandle<()>> = Vec::new();
    loop {
        if crate::util::interrupt::requested() || shutdown.load(Ordering::SeqCst) {
            break;
        }
        match listener.accept() {
            Ok((stream, peer)) => {
                let server = Arc::clone(&server);
                let shutdown = Arc::clone(&shutdown);
                conns.push(
                    std::thread::Builder::new()
                        .name(format!("serve-conn-{peer}"))
                        .spawn(move || {
                            let _ = handle_conn(stream, &server, &shutdown, cfg);
                        })
                        .expect("spawn connection thread"),
                );
                conns.retain(|h| !h.is_finished());
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                std::thread::sleep(POLL);
                // This thread is the tracer's sole drainer (the
                // coordinator role): keep the worker rings from
                // overflowing on long serves.
                if let Some(tr) = &tracer {
                    tr.drain();
                }
                if let Some(result) = watcher.poll(&server) {
                    match result {
                        Ok(()) => println!("serve: snapshot hot-reloaded"),
                        Err(msg) => {
                            eprintln!("serve: reload rejected (old snapshot keeps serving): {msg}")
                        }
                    }
                }
            }
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
    println!("serve: draining");
    drop(listener);
    shutdown.store(true, Ordering::SeqCst);
    server.drain();
    for h in conns {
        let _ = h.join();
    }
    let elapsed = started.elapsed();
    println!("serve: drained | {}", server.metrics().render(elapsed));
    println!("SERVE_JSON {}", server.metrics().summary_json(elapsed).to_string());
    Ok(())
}

/// Polls the snapshot file and triggers hot reloads on change.
///
/// Change detection compares the mtime *and* the stored snapshot header
/// CRC ([`ModelSnapshot::peek_header_crc`]): mtime has one-second
/// granularity on common filesystems, so a republish landing in the
/// same second as its predecessor is invisible to mtime alone. The
/// header CRC digests every section CRC, so any content change moves
/// it regardless of timestamps.
struct Watcher {
    path: PathBuf,
    enabled: bool,
    last_mtime: Option<SystemTime>,
    last_crc: Option<u32>,
    last_check: Instant,
}

impl Watcher {
    fn new(path: &Path, enabled: bool) -> Self {
        Self {
            path: path.to_path_buf(),
            enabled,
            last_mtime: mtime(path),
            last_crc: ModelSnapshot::peek_header_crc(path),
            last_check: Instant::now(),
        }
    }

    /// `Some(result)` when a reload was attempted.
    fn poll(&mut self, server: &QueryServer) -> Option<Result<(), String>> {
        if !self.enabled || self.last_check.elapsed() < WATCH_EVERY {
            return None;
        }
        self.last_check = Instant::now();
        let now = mtime(&self.path);
        let crc = ModelSnapshot::peek_header_crc(&self.path);
        if now.is_none() && crc.is_none() {
            // File briefly missing (mid-publish rename) — keep serving.
            return None;
        }
        if self.last_mtime == now && self.last_crc == crc {
            return None;
        }
        self.last_mtime = now;
        self.last_crc = crc;
        Some(server.reload_from(&self.path).map_err(|e| e.to_string()))
    }

    /// Make the next `poll` due immediately (tests only — the
    /// production cadence is [`WATCH_EVERY`]).
    #[cfg(test)]
    fn force_due(&mut self) {
        self.last_check = Instant::now()
            .checked_sub(WATCH_EVERY)
            .unwrap_or_else(Instant::now);
    }
}

fn mtime(path: &Path) -> Option<SystemTime> {
    std::fs::metadata(path).and_then(|m| m.modified()).ok()
}

fn handle_conn(
    stream: TcpStream,
    server: &QueryServer,
    shutdown: &AtomicBool,
    cfg: ServeConfig,
) -> io::Result<()> {
    stream.set_read_timeout(Some(Duration::from_millis(50)))?;
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    loop {
        if shutdown.load(Ordering::SeqCst) {
            return Ok(());
        }
        match recv_line(&mut reader, &mut line) {
            Ok(false) => return Ok(()), // client closed
            Ok(true) => {
                let reply = dispatch(line.trim(), server, shutdown, &cfg);
                send_line(&mut writer, &reply)?;
            }
            Err(e) if crate::util::net::is_timeout(&e) => {}
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(_) => return Ok(()), // connection dropped
        }
    }
}

fn dispatch(line: &str, server: &QueryServer, shutdown: &AtomicBool, cfg: &ServeConfig) -> Json {
    if line.is_empty() {
        return error_reply(None, "bad-request");
    }
    let req = match Json::parse(line) {
        Ok(j) => j,
        Err(_) => return error_reply(None, "bad-request"),
    };
    match req.get("cmd").and_then(Json::as_str) {
        Some("info") => {
            let snap = server.snapshot();
            let mut j = Json::obj();
            j.set("ok", true)
                .set("k", snap.k)
                .set("v", snap.v)
                .set("seed", snap.seed)
                .set("fold_iters", cfg.fold_iters);
            return j;
        }
        Some("stats") => {
            let mut j = server.metrics().summary_json(Duration::from_secs(0));
            j.set("ok", true);
            return j;
        }
        Some("shutdown") => {
            shutdown.store(true, Ordering::SeqCst);
            let mut j = Json::obj();
            j.set("ok", true).set("draining", true);
            return j;
        }
        Some(_) => return error_reply(None, "bad-request"),
        None => {}
    }
    let id = match req.get("id").and_then(Json::as_u64) {
        Some(id) => id,
        None => return error_reply(None, "bad-request"),
    };
    let words: Option<Vec<u32>> = req.get("words").and_then(Json::as_arr).map(|arr| {
        arr.iter().filter_map(Json::as_u64).map(|w| w as u32).collect()
    });
    let words = match words {
        Some(w) => w,
        None => return error_reply(Some(id), "bad-request"),
    };
    let deadline =
        req.get("deadline_ms").and_then(Json::as_u64).map(Duration::from_millis);
    match server.query(id, words, deadline) {
        Ok(reply) => {
            let mut j = Json::obj();
            j.set("id", reply.id)
                .set("ok", true)
                .set("degraded", reply.degraded)
                .set("iters", reply.iters)
                .set(
                    "theta",
                    Json::Arr(reply.theta.iter().map(|&p| Json::from(p as f64)).collect()),
                );
            j
        }
        Err(e) => {
            let mut j = error_reply(Some(id), e.tag());
            if let ServeError::BadRequest(msg) = e {
                j.set("detail", msg);
            }
            j
        }
    }
}

fn error_reply(id: Option<u64>, tag: &str) -> Json {
    let mut j = Json::obj();
    if let Some(id) = id {
        j.set("id", id);
    }
    j.set("ok", false).set("error", tag);
    j
}

/// Line-protocol client, used by `pplda query-bench` and the
/// integration tests.
pub struct Client {
    writer: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    pub fn connect(addr: &SocketAddr) -> io::Result<Self> {
        let stream = crate::util::net::connect(addr)?;
        let writer = stream.try_clone()?;
        Ok(Self { writer, reader: BufReader::new(stream) })
    }

    fn roundtrip(&mut self, req: &Json) -> io::Result<Json> {
        send_line(&mut self.writer, req)?;
        let mut line = String::new();
        if !recv_line(&mut self.reader, &mut line)? {
            return Err(io::Error::new(io::ErrorKind::UnexpectedEof, "server closed"));
        }
        Json::parse(line.trim()).map_err(io::Error::other)
    }

    pub fn info(&mut self) -> io::Result<Json> {
        let mut j = Json::obj();
        j.set("cmd", "info");
        self.roundtrip(&j)
    }

    pub fn stats(&mut self) -> io::Result<Json> {
        let mut j = Json::obj();
        j.set("cmd", "stats");
        self.roundtrip(&j)
    }

    pub fn shutdown(&mut self) -> io::Result<Json> {
        let mut j = Json::obj();
        j.set("cmd", "shutdown");
        self.roundtrip(&j)
    }

    /// One query round-trip; the raw JSON reply (ok or typed error).
    pub fn query(
        &mut self,
        id: u64,
        words: &[u32],
        deadline_ms: Option<u64>,
    ) -> io::Result<Json> {
        let mut j = Json::obj();
        j.set("id", id).set(
            "words",
            Json::Arr(words.iter().map(|&w| Json::from(u64::from(w))).collect()),
        );
        if let Some(ms) = deadline_ms {
            j.set("deadline_ms", ms);
        }
        self.roundtrip(&j)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gibbs::counts::LdaCounts;
    use crate::util::rng::Rng;

    fn snapshot(seed: u64, k: usize, v: usize) -> ModelSnapshot {
        let mut rng = Rng::new(seed);
        let mut counts = LdaCounts::zeros(4, v, k);
        for w in 0..v {
            for t in 0..k {
                let c = (1 + rng.gen_range(50)) as f32;
                counts.word_topic[w * k + t] = c;
                counts.topic[t] += c as u32;
            }
        }
        ModelSnapshot::from_counts(&counts, 0.5, 0.1, seed)
    }

    fn temp_path(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!(
            "pplda_watch_{tag}_{}_{:?}.ppsnap",
            std::process::id(),
            std::thread::current().id()
        ))
    }

    /// Regression: a republish landing in the same mtime second as its
    /// predecessor must still be picked up — the watcher compares the
    /// snapshot header CRC, not just the mtime. Simulated by pinning
    /// `last_mtime` to the post-publish mtime (exactly what a
    /// same-second republish looks like to a pure mtime poll).
    #[test]
    fn same_second_republish_is_detected_via_header_crc() {
        let path = temp_path("crc");
        snapshot(1, 8, 32).write(&path).unwrap();
        let server =
            QueryServer::start(ModelSnapshot::load(&path).unwrap(), ServeConfig::default());
        let mut w = Watcher::new(&path, true);

        // Republish different content; hide the mtime change.
        snapshot(2, 8, 32).write(&path).unwrap();
        w.last_mtime = mtime(&path);
        w.force_due();
        let result = w.poll(&server).expect("header CRC change must trigger a reload");
        result.expect("reload of a valid snapshot succeeds");
        assert_eq!(server.snapshot().seed, 2, "server must now serve the republish");

        // Unchanged file: no reload attempt.
        w.force_due();
        assert!(w.poll(&server).is_none());
        server.drain();
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn watcher_tolerates_a_briefly_missing_file() {
        let path = temp_path("gone");
        snapshot(3, 8, 32).write(&path).unwrap();
        let server =
            QueryServer::start(ModelSnapshot::load(&path).unwrap(), ServeConfig::default());
        let mut w = Watcher::new(&path, true);
        std::fs::remove_file(&path).unwrap();
        w.force_due();
        assert!(w.poll(&server).is_none(), "mid-publish gap must not force a reload");
        // File comes back with new content: reload fires.
        snapshot(4, 8, 32).write(&path).unwrap();
        w.force_due();
        w.poll(&server).expect("reappearing file triggers a reload").unwrap();
        assert_eq!(server.snapshot().seed, 4);
        server.drain();
        std::fs::remove_file(&path).ok();
    }
}
