//! The batched fold-in query server.
//!
//! Requests enter a **bounded admission queue** (a full queue is a typed
//! [`ServeError::Overloaded`] rejection, never an unbounded backlog),
//! worker threads pull **micro-batches** off the queue and fold each
//! request in against the current [`ModelSnapshot`] using reusable
//! per-worker scratch. Robustness is layered on explicitly:
//!
//! - **Deadlines** — a request carrying a deadline that expires while
//!   queued is shed at dequeue with [`ServeError::Deadline`]; it is
//!   never sampled (no work is spent on a reply nobody is waiting for).
//! - **Graceful degradation** — when the queue runs past a configured
//!   depth fraction, fold-in iterations shrink linearly toward a floor;
//!   the reply is flagged `degraded` and carries the iteration count
//!   actually used, so it remains reproducible (the engine's RNG-prefix
//!   contract, see [`crate::serve::engine`]).
//! - **Panic containment** — each request runs under `catch_unwind`
//!   with one retry; a request that panics twice gets a typed
//!   [`ServeError::Panicked`] reply and the worker keeps serving. The
//!   `serve.request` failpoint drives this path in chaos tests.
//! - **Atomic hot reload** — [`QueryServer::reload_from`] validates a
//!   candidate snapshot *completely* (including under the
//!   `serve.reload` failpoint) before a single pointer swap; any
//!   failure leaves the old snapshot serving.
//! - **Graceful drain** — stop admitting, finish everything in flight,
//!   fulfil stragglers with [`ServeError::ShuttingDown`], join workers.
//!
//! Queue waits, work time, and end-to-end latency flow into
//! [`ServeMetrics`] histograms; when a [`Tracer`] is attached each
//! request also emits `QueueWait` + `Task` spans on its worker's lane,
//! so `pplda analyze-trace` works on serve traces unchanged.

use crate::obs::trace::{Event, EventKind, Tracer};
use crate::serve::engine::{self, FoldScratch};
use crate::serve::metrics::ServeMetrics;
use crate::serve::snapshot::{ModelSnapshot, SnapshotError};
use crate::util::fault::{self, sites, FaultKind};
use std::collections::VecDeque;
use std::fmt;
use std::path::Path;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::{Arc, Condvar, Mutex, RwLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Typed request outcome short of a reply. The wire layer maps `tag()`
/// into the error field of a JSON reply; clients switch on it.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ServeError {
    /// Admission queue full — back off and retry.
    Overloaded,
    /// Deadline expired while queued; the request was never sampled.
    Deadline,
    /// The request panicked past its retry budget (contained).
    Panicked,
    /// Server is draining or stopped.
    ShuttingDown,
    /// Malformed request (e.g. word id out of vocabulary).
    BadRequest(String),
}

impl ServeError {
    pub fn tag(&self) -> &'static str {
        match self {
            Self::Overloaded => "overloaded",
            Self::Deadline => "deadline",
            Self::Panicked => "panicked",
            Self::ShuttingDown => "shutting-down",
            Self::BadRequest(_) => "bad-request",
        }
    }
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Self::BadRequest(msg) => write!(f, "bad request: {msg}"),
            other => f.write_str(other.tag()),
        }
    }
}

impl std::error::Error for ServeError {}

/// A successful fold-in reply.
#[derive(Clone, Debug, PartialEq)]
pub struct Reply {
    pub id: u64,
    /// Document–topic mixture over the snapshot's K topics.
    pub theta: Vec<f32>,
    /// Fold-in iterations actually run (may be below nominal when
    /// `degraded`); replaying the engine at this count reproduces
    /// `theta` bit-exactly.
    pub iters: usize,
    pub degraded: bool,
}

#[derive(Clone, Copy, Debug)]
pub struct ServeConfig {
    /// Worker threads. `0` is allowed (nothing dequeues) — used by
    /// admission-control tests.
    pub workers: usize,
    /// Admission queue bound; beyond it, `Overloaded`.
    pub queue_capacity: usize,
    /// Max requests a worker claims per dequeue.
    pub max_batch: usize,
    /// Nominal fold-in Gibbs iterations.
    pub fold_iters: usize,
    /// Degradation floor.
    pub min_fold_iters: usize,
    /// Queue-depth fraction where degradation starts (1.0 disables).
    pub degrade_at: f64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            workers: 2,
            queue_capacity: 256,
            max_batch: 8,
            fold_iters: 10,
            min_fold_iters: 2,
            degrade_at: 0.5,
        }
    }
}

const RUNNING: u8 = 0;
const DRAINING: u8 = 1;
const STOPPED: u8 = 2;

/// One-shot reply slot a client blocks on.
#[derive(Default)]
struct Promise {
    slot: Mutex<Option<Result<Reply, ServeError>>>,
    cv: Condvar,
}

fn fulfill(p: &Promise, r: Result<Reply, ServeError>) {
    *p.slot.lock().unwrap() = Some(r);
    p.cv.notify_all();
}

/// Client-side handle for a submitted request.
pub struct Handle {
    promise: Arc<Promise>,
}

impl Handle {
    /// Block until the server fulfils the request.
    pub fn wait(self) -> Result<Reply, ServeError> {
        let mut slot = self.promise.slot.lock().unwrap();
        loop {
            if let Some(r) = slot.take() {
                return r;
            }
            slot = self.promise.cv.wait(slot).unwrap();
        }
    }
}

struct Pending {
    id: u64,
    words: Vec<u32>,
    deadline: Option<Instant>,
    enqueued: Instant,
    promise: Arc<Promise>,
}

struct Inner {
    cfg: ServeConfig,
    queue: Mutex<VecDeque<Pending>>,
    cv: Condvar,
    state: AtomicU8,
    snapshot: RwLock<Arc<ModelSnapshot>>,
    metrics: ServeMetrics,
    tracer: Option<Arc<Tracer>>,
}

impl Inner {
    fn state(&self) -> u8 {
        self.state.load(Ordering::SeqCst)
    }

    /// Fold-in iterations for a dequeue that found `depth` requests
    /// queued: nominal below the degradation threshold, then a linear
    /// ramp down to the floor at a full queue.
    fn iters_for_depth(&self, depth: usize) -> usize {
        let cfg = &self.cfg;
        let frac = depth as f64 / cfg.queue_capacity.max(1) as f64;
        if frac <= cfg.degrade_at {
            return cfg.fold_iters;
        }
        let span = (1.0 - cfg.degrade_at).max(1e-9);
        let x = ((frac - cfg.degrade_at) / span).min(1.0);
        let target = cfg.fold_iters as f64 - x * (cfg.fold_iters - cfg.min_fold_iters) as f64;
        (target.round() as usize).max(cfg.min_fold_iters)
    }

    fn worker_loop(&self, lane: usize) {
        let mut scratch = FoldScratch::new();
        loop {
            let (batch, depth) = {
                let mut q = self.queue.lock().unwrap();
                loop {
                    if !q.is_empty() {
                        break;
                    }
                    if self.state() != RUNNING {
                        return; // drained: queue empty, no more admits
                    }
                    q = self.cv.wait(q).unwrap();
                }
                let depth = q.len();
                let n = depth.min(self.cfg.max_batch.max(1));
                (q.drain(..n).collect::<Vec<_>>(), depth)
            };
            let iters = self.iters_for_depth(depth);
            for p in batch {
                self.process(p, iters, &mut scratch, lane);
            }
        }
    }

    fn process(&self, p: Pending, iters: usize, scratch: &mut FoldScratch, lane: usize) {
        let dequeued = Instant::now();
        let queue_ns = dequeued.duration_since(p.enqueued).as_nanos() as u64;
        self.metrics.queue_ns.observe(queue_ns);
        if p.deadline.is_some_and(|dl| dequeued >= dl) {
            self.metrics.shed_deadline.inc();
            fulfill(&p.promise, Err(ServeError::Deadline));
            return;
        }
        let snap = self.snapshot.read().unwrap().clone();
        let t_work = self.tracer.as_ref().map(|tr| tr.now());
        // Containment boundary: the fold-in (and its chaos probe) runs
        // under `catch_unwind` with one retry. A panic cannot take the
        // worker down, and the retry is bit-identical to an undisturbed
        // run because the engine reseeds from (snapshot, request id).
        // `AssertUnwindSafe` is sound: `fold_in` resets the scratch
        // before touching it, so a mid-request unwind leaves no state a
        // later request can observe.
        let mut theta = None;
        for attempt in 0..=1u64 {
            let run = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                match fault::fire(sites::SERVE_REQUEST, [snap.seed, p.id, attempt]) {
                    Some(FaultKind::Panic) => panic!("injected fault: serve.request"),
                    // Injected transient failure (io-error / torn-write
                    // flavors): fail the attempt without unwinding.
                    Some(_) => None,
                    None => Some(engine::fold_in(&snap, scratch, &p.words, p.id, iters)),
                }
            }));
            match run {
                Ok(Some(t)) => {
                    theta = Some(t);
                    break;
                }
                Ok(None) => {}
                Err(_) => self.metrics.panics_contained.inc(),
            }
            if attempt == 0 {
                self.metrics.retries.inc();
            }
        }
        let work_ns = dequeued.elapsed().as_nanos() as u64;
        self.metrics.work_ns.observe(work_ns);
        self.metrics.latency_ns.observe(queue_ns + work_ns);
        if let (Some(tr), Some(t0)) = (self.tracer.as_ref(), t_work) {
            let lane = lane as u16;
            let ticket = p.id as u32;
            tr.emit(Event {
                lane,
                ticket,
                partition: p.id,
                t0_ns: t0.saturating_sub(queue_ns),
                dur_ns: queue_ns,
                ..Event::of(EventKind::QueueWait)
            });
            tr.emit(Event {
                lane,
                ticket,
                partition: p.id,
                t0_ns: t0,
                dur_ns: tr.now().saturating_sub(t0),
                arg: iters as u64,
                ..Event::of(EventKind::Task)
            });
        }
        match theta {
            Some(theta) => {
                let degraded = iters < self.cfg.fold_iters;
                if degraded {
                    self.metrics.degraded.inc();
                }
                self.metrics.completed.inc();
                fulfill(&p.promise, Ok(Reply { id: p.id, theta, iters, degraded }));
            }
            None => {
                self.metrics.failed.inc();
                fulfill(&p.promise, Err(ServeError::Panicked));
            }
        }
    }
}

/// The server: shared state + owned worker threads.
pub struct QueryServer {
    inner: Arc<Inner>,
    workers: Mutex<Vec<JoinHandle<()>>>,
}

impl QueryServer {
    pub fn start(snapshot: ModelSnapshot, cfg: ServeConfig) -> Self {
        Self::start_traced(snapshot, cfg, None)
    }

    /// Start with an optional tracer; worker `i` owns tracer lane `i`
    /// (the tracer must have been created with ≥ `cfg.workers` lanes).
    pub fn start_traced(
        snapshot: ModelSnapshot,
        cfg: ServeConfig,
        tracer: Option<Arc<Tracer>>,
    ) -> Self {
        let inner = Arc::new(Inner {
            cfg,
            queue: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            state: AtomicU8::new(RUNNING),
            snapshot: RwLock::new(Arc::new(snapshot)),
            metrics: ServeMetrics::new(),
            tracer,
        });
        let workers = (0..cfg.workers)
            .map(|lane| {
                let inner = Arc::clone(&inner);
                std::thread::Builder::new()
                    .name(format!("serve-{lane}"))
                    .spawn(move || inner.worker_loop(lane))
                    .expect("spawn serve worker")
            })
            .collect();
        Self { inner, workers: Mutex::new(workers) }
    }

    pub fn metrics(&self) -> &ServeMetrics {
        &self.inner.metrics
    }

    /// The snapshot currently serving.
    pub fn snapshot(&self) -> Arc<ModelSnapshot> {
        self.inner.snapshot.read().unwrap().clone()
    }

    /// Non-blocking admission. Typed rejection when full, draining, or
    /// malformed; otherwise a [`Handle`] to wait on.
    pub fn submit(
        &self,
        id: u64,
        words: Vec<u32>,
        deadline: Option<Duration>,
    ) -> Result<Handle, ServeError> {
        if self.inner.state() != RUNNING {
            return Err(ServeError::ShuttingDown);
        }
        let v = self.inner.snapshot.read().unwrap().v;
        if let Some(&w) = words.iter().find(|&&w| w as usize >= v) {
            return Err(ServeError::BadRequest(format!("word id {w} out of range (V={v})")));
        }
        let now = Instant::now();
        let promise = Arc::new(Promise::default());
        let handle = Handle { promise: Arc::clone(&promise) };
        {
            let mut q = self.inner.queue.lock().unwrap();
            // Re-check under the queue lock: drain flushes the queue
            // after joining workers, so an admit racing the drain must
            // not strand a waiter.
            if self.inner.state() != RUNNING {
                return Err(ServeError::ShuttingDown);
            }
            if q.len() >= self.inner.cfg.queue_capacity {
                self.inner.metrics.rejected_overload.inc();
                return Err(ServeError::Overloaded);
            }
            q.push_back(Pending {
                id,
                words,
                deadline: deadline.map(|d| now + d),
                enqueued: now,
                promise,
            });
        }
        self.inner.metrics.accepted.inc();
        self.inner.cv.notify_one();
        Ok(handle)
    }

    /// Submit and block for the reply.
    pub fn query(
        &self,
        id: u64,
        words: Vec<u32>,
        deadline: Option<Duration>,
    ) -> Result<Reply, ServeError> {
        self.submit(id, words, deadline)?.wait()
    }

    /// Atomic hot reload: fully validate the candidate at `path`, then
    /// pointer-swap. On *any* failure — unreadable, torn, corrupt,
    /// shape-mismatched, or a panic out of the loader (contained here) —
    /// the old snapshot keeps serving and the error is returned typed.
    pub fn reload_from(&self, path: &Path) -> Result<(), SnapshotError> {
        let token = fault::path_token(path);
        let run = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            match fault::fire(sites::SERVE_RELOAD, [token, 0, 0]) {
                Some(FaultKind::Panic) => panic!("injected fault: serve.reload"),
                Some(FaultKind::IoError) => {
                    return Err(SnapshotError::Io {
                        path: path.to_path_buf(),
                        op: "reload",
                        source: std::io::Error::other("injected fault"),
                    });
                }
                Some(FaultKind::TornWrite) => {
                    return Err(SnapshotError::Truncated {
                        path: path.to_path_buf(),
                        len: 0,
                        expected: 0,
                    });
                }
                None => {}
            }
            ModelSnapshot::load(path)
        }));
        let loaded = run.unwrap_or_else(|_| {
            Err(SnapshotError::Corrupt {
                path: path.to_path_buf(),
                section: "reload (panic contained)",
            })
        });
        let new = match loaded {
            Ok(new) => new,
            Err(e) => {
                self.inner.metrics.reloads_rejected.inc();
                return Err(e);
            }
        };
        {
            let cur = self.inner.snapshot.read().unwrap();
            if new.k != cur.k || new.v != cur.v {
                let detail =
                    format!("serving K={} V={}, candidate K={} V={}", cur.k, cur.v, new.k, new.v);
                drop(cur);
                self.inner.metrics.reloads_rejected.inc();
                return Err(SnapshotError::Mismatch { path: path.to_path_buf(), detail });
            }
        }
        *self.inner.snapshot.write().unwrap() = Arc::new(new);
        self.inner.metrics.reloads_ok.inc();
        Ok(())
    }

    /// Graceful drain: stop admitting, let workers finish everything
    /// already queued, fulfil any straggler with `ShuttingDown`, join.
    /// Idempotent.
    pub fn drain(&self) {
        self.inner.state.store(DRAINING, Ordering::SeqCst);
        self.inner.cv.notify_all();
        let handles: Vec<_> = self.workers.lock().unwrap().drain(..).collect();
        for h in handles {
            let _ = h.join();
        }
        // With zero workers (or an admit that raced the join) entries
        // may remain; nobody will serve them — fail them typed.
        let stragglers: Vec<Pending> =
            self.inner.queue.lock().unwrap().drain(..).collect();
        for p in stragglers {
            fulfill(&p.promise, Err(ServeError::ShuttingDown));
        }
        self.inner.state.store(STOPPED, Ordering::SeqCst);
    }
}

impl Drop for QueryServer {
    fn drop(&mut self) {
        self.drain();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gibbs::counts::LdaCounts;
    use crate::util::rng::Rng;

    fn snapshot(seed: u64, k: usize, v: usize) -> ModelSnapshot {
        let mut rng = Rng::new(seed);
        let mut counts = LdaCounts::zeros(4, v, k);
        for w in 0..v {
            for t in 0..k {
                let c = (1 + rng.gen_range(50)) as f32;
                counts.word_topic[w * k + t] = c;
                counts.topic[t] += c as u32;
            }
        }
        ModelSnapshot::from_counts(&counts, 0.5, 0.1, seed)
    }

    fn cfg() -> ServeConfig {
        ServeConfig { workers: 2, queue_capacity: 16, max_batch: 4, ..Default::default() }
    }

    #[test]
    fn replies_match_the_engine_oracle() {
        let snap = snapshot(31, 8, 64);
        let words = vec![1u32, 5, 9, 1, 40];
        let mut scratch = FoldScratch::new();
        let oracle = engine::fold_in(&snap, &mut scratch, &words, 77, 10);
        let server = QueryServer::start(snapshot(31, 8, 64), cfg());
        let reply = server.query(77, words, None).unwrap();
        assert_eq!(reply.id, 77);
        assert_eq!(reply.iters, 10);
        assert!(!reply.degraded);
        assert_eq!(reply.theta, oracle, "server reply must be bit-identical to oracle");
        server.drain();
        assert_eq!(server.metrics().completed.get(), 1);
    }

    #[test]
    fn concurrent_queries_are_independent_of_batching() {
        let server = Arc::new(QueryServer::start(snapshot(32, 8, 64), cfg()));
        let mut scratch = FoldScratch::new();
        let oracle_snap = snapshot(32, 8, 64);
        let words = |id: u64| vec![(id % 64) as u32, 3, 17, 60];
        let threads: Vec<_> = (0..24u64)
            .map(|id| {
                let server = Arc::clone(&server);
                std::thread::spawn(move || (id, server.query(id, words(id), None).unwrap()))
            })
            .collect();
        for t in threads {
            let (id, reply) = t.join().unwrap();
            let oracle =
                engine::fold_in(&oracle_snap, &mut scratch, &words(id), id, reply.iters);
            assert_eq!(reply.theta, oracle, "id={id}");
        }
        server.drain();
    }

    #[test]
    fn full_queue_is_typed_overload() {
        // Zero workers: nothing dequeues, so admission control is
        // exercised deterministically.
        let c = ServeConfig { workers: 0, queue_capacity: 3, ..cfg() };
        let server = QueryServer::start(snapshot(33, 4, 16), c);
        let mut handles = Vec::new();
        for id in 0..3 {
            handles.push(server.submit(id, vec![1, 2], None).unwrap());
        }
        assert_eq!(server.submit(9, vec![1], None).unwrap_err(), ServeError::Overloaded);
        assert_eq!(server.metrics().rejected_overload.get(), 1);
        server.drain();
        for h in handles {
            assert_eq!(h.wait().unwrap_err(), ServeError::ShuttingDown);
        }
        assert_eq!(server.submit(10, vec![1], None).unwrap_err(), ServeError::ShuttingDown);
    }

    #[test]
    fn expired_deadline_is_shed_without_sampling() {
        let server = QueryServer::start(snapshot(34, 4, 16), cfg());
        let err = server.query(1, vec![1, 2, 3], Some(Duration::ZERO)).unwrap_err();
        assert_eq!(err, ServeError::Deadline);
        server.drain();
        assert_eq!(server.metrics().shed_deadline.get(), 1);
        assert_eq!(server.metrics().completed.get(), 0);
    }

    #[test]
    fn out_of_vocab_word_is_bad_request() {
        let server = QueryServer::start(snapshot(35, 4, 16), cfg());
        match server.query(1, vec![16], None) {
            Err(ServeError::BadRequest(msg)) => assert!(msg.contains("16"), "{msg}"),
            other => panic!("{other:?}"),
        }
        server.drain();
    }

    #[test]
    fn degradation_ramps_iterations_toward_the_floor() {
        let inner = Inner {
            cfg: ServeConfig {
                workers: 0,
                queue_capacity: 100,
                max_batch: 8,
                fold_iters: 10,
                min_fold_iters: 2,
                degrade_at: 0.5,
            },
            queue: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            state: AtomicU8::new(RUNNING),
            snapshot: RwLock::new(Arc::new(snapshot(36, 4, 16))),
            metrics: ServeMetrics::new(),
            tracer: None,
        };
        assert_eq!(inner.iters_for_depth(0), 10);
        assert_eq!(inner.iters_for_depth(50), 10); // at the threshold
        assert_eq!(inner.iters_for_depth(75), 6); // halfway down the ramp
        assert_eq!(inner.iters_for_depth(100), 2); // full queue: floor
        assert_eq!(inner.iters_for_depth(1000), 2); // never below floor
    }

    #[test]
    fn degraded_reply_is_flagged_and_reproducible() {
        // Force permanent degradation (degrade_at = 0 ramps the whole
        // queue range; any nonzero depth at dequeue shrinks iters).
        let c = ServeConfig {
            workers: 1,
            queue_capacity: 4,
            max_batch: 1,
            fold_iters: 10,
            min_fold_iters: 2,
            degrade_at: 0.0,
        };
        let server = QueryServer::start(snapshot(37, 8, 64), c);
        let reply = server.query(5, vec![1, 2, 3], None).unwrap();
        assert!(reply.degraded);
        assert!(reply.iters < 10 && reply.iters >= 2);
        // Reproducible at the reported count.
        let mut scratch = FoldScratch::new();
        let oracle =
            engine::fold_in(&snapshot(37, 8, 64), &mut scratch, &[1, 2, 3], 5, reply.iters);
        assert_eq!(reply.theta, oracle);
        server.drain();
        assert_eq!(server.metrics().degraded.get(), 1);
    }

    #[test]
    fn hot_reload_swaps_and_rejections_keep_serving() {
        let dir = std::env::temp_dir().join(format!("ppserve-reload-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let server = QueryServer::start(snapshot(40, 8, 64), cfg());
        let before = server.query(1, vec![4, 8], None).unwrap();

        // Corrupt candidate: rejected typed, old snapshot still serves.
        let bad = dir.join("bad.ppsnap");
        std::fs::write(&bad, b"PPSNAP1\0 garbage garbage garbage garbage garbage").unwrap();
        assert!(server.reload_from(&bad).is_err());
        assert_eq!(server.metrics().reloads_rejected.get(), 1);
        let after_reject = server.query(1, vec![4, 8], None).unwrap();
        assert_eq!(before.theta, after_reject.theta);

        // Shape mismatch: rejected typed.
        let small = dir.join("small.ppsnap");
        snapshot(41, 4, 64).write(&small).unwrap();
        match server.reload_from(&small) {
            Err(SnapshotError::Mismatch { .. }) => {}
            other => panic!("{other:?}"),
        }

        // Good candidate (same shape, new seed): swapped atomically.
        let good = dir.join("good.ppsnap");
        snapshot(42, 8, 64).write(&good).unwrap();
        server.reload_from(&good).unwrap();
        assert_eq!(server.metrics().reloads_ok.get(), 1);
        let after = server.query(1, vec![4, 8], None).unwrap();
        assert_ne!(before.theta, after.theta, "new snapshot should answer differently");
        // And deterministically against the reloaded model.
        let mut scratch = FoldScratch::new();
        let oracle = engine::fold_in(
            &ModelSnapshot::load(&good).unwrap(),
            &mut scratch,
            &[4, 8],
            1,
            after.iters,
        );
        assert_eq!(after.theta, oracle);
        server.drain();
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn drain_completes_queued_work() {
        let server = QueryServer::start(snapshot(43, 8, 64), cfg());
        let handles: Vec<_> =
            (0..10u64).map(|id| server.submit(id, vec![1, 2, 3], None).unwrap()).collect();
        server.drain();
        let mut ok = 0;
        for h in handles {
            if h.wait().is_ok() {
                ok += 1;
            }
        }
        // Every admitted request was fulfilled (served before the drain
        // finished — none lost, none left hanging).
        assert_eq!(ok + server.metrics().shed_deadline.get() as usize, 10);
        assert_eq!(server.metrics().completed.get() as usize, ok);
    }

    #[cfg(feature = "failpoints")]
    mod chaos {
        use super::*;
        use crate::util::fault::{install, Fault, ANY};

        #[test]
        fn request_panic_is_contained_and_retried_bit_identically() {
            let snap_seed = 50u64;
            let mut scratch = FoldScratch::new();
            let oracle =
                engine::fold_in(&snapshot(snap_seed, 8, 64), &mut scratch, &[7, 9], 3, 10);
            let server = QueryServer::start(snapshot(snap_seed, 8, 64), cfg());
            let _g = install(vec![Fault {
                site: sites::SERVE_REQUEST,
                key: [ANY, 3, 0], // request id 3, first attempt
                kind: FaultKind::Panic,
            }]);
            let reply = server.query(3, vec![7, 9], None).unwrap();
            assert_eq!(reply.theta, oracle, "retried reply must equal undisturbed oracle");
            assert_eq!(server.metrics().panics_contained.get(), 1);
            assert_eq!(server.metrics().retries.get(), 1);
            // The worker survived: it can still serve.
            assert!(server.query(4, vec![1], None).is_ok());
            server.drain();
            assert_eq!(server.metrics().failed.get(), 0);
        }

        #[test]
        fn repeated_panic_exhausts_retry_into_typed_failure() {
            let server = QueryServer::start(snapshot(51, 8, 64), cfg());
            let _g = install(vec![
                Fault { site: sites::SERVE_REQUEST, key: [ANY, 6, 0], kind: FaultKind::Panic },
                Fault { site: sites::SERVE_REQUEST, key: [ANY, 6, 1], kind: FaultKind::Panic },
            ]);
            assert_eq!(server.query(6, vec![2], None).unwrap_err(), ServeError::Panicked);
            assert_eq!(server.metrics().panics_contained.get(), 2);
            assert_eq!(server.metrics().failed.get(), 1);
            // Server still healthy afterwards.
            assert!(server.query(7, vec![2], None).is_ok());
            server.drain();
        }

        #[test]
        fn transient_request_faults_retry_to_the_oracle_reply() {
            for kind in [FaultKind::IoError, FaultKind::TornWrite] {
                let mut scratch = FoldScratch::new();
                let oracle =
                    engine::fold_in(&snapshot(52, 8, 64), &mut scratch, &[5, 6], 8, 10);
                let server = QueryServer::start(snapshot(52, 8, 64), cfg());
                let _g = install(vec![Fault {
                    site: sites::SERVE_REQUEST,
                    key: [ANY, 8, ANY],
                    kind,
                }]);
                let reply = server.query(8, vec![5, 6], None).unwrap();
                assert_eq!(reply.theta, oracle, "{kind:?}");
                server.drain();
                assert_eq!(server.metrics().failed.get(), 0);
            }
        }

        #[test]
        fn reload_faults_never_unseat_the_serving_snapshot() {
            let dir =
                std::env::temp_dir().join(format!("ppserve-chaos-reload-{}", std::process::id()));
            std::fs::create_dir_all(&dir).unwrap();
            let good = dir.join("good.ppsnap");
            snapshot(61, 8, 64).write(&good).unwrap();
            let server = QueryServer::start(snapshot(60, 8, 64), cfg());
            let before = server.query(1, vec![3], None).unwrap();
            for kind in [FaultKind::Panic, FaultKind::IoError, FaultKind::TornWrite] {
                let _g = install(vec![Fault {
                    site: sites::SERVE_RELOAD,
                    key: [fault::path_token(&good), ANY, ANY],
                    kind,
                }]);
                assert!(server.reload_from(&good).is_err(), "{kind:?}");
                // Old snapshot still serving, bit-identically.
                let again = server.query(1, vec![3], None).unwrap();
                assert_eq!(again.theta, before.theta, "{kind:?}");
            }
            assert_eq!(server.metrics().reloads_rejected.get(), 3);
            // Without a fault, the same candidate loads fine.
            server.reload_from(&good).unwrap();
            server.drain();
            std::fs::remove_dir_all(&dir).unwrap();
        }

        #[test]
        fn snapshot_read_faults_during_reload_are_contained() {
            let dir =
                std::env::temp_dir().join(format!("ppserve-chaos-snapread-{}", std::process::id()));
            std::fs::create_dir_all(&dir).unwrap();
            let good = dir.join("good.ppsnap");
            snapshot(63, 8, 64).write(&good).unwrap();
            let server = QueryServer::start(snapshot(62, 8, 64), cfg());
            let before = server.query(2, vec![11], None).unwrap();
            // Panic inside the loader itself (snapshot.read site): the
            // reload boundary contains it and the old model serves on.
            {
                let _g = install(vec![Fault {
                    site: sites::SNAPSHOT_READ,
                    key: [fault::path_token(&good), ANY, ANY],
                    kind: FaultKind::Panic,
                }]);
                match server.reload_from(&good) {
                    Err(SnapshotError::Corrupt { section, .. }) => {
                        assert!(section.contains("panic"), "{section}")
                    }
                    other => panic!("{other:?}"),
                }
            }
            // Transient read error: absorbed by the loader's retry, the
            // reload succeeds.
            {
                let _g = install(vec![Fault {
                    site: sites::SNAPSHOT_READ,
                    key: [fault::path_token(&good), ANY, ANY],
                    kind: FaultKind::IoError,
                }]);
                server.reload_from(&good).unwrap();
            }
            let after = server.query(2, vec![11], None).unwrap();
            assert_ne!(before.theta, after.theta);
            server.drain();
            std::fs::remove_dir_all(&dir).unwrap();
        }
    }
}
