//! Fold-in inference over a frozen [`ModelSnapshot`].
//!
//! A query is a bag of word ids; the engine Gibbs-samples topic
//! assignments for the query's tokens against the *fixed* trained
//! word–topic counts and returns the document–topic mixture θ. Because
//! the model never moves, the per-token conditional
//!
//! ```text
//! p(t) ∝ (n_dk[t] + α) · φ_wt
//!      =  n_dk[t]·φ_wt   (doc bucket — nonzero only for topics in the doc)
//!      +  α·φ_wt         (word bucket — the snapshot's alias table)
//! ```
//!
//! splits into an exact two-bucket mixture: the doc bucket is a walk of
//! the document's nonzero topic list (O(k_doc), k_doc ≤ doc length), and
//! the word bucket is a precomputed O(1) alias draw with total mass
//! `wtotal[w]` straight from the snapshot. One uniform per token decides
//! the bucket *and* the draw within it — unlike the training alias
//! kernel there is no staleness and therefore no Metropolis–Hastings
//! correction; this is an exact Gibbs step.
//!
//! ## Determinism contract
//!
//! The RNG is `Rng::stream(snapshot.seed, request_id)`, and the sampler
//! consumes exactly one `f64` per token per pass (initialization counts
//! as one pass). A reply is therefore a pure function of
//! `(snapshot, request_id, words, iters)` — independent of batching,
//! worker count, queue state, or wall clock. Degraded replies (fewer
//! iterations under overload) consume a strict *prefix* of the stream,
//! so they are reproducible by re-running the oracle at the reported
//! iteration count.

use crate::serve::snapshot::ModelSnapshot;
use crate::util::rng::Rng;

/// Reusable per-worker scratch: zero allocation per request once the
/// high-water marks are reached.
#[derive(Default)]
pub struct FoldScratch {
    /// Dense per-topic counts of the query document, `[K]`.
    n_dk: Vec<u32>,
    /// Topics with `n_dk > 0`, in first-touch order — the doc-bucket
    /// walk order (deterministic; part of the sampling procedure).
    nonzero: Vec<u32>,
    /// Current assignment per token.
    z: Vec<u32>,
}

impl FoldScratch {
    pub fn new() -> Self {
        Self::default()
    }

    fn reset(&mut self, k: usize, tokens: usize) {
        self.n_dk.clear();
        self.n_dk.resize(k, 0);
        self.nonzero.clear();
        self.z.clear();
        self.z.reserve(tokens);
    }

    #[inline]
    fn add(&mut self, t: u32) {
        if self.n_dk[t as usize] == 0 {
            self.nonzero.push(t);
        }
        self.n_dk[t as usize] += 1;
    }

    #[inline]
    fn remove(&mut self, t: u32) {
        self.n_dk[t as usize] -= 1;
        if self.n_dk[t as usize] == 0 {
            let at = self.nonzero.iter().position(|&x| x == t).unwrap();
            self.nonzero.swap_remove(at);
        }
    }

    /// One exact Gibbs draw for word `w` given the current doc counts.
    #[inline]
    fn draw(&self, snap: &ModelSnapshot, w: usize, u: f64) -> u32 {
        // Doc bucket mass: Σ_{t: n_dk>0} n_dk[t]·φ_wt.
        let mut pd = 0.0f64;
        for &t in &self.nonzero {
            pd += self.n_dk[t as usize] as f64 * snap.phi(w, t as usize);
        }
        let pw = snap.wtotal[w];
        let scaled = u * (pd + pw);
        if scaled < pd {
            // Walk the nonzero list to invert the doc-bucket CDF.
            let mut acc = 0.0f64;
            for &t in &self.nonzero {
                acc += self.n_dk[t as usize] as f64 * snap.phi(w, t as usize);
                if scaled < acc {
                    return t;
                }
            }
            // fp slack at the boundary: last nonzero topic.
            *self.nonzero.last().unwrap()
        } else {
            // Word bucket: rescale the leftover uniform into [0,1) and
            // alias-sample (clamped at 1.0 by `sample_with`).
            snap.tables[w].sample_with((scaled - pd) / pw) as u32
        }
    }
}

/// Fold a query document into the snapshot's topic space.
///
/// `words` must all be `< snap.v` (the server validates before
/// dispatch). Returns θ over the K topics:
/// `θ_t = (n_dk[t] + α) / (len + K·α)`.
pub fn fold_in(
    snap: &ModelSnapshot,
    scratch: &mut FoldScratch,
    words: &[u32],
    request_id: u64,
    iters: usize,
) -> Vec<f32> {
    debug_assert!(words.iter().all(|&w| (w as usize) < snap.v));
    let k = snap.k;
    let mut rng = Rng::stream(snap.seed, request_id);
    scratch.reset(k, words.len());
    // Initialization pass: sample each token against the doc counts
    // accumulated so far (the first token's conditional is exactly the
    // word bucket: all-zero doc counts).
    for &w in words {
        let t = scratch.draw(snap, w as usize, rng.f64());
        scratch.add(t);
        scratch.z.push(t);
    }
    // Gibbs passes: remove, resample, re-add.
    for _ in 0..iters {
        for (i, &w) in words.iter().enumerate() {
            let old = scratch.z[i];
            scratch.remove(old);
            let t = scratch.draw(snap, w as usize, rng.f64());
            scratch.add(t);
            scratch.z[i] = t;
        }
    }
    let alpha = snap.alpha;
    let denom = words.len() as f32 + k as f32 * alpha;
    scratch.n_dk.iter().map(|&c| (c as f32 + alpha) / denom).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gibbs::counts::LdaCounts;

    /// Snapshot with a planted block structure: word w prefers topic
    /// w % k strongly.
    fn planted(k: usize, v: usize, seed: u64) -> ModelSnapshot {
        let mut counts = LdaCounts::zeros(4, v, k);
        for w in 0..v {
            for t in 0..k {
                let c = if t == w % k { 500.0 } else { 1.0 };
                counts.word_topic[w * k + t] = c;
                counts.topic[t] += c as u32;
            }
        }
        ModelSnapshot::from_counts(&counts, 0.5, 0.1, seed)
    }

    #[test]
    fn replies_are_deterministic_in_request_id() {
        let snap = planted(8, 64, 42);
        let words: Vec<u32> = vec![3, 11, 19, 3, 27, 5];
        let mut s1 = FoldScratch::new();
        let mut s2 = FoldScratch::new();
        let a = fold_in(&snap, &mut s1, &words, 7, 5);
        let b = fold_in(&snap, &mut s2, &words, 7, 5);
        assert_eq!(a, b, "same (snapshot, id) must be bit-identical");
        // Scratch reuse across different requests must not leak state.
        let c = fold_in(&snap, &mut s1, &words, 8, 5);
        let a_again = fold_in(&snap, &mut s1, &words, 7, 5);
        assert_eq!(a, a_again, "scratch reuse changed the reply");
        assert_ne!(a, c, "different ids should (generically) differ");
    }

    #[test]
    fn theta_is_a_distribution() {
        let snap = planted(8, 64, 1);
        let mut s = FoldScratch::new();
        let theta = fold_in(&snap, &mut s, &[1, 2, 3, 4, 5], 99, 3);
        assert_eq!(theta.len(), 8);
        let sum: f32 = theta.iter().sum();
        assert!((sum - 1.0).abs() < 1e-4, "sum={sum}");
        assert!(theta.iter().all(|&p| p > 0.0));
    }

    #[test]
    fn empty_document_is_the_prior() {
        let snap = planted(4, 16, 2);
        let mut s = FoldScratch::new();
        let theta = fold_in(&snap, &mut s, &[], 0, 10);
        for &p in &theta {
            assert!((p - 0.25).abs() < 1e-6);
        }
    }

    #[test]
    fn recovers_planted_topic() {
        // A document of words all preferring topic 3 should land its
        // mass there, across many request ids.
        let (k, v) = (8usize, 64usize);
        let snap = planted(k, v, 3);
        let words: Vec<u32> = (0..30).map(|i| (3 + (i % 4) * k as u32 * 2) % v as u32).collect();
        // All words ≡ 3 mod k by construction:
        assert!(words.iter().all(|&w| w as usize % k == 3));
        let mut s = FoldScratch::new();
        let mut mass3 = 0.0f64;
        for id in 0..50u64 {
            let theta = fold_in(&snap, &mut s, &words, id, 5);
            mass3 += theta[3] as f64;
        }
        mass3 /= 50.0;
        assert!(mass3 > 0.8, "planted topic mass {mass3}");
    }

    #[test]
    fn degraded_iterations_are_a_prefix_of_the_stream() {
        // The contract the server's degradation mode relies on: running
        // fewer iterations is reproducible by an oracle run at that
        // count (same id, same snapshot) — not some divergent state.
        let snap = planted(8, 64, 4);
        let words = vec![9u32, 17, 25, 33, 41];
        let mut s = FoldScratch::new();
        for iters in [0usize, 1, 2, 5] {
            let a = fold_in(&snap, &mut s, &words, 123, iters);
            let b = fold_in(&snap, &mut s, &words, 123, iters);
            assert_eq!(a, b, "iters={iters}");
        }
    }

    #[test]
    fn snapshot_round_trip_preserves_replies_bit_exactly() {
        // Serving from a loaded snapshot must equal serving from the
        // in-memory original — the bytes on disk define the behaviour.
        let snap = planted(8, 64, 5);
        let path = std::env::temp_dir()
            .join(format!("ppsnap-engine-{}", std::process::id()));
        snap.write(&path).unwrap();
        let loaded = ModelSnapshot::load(&path).unwrap();
        let words = vec![2u32, 14, 30, 2, 61];
        let mut s = FoldScratch::new();
        for id in [0u64, 1, 99, 12345] {
            let a = fold_in(&snap, &mut s, &words, id, 4);
            let b = fold_in(&loaded, &mut s, &words, id, 4);
            assert_eq!(a, b, "id={id}");
        }
        std::fs::remove_file(&path).unwrap();
    }
}
