//! Serve-path metrics, built on the PR-8 [`crate::obs::metrics`]
//! primitives: log-bucketed [`Histogram`]s for the latency decomposition
//! (queue wait / work / end-to-end) and [`Counter`]s for every outcome a
//! request can have. One instance lives in the server's shared state;
//! workers record lock-free.

use crate::obs::metrics::{Counter, Histogram};
use crate::util::json::Json;
use std::time::Duration;

#[derive(Default)]
pub struct ServeMetrics {
    /// End-to-end: admission to reply fulfilled.
    pub latency_ns: Histogram,
    /// Admission to dequeue by a worker.
    pub queue_ns: Histogram,
    /// Fold-in execution alone.
    pub work_ns: Histogram,
    pub accepted: Counter,
    pub completed: Counter,
    /// Admission refusals: queue full.
    pub rejected_overload: Counter,
    /// Dropped at dequeue with an expired deadline — never sampled.
    pub shed_deadline: Counter,
    /// Replies served with reduced fold-in iterations.
    pub degraded: Counter,
    /// Request panics caught by the containment boundary.
    pub panics_contained: Counter,
    /// Contained failures given their one retry.
    pub retries: Counter,
    /// Requests failed after the retry budget (typed `Panicked`).
    pub failed: Counter,
    pub reloads_ok: Counter,
    pub reloads_rejected: Counter,
}

impl ServeMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// One summary object (the shape the serve CLI prints and the bench
    /// embeds in BENCH_JSON rows).
    pub fn summary_json(&self, elapsed: Duration) -> Json {
        let q = |h: &Histogram, p: f64| h.quantile(p) as f64 / 1e6;
        let secs = elapsed.as_secs_f64().max(1e-9);
        let mut j = Json::obj();
        j.set("accepted", self.accepted.get())
            .set("completed", self.completed.get())
            .set("rejected_overload", self.rejected_overload.get())
            .set("shed_deadline", self.shed_deadline.get())
            .set("degraded", self.degraded.get())
            .set("panics_contained", self.panics_contained.get())
            .set("retries", self.retries.get())
            .set("failed", self.failed.get())
            .set("reloads_ok", self.reloads_ok.get())
            .set("reloads_rejected", self.reloads_rejected.get())
            .set("qps", self.completed.get() as f64 / secs)
            .set("latency_p50_ms", q(&self.latency_ns, 0.50))
            .set("latency_p95_ms", q(&self.latency_ns, 0.95))
            .set("latency_p99_ms", q(&self.latency_ns, 0.99))
            .set("queue_p99_ms", q(&self.queue_ns, 0.99))
            .set("work_p99_ms", q(&self.work_ns, 0.99));
        j
    }

    /// Human-readable one-screen summary (serve shutdown line).
    pub fn render(&self, elapsed: Duration) -> String {
        let secs = elapsed.as_secs_f64().max(1e-9);
        format!(
            "served {} ok ({:.1} qps) | p50 {:.2}ms p95 {:.2}ms p99 {:.2}ms | \
             overload {} deadline {} degraded {} | panics {} retries {} failed {} | \
             reloads {}+{}",
            self.completed.get(),
            self.completed.get() as f64 / secs,
            self.latency_ns.quantile(0.50) as f64 / 1e6,
            self.latency_ns.quantile(0.95) as f64 / 1e6,
            self.latency_ns.quantile(0.99) as f64 / 1e6,
            self.rejected_overload.get(),
            self.shed_deadline.get(),
            self.degraded.get(),
            self.panics_contained.get(),
            self.retries.get(),
            self.failed.get(),
            self.reloads_ok.get(),
            self.reloads_rejected.get(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_reports_counts_and_quantiles() {
        let m = ServeMetrics::new();
        m.accepted.add(10);
        m.completed.add(9);
        m.rejected_overload.inc();
        for i in 1..=9u64 {
            m.latency_ns.observe(i * 1_000_000);
        }
        let j = m.summary_json(Duration::from_secs(3));
        assert_eq!(j.get("accepted").and_then(Json::as_u64), Some(10));
        assert_eq!(j.get("completed").and_then(Json::as_u64), Some(9));
        let qps = j.get("qps").and_then(Json::as_f64).unwrap();
        assert!((qps - 3.0).abs() < 1e-9);
        let p50 = j.get("latency_p50_ms").and_then(Json::as_f64).unwrap();
        assert!(p50 > 0.0 && p50 < 10.0, "p50={p50}");
        let line = m.render(Duration::from_secs(3));
        assert!(line.contains("served 9 ok"), "{line}");
    }
}
