//! Artifact discovery + compiled-executable wrappers.
//!
//! `artifacts/manifest.tsv` (written by python/compile/aot.py) maps
//! `(kind, batch, topics)` to an HLO text file. [`Artifacts`] parses it;
//! [`SamplerExe`] / [`LoglikExe`] compile one entry on the PJRT CPU
//! client and expose typed `run` methods matching the L2 signatures:
//!
//! ```text
//! sampler(njk[B,K], nkw[B,K], nk[1,K], unif[B,K], params[1,4]) -> (z[B],)
//! loglik (njk[B,K], nj[B,1], nkw[B,K], nk[1,K], params[1,4])
//!                                             -> (sum[], ll[B])
//! ```

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::util::error::{bail, Context, Result};
use xla::{HloModuleProto, Literal, PjRtLoadedExecutable, XlaComputation};

use crate::runtime::client;

/// Parsed manifest of available artifacts.
#[derive(Clone, Debug)]
pub struct Artifacts {
    dir: PathBuf,
    /// (kind, batch, topics) → file name.
    entries: BTreeMap<(String, usize, usize), String>,
}

impl Artifacts {
    /// Parse `<dir>/manifest.tsv`. Errors if the manifest is missing —
    /// callers that want optional behaviour should check
    /// [`Artifacts::available`] first.
    pub fn discover(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = dir.join("manifest.tsv");
        let text = std::fs::read_to_string(&manifest)
            .with_context(|| format!("read {}", manifest.display()))?;
        let mut entries = BTreeMap::new();
        for (i, line) in text.lines().enumerate() {
            if i == 0 || line.trim().is_empty() {
                continue; // header
            }
            let cols: Vec<&str> = line.split('\t').collect();
            if cols.len() != 4 {
                bail!("manifest line {} malformed: {line:?}", i + 1);
            }
            let kind = cols[0].to_string();
            let batch: usize = cols[1].parse().context("batch")?;
            let k: usize = cols[2].parse().context("topics")?;
            entries.insert((kind, batch, k), cols[3].to_string());
        }
        Ok(Self { dir, entries })
    }

    /// True if an artifact directory with a manifest exists.
    pub fn available(dir: impl AsRef<Path>) -> bool {
        dir.as_ref().join("manifest.tsv").is_file()
    }

    /// Default artifact location: `$PPLDA_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var_os("PPLDA_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }

    pub fn variants(&self, kind: &str) -> Vec<(usize, usize)> {
        self.entries
            .keys()
            .filter(|(k, _, _)| k == kind)
            .map(|&(_, b, t)| (b, t))
            .collect()
    }

    fn path_for(&self, kind: &str, batch: usize, k: usize) -> Result<PathBuf> {
        match self
            .entries
            .get(&(kind.to_string(), batch, k))
        {
            Some(f) => Ok(self.dir.join(f)),
            None => bail!(
                "no {kind} artifact for batch={batch} topics={k}; available: {:?}",
                self.variants(kind)
            ),
        }
    }

    /// Compile the sampler for `(batch, k)`.
    pub fn sampler(&self, batch: usize, k: usize) -> Result<SamplerExe> {
        let exe = compile(&self.path_for("sampler", batch, k)?)?;
        Ok(SamplerExe { exe, batch, k })
    }

    /// Compile the log-likelihood kernel for `(batch, k)`.
    pub fn loglik(&self, batch: usize, k: usize) -> Result<LoglikExe> {
        let exe = compile(&self.path_for("loglik", batch, k)?)?;
        Ok(LoglikExe { exe, batch, k })
    }
}

fn compile(path: &Path) -> Result<PjRtLoadedExecutable> {
    let client = client::cpu()?;
    let proto = HloModuleProto::from_text_file(path)
        .with_context(|| format!("parse HLO text {}", path.display()))?;
    let comp = XlaComputation::from_proto(&proto);
    client
        .compile(&comp)
        .with_context(|| format!("compile {}", path.display()))
}

fn literal_2d(data: &[f32], rows: usize, cols: usize) -> Result<Literal> {
    debug_assert_eq!(data.len(), rows * cols);
    Ok(Literal::vec1(data).reshape(&[rows as i64, cols as i64])?)
}

/// Compiled topic-sampling kernel (Gumbel-max collapsed-Gibbs draw).
pub struct SamplerExe {
    exe: PjRtLoadedExecutable,
    pub batch: usize,
    pub k: usize,
}

impl SamplerExe {
    /// All slices must match the compiled shapes: `njk`, `nkw`, `unif`
    /// are `[batch*k]`, `nk` is `[k]`, `params` is `(α, β, Kα, Wβ)`.
    pub fn run(
        &self,
        njk: &[f32],
        nkw: &[f32],
        nk: &[f32],
        unif: &[f32],
        params: [f32; 4],
    ) -> Result<Vec<i32>> {
        let b = self.batch;
        let k = self.k;
        let args = [
            literal_2d(njk, b, k)?,
            literal_2d(nkw, b, k)?,
            literal_2d(nk, 1, k)?,
            literal_2d(unif, b, k)?,
            literal_2d(&params, 1, 4)?,
        ];
        let result = self.exe.execute::<Literal>(&args)?[0][0].to_literal_sync()?;
        let z = result.to_tuple1()?;
        Ok(z.to_vec::<i32>()?)
    }
}

/// Compiled per-token log-likelihood kernel.
pub struct LoglikExe {
    exe: PjRtLoadedExecutable,
    pub batch: usize,
    pub k: usize,
}

impl LoglikExe {
    /// Returns (batch sum, per-token log-likelihoods).
    pub fn run(
        &self,
        njk: &[f32],
        nj: &[f32],
        nkw: &[f32],
        nk: &[f32],
        params: [f32; 4],
    ) -> Result<(f32, Vec<f32>)> {
        let b = self.batch;
        let k = self.k;
        let args = [
            literal_2d(njk, b, k)?,
            literal_2d(nj, b, 1)?,
            literal_2d(nkw, b, k)?,
            literal_2d(nk, 1, k)?,
            literal_2d(&params, 1, 4)?,
        ];
        let result = self.exe.execute::<Literal>(&args)?[0][0].to_literal_sync()?;
        let (sum, ll) = result.to_tuple2()?;
        Ok((sum.to_vec::<f32>()?[0], ll.to_vec::<f32>()?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts() -> Option<Artifacts> {
        let dir = Artifacts::default_dir();
        if !Artifacts::available(&dir) {
            eprintln!("skipping runtime test: no artifacts at {dir:?} (run `make artifacts`)");
            return None;
        }
        Some(Artifacts::discover(dir).unwrap())
    }

    #[test]
    fn manifest_discovery_lists_variants() {
        let Some(a) = artifacts() else { return };
        let variants = a.variants("sampler");
        assert!(!variants.is_empty());
        assert!(a.variants("loglik").len() == variants.len());
        assert!(a.sampler(999_999, 3).is_err(), "unknown variant must error");
    }

    #[test]
    fn sampler_runs_and_respects_dominant_topic() {
        let Some(a) = artifacts() else { return };
        let (b, k) = a.variants("sampler")[0];
        let exe = a.sampler(b, k).unwrap();
        // Topic 3 has overwhelming counts for every token → argmax must
        // pick it regardless of Gumbel noise.
        let mut njk = vec![0.0f32; b * k];
        let mut nkw = vec![0.0f32; b * k];
        for i in 0..b {
            njk[i * k + 3] = 1e6;
            nkw[i * k + 3] = 1e6;
        }
        let nk = vec![1.0f32; k];
        let unif = vec![0.5f32; b * k];
        let z = exe
            .run(&njk, &nkw, &nk, &unif, [0.5, 0.1, 0.5 * k as f32, 0.1 * 100.0])
            .unwrap();
        assert_eq!(z.len(), b);
        assert!(z.iter().all(|&t| t == 3), "expected all 3s");
    }

    #[test]
    fn loglik_matches_native_computation() {
        let Some(a) = artifacts() else { return };
        let (b, k) = a.variants("loglik")[0];
        let exe = a.loglik(b, k).unwrap();
        let (alpha, beta, w) = (0.5f32, 0.1f32, 1000usize);
        // Small deterministic counts.
        let njk: Vec<f32> = (0..b * k).map(|i| ((i * 7) % 5) as f32).collect();
        let nkw: Vec<f32> = (0..b * k).map(|i| ((i * 11) % 4) as f32).collect();
        let nk: Vec<f32> = (0..k).map(|t| 50.0 + t as f32).collect();
        let nj: Vec<f32> = (0..b)
            .map(|i| njk[i * k..(i + 1) * k].iter().sum())
            .collect();
        let params = [alpha, beta, alpha * k as f32, beta * w as f32];
        let (sum, ll) = exe.run(&njk, &nj, &nkw, &nk, params).unwrap();
        assert_eq!(ll.len(), b);

        // Native reference.
        for i in 0..b {
            let mut p = 0.0f64;
            for t in 0..k {
                let theta = (njk[i * k + t] as f64 + alpha as f64)
                    / (nj[i] as f64 + (alpha * k as f32) as f64);
                let phi = (nkw[i * k + t] as f64 + beta as f64)
                    / (nk[t] as f64 + (beta * w as f32) as f64);
                p += theta * phi;
            }
            let want = p.ln();
            assert!(
                (ll[i] as f64 - want).abs() < 1e-4,
                "token {i}: xla {} vs native {want}",
                ll[i]
            );
        }
        let native_sum: f64 = ll.iter().map(|&v| v as f64).sum();
        assert!((sum as f64 - native_sum).abs() < native_sum.abs() * 1e-4 + 1e-3);
    }
}
