//! Per-thread PJRT CPU client.
//!
//! The `xla` crate's `PjRtClient` is `Rc`-based (`!Send`/`!Sync`), so the
//! client is cached per thread rather than process-wide. All XLA-path
//! execution happens on the coordinator thread anyway — the parallel
//! Gibbs workers use the native kernel; the XLA backend is a
//! single-threaded batched executor (see `sampler_xla`).

use std::cell::RefCell;

use crate::util::error::Result;
use xla::PjRtClient;

thread_local! {
    static CLIENT: RefCell<Option<PjRtClient>> = const { RefCell::new(None) };
}

/// This thread's CPU client (created on first use, then cached; the
/// returned handle is a cheap `Rc` clone).
pub fn cpu() -> Result<PjRtClient> {
    CLIENT.with(|cell| {
        let mut slot = cell.borrow_mut();
        if slot.is_none() {
            *slot = Some(PjRtClient::cpu()?);
        }
        Ok(slot.as_ref().unwrap().clone())
    })
}

#[cfg(test)]
mod tests {
    #[test]
    fn client_initializes() {
        let c = super::cpu().expect("PJRT CPU client");
        assert!(c.device_count() >= 1);
        let name = c.platform_name().to_lowercase();
        assert!(name.contains("cpu") || name.contains("host"), "{name}");
        // Second call reuses the cached client (cheap clone, no crash).
        let _ = super::cpu().unwrap();
    }
}
