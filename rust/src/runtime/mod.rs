//! PJRT runtime: load the AOT-compiled JAX/Pallas artifacts (HLO text)
//! and run them from the rust hot path.
//!
//! Python runs only at build time (`make artifacts`); this module makes
//! the binary self-contained afterwards. The interchange format is HLO
//! *text* — the bundled xla_extension 0.5.1 rejects serialized protos
//! from jax ≥ 0.5 (64-bit instruction ids), while the text parser
//! reassigns ids (see /opt/xla-example/README.md and python/compile/aot.py).

pub mod client;
pub mod executor;
pub mod sampler_xla;

pub use executor::{Artifacts, LoglikExe, SamplerExe};
