//! XLA-offloaded sampling and perplexity backends.
//!
//! These drive the AOT-compiled JAX/Pallas kernels from the coordinator:
//! the sweep is batched — for each batch of `B` tokens the coordinator
//! gathers the count rows (with per-token self-exclusion on `n_jk`,
//! `n_kw`), ships them to the compiled kernel, and applies the returned
//! assignments as count deltas.
//!
//! Within a batch the gathered counts are frozen (the ESCA-style
//! approximation): two tokens of the same document see the same stale
//! row. The topic totals `n_k` are also batch-frozen without
//! self-exclusion — an `O(1/n_k)` perturbation. Batch size therefore
//! trades kernel efficiency against sampling fidelity; the native
//! backend remains the exact reference and the equivalence tests in
//! `rust/tests/` bound the perplexity gap.

use crate::util::error::Result;

use crate::corpus::bow::BagOfWords;
use crate::gibbs::counts::LdaCounts;
use crate::gibbs::sampler::Hyper;
use crate::gibbs::tokens::TokenBlock;
use crate::runtime::executor::{LoglikExe, SamplerExe};
use crate::util::rng::Rng;

fn params_of(h: &Hyper) -> [f32; 4] {
    [h.alpha, h.beta, h.alpha * h.k as f32, h.wbeta]
}

/// Batched XLA sweep over a token block (serial semantics).
pub struct XlaSampler {
    exe: SamplerExe,
    njk: Vec<f32>,
    nkw: Vec<f32>,
    nk: Vec<f32>,
    unif: Vec<f32>,
}

impl XlaSampler {
    pub fn new(exe: SamplerExe) -> Self {
        let (b, k) = (exe.batch, exe.k);
        Self {
            exe,
            njk: vec![0.0; b * k],
            nkw: vec![0.0; b * k],
            nk: vec![0.0; k],
            unif: vec![0.0; b * k],
        }
    }

    pub fn batch(&self) -> usize {
        self.exe.batch
    }

    /// One full sweep of `block` against `counts`, in batches of the
    /// compiled size. Counts and assignments are updated in place.
    pub fn sweep(
        &mut self,
        block: &mut TokenBlock,
        counts: &mut LdaCounts,
        h: &Hyper,
        rng: &mut Rng,
    ) -> Result<()> {
        assert_eq!(h.k, self.exe.k, "model K != compiled K");
        let b = self.exe.batch;
        let k = self.exe.k;
        let params = params_of(h);

        let mut start = 0;
        while start < block.len() {
            let len = (block.len() - start).min(b);

            // Gather rows with per-token self-exclusion; pad the tail
            // with benign zeros (outputs beyond `len` are ignored).
            for i in 0..b {
                let dst_njk = &mut self.njk[i * k..(i + 1) * k];
                let dst_nkw = &mut self.nkw[i * k..(i + 1) * k];
                if i < len {
                    let t = start + i;
                    let d = block.docs[t] as usize;
                    let w = block.words[t] as usize;
                    let old = block.z[t] as usize;
                    dst_njk.copy_from_slice(counts.doc_row(d));
                    dst_njk[old] -= 1.0;
                    dst_nkw.copy_from_slice(counts.word_row(w));
                    dst_nkw[old] -= 1.0;
                } else {
                    dst_njk.fill(0.0);
                    dst_nkw.fill(0.0);
                }
            }
            for (dst, &src) in self.nk.iter_mut().zip(&counts.topic) {
                *dst = src as f32;
            }
            for u in &mut self.unif {
                *u = rng.f32_open();
            }

            let z_new = self
                .exe
                .run(&self.njk, &self.nkw, &self.nk, &self.unif, params)?;

            // Apply deltas.
            for i in 0..len {
                let t = start + i;
                let d = block.docs[t] as usize;
                let w = block.words[t] as usize;
                let old = block.z[t] as usize;
                let new = z_new[i] as usize;
                debug_assert!(new < k);
                if new != old {
                    counts.doc_topic[d * k + old] -= 1.0;
                    counts.doc_topic[d * k + new] += 1.0;
                    counts.word_topic[w * k + old] -= 1.0;
                    counts.word_topic[w * k + new] += 1.0;
                    counts.topic[old] -= 1;
                    counts.topic[new] += 1;
                    block.z[t] = new as u32;
                }
            }
            start += len;
        }
        Ok(())
    }
}

/// Batched XLA perplexity over corpus cells (weighting per-token
/// log-likelihoods by cell counts).
pub struct XlaPerplexity {
    exe: LoglikExe,
    njk: Vec<f32>,
    nj: Vec<f32>,
    nkw: Vec<f32>,
    nk: Vec<f32>,
    weights: Vec<f64>,
}

impl XlaPerplexity {
    pub fn new(exe: LoglikExe) -> Self {
        let (b, k) = (exe.batch, exe.k);
        Self {
            exe,
            njk: vec![0.0; b * k],
            nj: vec![0.0; b],
            nkw: vec![0.0; b * k],
            nk: vec![0.0; k],
            weights: vec![0.0; b],
        }
    }

    pub fn perplexity(
        &mut self,
        bow: &BagOfWords,
        counts: &LdaCounts,
        h: &Hyper,
    ) -> Result<f64> {
        assert_eq!(h.k, self.exe.k, "model K != compiled K");
        let b = self.exe.batch;
        let k = self.exe.k;
        let params = params_of(h);
        for (dst, &src) in self.nk.iter_mut().zip(&counts.topic) {
            *dst = src as f32;
        }

        let mut ll = 0.0f64;
        let mut fill = 0usize;
        // Iterate distinct cells; flush a batch whenever full.
        for j in 0..bow.num_docs() {
            let nj = counts.doc_len(j) as f32;
            for e in bow.doc(j) {
                let i = fill;
                self.njk[i * k..(i + 1) * k].copy_from_slice(counts.doc_row(j));
                self.nkw[i * k..(i + 1) * k]
                    .copy_from_slice(counts.word_row(e.word as usize));
                self.nj[i] = nj;
                self.weights[i] = e.count as f64;
                fill += 1;
                if fill == b {
                    ll += self.flush(fill, params)?;
                    fill = 0;
                }
            }
        }
        if fill > 0 {
            // Pad with harmless rows (weight 0).
            for i in fill..b {
                self.njk[i * k..(i + 1) * k].fill(0.0);
                self.nkw[i * k..(i + 1) * k].fill(0.0);
                self.nj[i] = 0.0;
                self.weights[i] = 0.0;
            }
            ll += self.flush(b, params)?;
        }
        Ok((-ll / bow.num_tokens().max(1) as f64).exp())
    }

    fn flush(&mut self, rows: usize, params: [f32; 4]) -> Result<f64> {
        let (_sum, per_token) =
            self.exe
                .run(&self.njk, &self.nj, &self.nkw, &self.nk, params)?;
        Ok(per_token[..rows]
            .iter()
            .zip(&self.weights[..rows])
            .map(|(&l, &w)| l as f64 * w)
            .sum())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::synthetic::{generate, Profile};
    use crate::gibbs::perplexity as native_perplexity;
    use crate::runtime::executor::Artifacts;

    fn artifacts() -> Option<Artifacts> {
        let dir = Artifacts::default_dir();
        if !Artifacts::available(&dir) {
            eprintln!("skipping xla sampler test: run `make artifacts` first");
            return None;
        }
        Some(Artifacts::discover(dir).unwrap())
    }

    #[test]
    fn xla_perplexity_matches_native() {
        let Some(a) = artifacts() else { return };
        let (b, k) = a.variants("loglik")[0];
        let bow = generate(&Profile::tiny(), 71);
        let mut rng = Rng::new(1);
        let block = TokenBlock::from_corpus(&bow, k, &mut rng);
        let mut counts = LdaCounts::zeros(bow.num_docs(), bow.num_words(), k);
        counts.absorb(&block);
        let h = Hyper::new(k, 0.5, 0.1, bow.num_words());

        let mut xp = XlaPerplexity::new(a.loglik(b, k).unwrap());
        let xla = xp.perplexity(&bow, &counts, &h).unwrap();
        let native = native_perplexity::perplexity(&bow, &counts, &h);
        let rel = (xla - native).abs() / native;
        assert!(rel < 1e-3, "xla {xla} vs native {native} (rel {rel})");
    }

    #[test]
    fn xla_sweep_preserves_invariants_and_learns() {
        let Some(a) = artifacts() else { return };
        let (b, k) = a.variants("sampler")[0];
        let bow = generate(&Profile::tiny(), 72);
        let mut rng = Rng::new(2);
        let mut block = TokenBlock::from_corpus(&bow, k, &mut rng);
        let mut counts = LdaCounts::zeros(bow.num_docs(), bow.num_words(), k);
        counts.absorb(&block);
        let h = Hyper::new(k, 0.5, 0.1, bow.num_words());
        let p0 = native_perplexity::perplexity(&bow, &counts, &h);

        let mut sampler = XlaSampler::new(a.sampler(b, k).unwrap());
        for _ in 0..10 {
            sampler.sweep(&mut block, &mut counts, &h, &mut rng).unwrap();
        }
        assert_eq!(counts.total(), bow.num_tokens());
        assert!(counts.check_consistency(&[&block]).is_ok());
        let p1 = native_perplexity::perplexity(&bow, &counts, &h);
        assert!(p1 < p0 * 0.95, "XLA sweeps should learn: {p0} → {p1}");
    }
}
