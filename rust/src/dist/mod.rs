//! Distributed multi-process training: a coordinator process drives
//! `pplda worker` processes over TCP, with heartbeats, deterministic
//! shard reassignment, and bit-identical crash recovery.
//!
//! The split follows the paper's data-parallel structure: partitioning
//! already makes epoch tasks independent (disjoint doc/word rows), so
//! the only state a worker needs is the task itself. That keeps workers
//! stateless and makes every fault-handling policy — reassignment,
//! speculation, local fallback — a pure re-execution of the same
//! `(sweep, partition)` RNG stream over the same input block, which is
//! how distributed runs stay bit-identical to a single process
//! (`docs/distributed.md` states the full contract).
//!
//! * [`wire`] — the two-plane protocol: JSON-lines control messages
//!   (hello/ping/pong/shutdown, shared with [`crate::util::net`]) and
//!   CRC-framed binary task/delta frames.
//! * [`worker`] — the worker process: accept loop, heartbeat responder,
//!   task execution through the same [`crate::scheduler::pool::run_task`]
//!   the in-process executors use.
//! * [`coordinator`] — [`DistExec`], the [`Executor`] that ships epochs
//!   to workers; failure detection and recovery live here.
//!
//! [`Executor`]: crate::scheduler::pool::Executor

pub mod coordinator;
pub mod wire;
pub mod worker;

pub use coordinator::{DistExec, DistOptions, NodeError};
pub use worker::{serve_on, serve_worker, WorkerOptions};

use std::io;
use std::net::{SocketAddr, ToSocketAddrs};
use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use crate::coordinator::checkpoint::{self, Manifest};
use crate::coordinator::TrainConfig;
use crate::corpus::BagOfWords;
use crate::obs::trace::Tracer;
use crate::partition::Plan;
use crate::scheduler::exec::ParallelLda;
use crate::util::interrupt;

/// Parse a workers file: one `host:port` per line, `#` comments and
/// blank lines ignored. Node index == line order, and determines both
/// the worker's trace lane and its failpoint key.
pub fn parse_workers_file(path: &Path) -> io::Result<Vec<SocketAddr>> {
    let text = std::fs::read_to_string(path)?;
    let mut out = Vec::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let addr = line
            .to_socket_addrs()
            .map_err(|e| {
                io::Error::new(
                    io::ErrorKind::InvalidInput,
                    format!("{}:{}: bad worker address {line:?}: {e}", path.display(), lineno + 1),
                )
            })?
            .next()
            .ok_or_else(|| {
                io::Error::new(
                    io::ErrorKind::InvalidInput,
                    format!("{}:{}: {line:?} resolved to nothing", path.display(), lineno + 1),
                )
            })?;
        out.push(addr);
    }
    if out.is_empty() {
        return Err(io::Error::new(
            io::ErrorKind::InvalidInput,
            format!("{}: no worker addresses", path.display()),
        ));
    }
    Ok(out)
}

/// What a distributed training run reports back to the CLI.
#[derive(Debug, Clone)]
pub struct DistReport {
    /// Sweeps actually completed (< `cfg.iters` only when interrupted).
    pub sweeps: usize,
    /// `(sweep, perplexity)` evaluation curve.
    pub curve: Vec<(usize, f64)>,
    pub final_perplexity: f64,
    pub train_secs: f64,
    pub tokens_per_sec: f64,
    /// Tasks re-dispatched after a node died.
    pub reassigns: u64,
    /// Speculative straggler duplicates dispatched.
    pub speculations: u64,
    /// Tasks the coordinator ran itself with no worker left.
    pub local_fallbacks: u64,
    /// Path of the final checkpoint, when one was requested.
    pub checkpoint: Option<std::path::PathBuf>,
}

/// Train LDA through a [`DistExec`]: the distributed counterpart of the
/// single-process train loop. The model, schedule, and evaluation all
/// live in this process; only epoch task execution is remote, so the
/// resulting counts are bit-identical to `--mode sequential` over the
/// same `(corpus, plan, seed)` — faults included.
pub fn train_lda_dist(
    bow: &BagOfWords,
    plan: &Plan,
    cfg: &TrainConfig,
    exec: &mut DistExec,
    tracer: Option<&Arc<Tracer>>,
    checkpoint_dir: Option<&Path>,
) -> DistReport {
    let mut lda = ParallelLda::init_scheduled(
        bow,
        plan,
        cfg.topics,
        cfg.alpha,
        cfg.beta,
        cfg.seed,
        cfg.schedule,
        cfg.resolved_workers(plan.p),
    );
    lda.set_kernel(cfg.kernel);
    lda.set_balance(cfg.balance);
    lda.set_commit(cfg.commit);
    if let Some(tr) = tracer {
        lda.set_tracer(Some(tr.clone()));
    }
    let t0 = Instant::now();
    let mut curve = Vec::new();
    for s in 0..cfg.iters {
        lda.sweep_with(exec);
        if cfg.eval_every > 0 && (s + 1) % cfg.eval_every == 0 && s + 1 < cfg.iters {
            curve.push((s + 1, lda.perplexity(bow)));
        }
        if interrupt::requested() {
            break;
        }
    }
    let train_secs = t0.elapsed().as_secs_f64();
    let sweeps = lda.sweeps_done();
    let final_perplexity = lda.perplexity(bow);
    curve.push((sweeps, final_perplexity));
    let checkpoint = checkpoint_dir.map(|dir| {
        let manifest = Manifest::lda(bow, plan, cfg, sweeps);
        checkpoint::write_lda(&lda, &manifest, dir).expect("write final checkpoint")
    });
    DistReport {
        sweeps,
        curve,
        final_perplexity,
        train_secs,
        tokens_per_sec: if train_secs > 0.0 {
            bow.num_tokens() as f64 * sweeps as f64 / train_secs
        } else {
            0.0
        },
        reassigns: exec.reassigns(),
        speculations: exec.speculations(),
        local_fallbacks: exec.local_fallbacks(),
        checkpoint,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn workers_file_parses_addresses_comments_and_blanks() {
        let dir = std::env::temp_dir().join(format!("pplda-workers-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("workers.txt");
        std::fs::write(
            &path,
            "# fleet\n127.0.0.1:7001\n\n127.0.0.1:7002   # second box\n",
        )
        .unwrap();
        let addrs = parse_workers_file(&path).unwrap();
        assert_eq!(addrs.len(), 2);
        assert_eq!(addrs[0].port(), 7001);
        assert_eq!(addrs[1].port(), 7002);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn workers_file_rejects_garbage_and_empty() {
        let dir = std::env::temp_dir().join(format!("pplda-workers-bad-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let bad = dir.join("bad.txt");
        std::fs::write(&bad, "not-an-address\n").unwrap();
        assert!(parse_workers_file(&bad).is_err());
        let empty = dir.join("empty.txt");
        std::fs::write(&empty, "# only comments\n\n").unwrap();
        assert!(parse_workers_file(&empty).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
