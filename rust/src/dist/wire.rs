//! The distributed wire protocol: CRC-framed binary data plane.
//!
//! One TCP stream per worker carries two interleaved planes:
//!
//! - **Control plane** — newline-delimited JSON (hello/ping/pong/
//!   shutdown/err), sharing the line primitives in [`crate::util::net`]
//!   with the serve protocol. A control line always starts with `{`.
//! - **Data plane** — binary frames for task payloads and count deltas.
//!   A frame starts with the magic `PPW1`, so a reader can sniff the
//!   first byte of the stream and parse either plane ([`recv_mixed`]).
//!
//! # Frame layout
//!
//! ```text
//! offset  size  field
//! 0       4     magic  b"PPW1"
//! 4       1     kind   (1 = task, 2 = delta)
//! 5       4     payload length, u32 LE
//! 9       4     crc32(payload), u32 LE
//! 13      len   payload
//! ```
//!
//! Every defect a hostile or failing transport can produce — torn
//! header, torn payload, flipped bit, wrong magic, absurd length —
//! surfaces as a typed [`WireError`], never a panic and never a
//! silently wrong message: the frame CRC covers the whole payload, and
//! the task payload's embedded token block is additionally a complete
//! checksummed `PPSHARD3` image ([`crate::corpus::shard`]), so a
//! partition crosses the network under exactly the integrity checks it
//! crosses the spill store with.
//!
//! # Payloads
//!
//! All integers little-endian. [`TaskMsg`]: the full closure of one
//! task — hyperparameters, pre-salted RNG seed, topic-total snapshot,
//! the doc/emit count rows the task touches (with their global row
//! ids), and the token block with doc/word ids *remapped to local row
//! indices* (kernels use ids only as row indices, so the worker's
//! compact matrices behave identically to the coordinator's full ones).
//! [`DeltaMsg`]: the task's signed topic-total delta plus the
//! *absolute* updated rows and `z` — absolute so that a duplicate
//! delivery (speculative re-execution, retransmit) is idempotent under
//! the coordinator's first-ticket-wins dedup.

use crate::corpus::shard;
use crate::gibbs::tokens::TokenBlock;
use crate::kernel::KernelKind;
use crate::util::crc::crc32;
use std::io::{self, BufRead, Read, Write};
use std::path::Path;

/// Frame magic. First byte (`P`) differs from `{`, which is what lets
/// [`recv_mixed`] sniff the plane.
pub const MAGIC: [u8; 4] = *b"PPW1";
/// Frame header bytes: magic + kind + len + crc.
pub const HEADER: usize = 13;
/// Largest accepted payload (1 GiB). A declared length beyond this is
/// reported as [`WireError::TooLarge`] instead of attempted — a flipped
/// length byte must not look like an allocation request.
pub const MAX_FRAME: u32 = 1 << 30;
/// Frame kind: coordinator → worker task payload.
pub const KIND_TASK: u8 = 1;
/// Frame kind: worker → coordinator delta payload.
pub const KIND_DELTA: u8 = 2;

/// Typed failure taxonomy of the wire layer. Everything the transport
/// or a corrupt peer can do lands here; nothing in this module panics
/// on malformed input.
#[derive(Debug)]
pub enum WireError {
    /// Transport failure (includes read timeouts — classify with
    /// [`crate::util::net::is_timeout`]).
    Io(io::Error),
    /// Frame did not start with [`MAGIC`] — the stream is unsynced.
    BadMagic([u8; 4]),
    /// Unknown frame kind byte.
    BadKind(u8),
    /// Declared payload length exceeds [`MAX_FRAME`].
    TooLarge(u64),
    /// Stream ended mid-header or mid-payload (torn frame).
    Truncated { want: usize, got: usize },
    /// An integrity check failed: `kind` names the failing layer
    /// ("frame" CRC, "block" image, payload "layout").
    Corrupt { kind: &'static str, detail: String },
    /// Structurally valid bytes that violate the protocol (unexpected
    /// message, inconsistent counts).
    Protocol(String),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Io(e) => write!(f, "wire io: {e}"),
            WireError::BadMagic(m) => write!(f, "bad frame magic {m:02x?}"),
            WireError::BadKind(k) => write!(f, "unknown frame kind {k}"),
            WireError::TooLarge(n) => write!(f, "frame payload of {n} bytes exceeds cap"),
            WireError::Truncated { want, got } => {
                write!(f, "torn frame: wanted {want} bytes, stream ended at {got}")
            }
            WireError::Corrupt { kind, detail } => write!(f, "corrupt {kind}: {detail}"),
            WireError::Protocol(d) => write!(f, "protocol violation: {d}"),
        }
    }
}

impl std::error::Error for WireError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            WireError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<io::Error> for WireError {
    fn from(e: io::Error) -> Self {
        WireError::Io(e)
    }
}

/// One unit read off the mixed stream.
#[derive(Debug)]
pub enum Incoming {
    /// A control-plane JSON line (already newline-stripped, unparsed).
    Line(String),
    /// A CRC-verified data-plane frame.
    Frame { kind: u8, payload: Vec<u8> },
    /// Clean end of stream.
    Eof,
}

/// Write one frame: header ([`MAGIC`], kind, length, payload CRC) then
/// the payload, flushed.
pub fn send_frame<W: Write>(w: &mut W, kind: u8, payload: &[u8]) -> io::Result<()> {
    let mut head = [0u8; HEADER];
    head[..4].copy_from_slice(&MAGIC);
    head[4] = kind;
    head[5..9].copy_from_slice(&(payload.len() as u32).to_le_bytes());
    head[9..13].copy_from_slice(&crc32(payload).to_le_bytes());
    w.write_all(&head)?;
    w.write_all(payload)?;
    w.flush()
}

/// Read the next unit off the mixed stream: sniff the first available
/// byte — `{` starts a JSON control line, anything else must be a
/// binary frame (whose magic check then catches unsynced garbage).
pub fn recv_mixed<R: BufRead>(r: &mut R) -> Result<Incoming, WireError> {
    let first = {
        let buf = r.fill_buf()?;
        if buf.is_empty() {
            return Ok(Incoming::Eof);
        }
        buf[0]
    };
    if first == b'{' {
        let mut line = String::new();
        if !crate::util::net::recv_line(r, &mut line)? {
            return Ok(Incoming::Eof);
        }
        Ok(Incoming::Line(line))
    } else {
        recv_frame(r)
    }
}

/// Read one binary frame (header + CRC-verified payload). A stream that
/// ends mid-frame yields [`WireError::Truncated`]; a payload whose CRC
/// does not match its header yields [`WireError::Corrupt`].
pub fn recv_frame<R: Read>(r: &mut R) -> Result<Incoming, WireError> {
    let mut head = [0u8; HEADER];
    read_full(r, &mut head)?;
    if head[..4] != MAGIC {
        return Err(WireError::BadMagic([head[0], head[1], head[2], head[3]]));
    }
    let kind = head[4];
    if kind != KIND_TASK && kind != KIND_DELTA {
        return Err(WireError::BadKind(kind));
    }
    let len = u32::from_le_bytes([head[5], head[6], head[7], head[8]]);
    if len > MAX_FRAME {
        return Err(WireError::TooLarge(len as u64));
    }
    let want = u32::from_le_bytes([head[9], head[10], head[11], head[12]]);
    let mut payload = vec![0u8; len as usize];
    read_full(r, &mut payload)?;
    let got = crc32(&payload);
    if got != want {
        return Err(WireError::Corrupt {
            kind: "frame",
            detail: format!("payload crc {got:#010x} != header {want:#010x}"),
        });
    }
    Ok(Incoming::Frame { kind, payload })
}

/// `read_exact` that reports *how far* a torn stream got (and retries
/// `Interrupted`), so truncation diagnostics carry real byte counts.
fn read_full<R: Read>(r: &mut R, buf: &mut [u8]) -> Result<(), WireError> {
    let mut got = 0;
    while got < buf.len() {
        match r.read(&mut buf[got..]) {
            Ok(0) => return Err(WireError::Truncated { want: buf.len(), got }),
            Ok(n) => got += n,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e) => return Err(WireError::Io(e)),
        }
    }
    Ok(())
}

/// Stable u8 code for a kernel kind (index into [`KernelKind::all`]).
pub fn kernel_code(kind: KernelKind) -> u8 {
    KernelKind::all()
        .iter()
        .position(|&k| k == kind)
        .expect("every kind is in all()") as u8
}

/// Inverse of [`kernel_code`].
pub fn kernel_from_code(code: u8) -> Option<KernelKind> {
    KernelKind::all().get(code as usize).copied()
}

/// Coordinator → worker: one task's complete execution closure.
#[derive(Debug, Clone, PartialEq)]
pub struct TaskMsg {
    /// Commit ticket (the task's index within its epoch).
    pub ticket: u32,
    /// Diagonal epoch within the sweep (trace coordinate).
    pub epoch: u32,
    pub sweep: u64,
    /// Grid-global partition id — the RNG stream key.
    pub partition: u64,
    /// Phase family (0 = word, 1 = BoT stamp) — a trace coordinate.
    pub family: u8,
    pub kernel: KernelKind,
    pub k: u32,
    pub alpha: f32,
    pub beta: f32,
    pub wbeta: f32,
    /// Pre-salted trainer/phase seed (see `scheduler::pool::task_rng`).
    pub seed: u64,
    /// Epoch-start topic totals (`k` entries).
    pub snapshot: Vec<u32>,
    /// Global row ids of the doc rows shipped in `doc_rows`, in the
    /// order the rows are packed (the block's doc ids are remapped to
    /// indices into this list).
    pub doc_ids: Vec<u64>,
    /// `doc_ids.len() * k` row-major counts.
    pub doc_rows: Vec<f32>,
    /// Global row ids of the emission-side rows (words, or BoT stamps).
    pub emit_ids: Vec<u64>,
    pub emit_rows: Vec<f32>,
    /// A `PPSHARD3` image of the token block, ids remapped local,
    /// stamped with the partition id.
    pub block: Vec<u8>,
}

/// Worker → coordinator: one completed task.
#[derive(Debug, Clone, PartialEq)]
pub struct DeltaMsg {
    pub ticket: u32,
    pub partition: u64,
    /// Measured task nanos (telemetry; feeds the adaptive estimators
    /// and the straggler EWMA, never results).
    pub nanos: u64,
    /// Signed topic-total delta (`k` entries).
    pub delta: Vec<i64>,
    /// Absolute updated doc rows, same order/shape as the task's
    /// `doc_ids`/`doc_rows`.
    pub doc_rows: Vec<f32>,
    pub emit_rows: Vec<f32>,
    /// The block's updated topic assignments.
    pub z: Vec<u32>,
}

impl TaskMsg {
    pub fn encode(&self) -> Vec<u8> {
        let mut b = Vec::with_capacity(
            64 + 4 * self.snapshot.len()
                + 12 * self.doc_ids.len()
                + 4 * self.doc_rows.len()
                + 12 * self.emit_ids.len()
                + 4 * self.emit_rows.len()
                + self.block.len(),
        );
        b.extend_from_slice(&self.ticket.to_le_bytes());
        b.extend_from_slice(&self.epoch.to_le_bytes());
        b.extend_from_slice(&self.sweep.to_le_bytes());
        b.extend_from_slice(&self.partition.to_le_bytes());
        b.push(self.family);
        b.push(kernel_code(self.kernel));
        b.extend_from_slice(&[0u8; 2]); // pad to 4-byte alignment of what follows
        b.extend_from_slice(&self.k.to_le_bytes());
        b.extend_from_slice(&self.alpha.to_le_bytes());
        b.extend_from_slice(&self.beta.to_le_bytes());
        b.extend_from_slice(&self.wbeta.to_le_bytes());
        b.extend_from_slice(&self.seed.to_le_bytes());
        put_u32s(&mut b, &self.snapshot);
        put_u64s(&mut b, &self.doc_ids);
        put_f32s(&mut b, &self.doc_rows);
        put_u64s(&mut b, &self.emit_ids);
        put_f32s(&mut b, &self.emit_rows);
        b.extend_from_slice(&(self.block.len() as u64).to_le_bytes());
        b.extend_from_slice(&self.block);
        b
    }

    pub fn decode(bytes: &[u8]) -> Result<TaskMsg, WireError> {
        let mut c = Cur::new(bytes);
        let ticket = c.u32()?;
        let epoch = c.u32()?;
        let sweep = c.u64()?;
        let partition = c.u64()?;
        let family = c.u8()?;
        let kernel = kernel_from_code(c.u8()?)
            .ok_or_else(|| WireError::Protocol("unknown kernel code".into()))?;
        c.take(2)?; // pad
        let k = c.u32()?;
        let alpha = c.f32()?;
        let beta = c.f32()?;
        let wbeta = c.f32()?;
        let seed = c.u64()?;
        let snapshot = c.u32s()?;
        if snapshot.len() != k as usize {
            return Err(WireError::Corrupt {
                kind: "layout",
                detail: format!("snapshot has {} entries for k={k}", snapshot.len()),
            });
        }
        let doc_ids = c.u64s()?;
        let doc_rows = c.f32s()?;
        let emit_ids = c.u64s()?;
        let emit_rows = c.f32s()?;
        if doc_rows.len() != doc_ids.len() * k as usize
            || emit_rows.len() != emit_ids.len() * k as usize
        {
            return Err(WireError::Corrupt {
                kind: "layout",
                detail: "row matrices do not match id counts".into(),
            });
        }
        let block_len = c.u64()? as usize;
        let block = c.take(block_len)?.to_vec();
        c.done()?;
        Ok(TaskMsg {
            ticket,
            epoch,
            sweep,
            partition,
            family,
            kernel,
            k,
            alpha,
            beta,
            wbeta,
            seed,
            snapshot,
            doc_ids,
            doc_rows,
            emit_ids,
            emit_rows,
            block,
        })
    }

    /// Decode and verify the embedded `PPSHARD3` block image. `origin`
    /// labels integrity errors (e.g. `wire://node-2/part-7`).
    pub fn decode_task_block(&self, origin: &Path) -> Result<TokenBlock, WireError> {
        let (block, stamp) = shard::decode_block(&self.block, origin).map_err(|e| {
            WireError::Corrupt { kind: "block", detail: e.to_string() }
        })?;
        if stamp != self.partition {
            return Err(WireError::Corrupt {
                kind: "block",
                detail: format!("block stamped {stamp}, task is partition {}", self.partition),
            });
        }
        Ok(block)
    }
}

impl DeltaMsg {
    pub fn encode(&self) -> Vec<u8> {
        let mut b = Vec::with_capacity(
            40 + 8 * self.delta.len()
                + 4 * (self.doc_rows.len() + self.emit_rows.len() + self.z.len()),
        );
        b.extend_from_slice(&self.ticket.to_le_bytes());
        b.extend_from_slice(&self.partition.to_le_bytes());
        b.extend_from_slice(&self.nanos.to_le_bytes());
        b.extend_from_slice(&(self.delta.len() as u32).to_le_bytes());
        for &d in &self.delta {
            b.extend_from_slice(&d.to_le_bytes());
        }
        put_f32s(&mut b, &self.doc_rows);
        put_f32s(&mut b, &self.emit_rows);
        put_u32s(&mut b, &self.z);
        b
    }

    pub fn decode(bytes: &[u8]) -> Result<DeltaMsg, WireError> {
        let mut c = Cur::new(bytes);
        let ticket = c.u32()?;
        let partition = c.u64()?;
        let nanos = c.u64()?;
        let n = c.u32()? as usize;
        let raw = c.take(8 * n)?;
        let mut delta = Vec::with_capacity(n);
        for ch in raw.chunks_exact(8) {
            let mut le = [0u8; 8];
            le.copy_from_slice(ch);
            delta.push(i64::from_le_bytes(le));
        }
        let doc_rows = c.f32s()?;
        let emit_rows = c.f32s()?;
        let z = c.u32s()?;
        c.done()?;
        Ok(DeltaMsg { ticket, partition, nanos, delta, doc_rows, emit_rows, z })
    }
}

fn put_u32s(b: &mut Vec<u8>, v: &[u32]) {
    b.extend_from_slice(&(v.len() as u32).to_le_bytes());
    for &x in v {
        b.extend_from_slice(&x.to_le_bytes());
    }
}

fn put_u64s(b: &mut Vec<u8>, v: &[u64]) {
    b.extend_from_slice(&(v.len() as u32).to_le_bytes());
    for &x in v {
        b.extend_from_slice(&x.to_le_bytes());
    }
}

fn put_f32s(b: &mut Vec<u8>, v: &[f32]) {
    b.extend_from_slice(&(v.len() as u32).to_le_bytes());
    for &x in v {
        b.extend_from_slice(&x.to_le_bytes());
    }
}

/// Bounds-checked little-endian cursor. Every overrun is a typed
/// [`WireError::Truncated`]; element counts are validated against the
/// remaining byte budget *before* any allocation, so a corrupt count
/// cannot request a huge buffer.
struct Cur<'a> {
    b: &'a [u8],
    at: usize,
}

impl<'a> Cur<'a> {
    fn new(b: &'a [u8]) -> Self {
        Cur { b, at: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        let end = self.at.checked_add(n).filter(|&e| e <= self.b.len()).ok_or(
            WireError::Truncated { want: self.at.saturating_add(n), got: self.b.len() },
        )?;
        let s = &self.b[self.at..end];
        self.at = end;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, WireError> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32, WireError> {
        let s = self.take(4)?;
        Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }

    fn f32(&mut self) -> Result<f32, WireError> {
        Ok(f32::from_bits(self.u32()?))
    }

    fn u64(&mut self) -> Result<u64, WireError> {
        let s = self.take(8)?;
        let mut le = [0u8; 8];
        le.copy_from_slice(s);
        Ok(u64::from_le_bytes(le))
    }

    fn u32s(&mut self) -> Result<Vec<u32>, WireError> {
        let n = self.u32()? as usize;
        let raw = self.take(4 * n)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    fn f32s(&mut self) -> Result<Vec<f32>, WireError> {
        Ok(self.u32s()?.into_iter().map(f32::from_bits).collect())
    }

    fn u64s(&mut self) -> Result<Vec<u64>, WireError> {
        let n = self.u32()? as usize;
        let raw = self.take(8 * n)?;
        Ok(raw
            .chunks_exact(8)
            .map(|c| {
                let mut le = [0u8; 8];
                le.copy_from_slice(c);
                u64::from_le_bytes(le)
            })
            .collect())
    }

    /// Trailing garbage is a layout error, not silently ignored.
    fn done(&self) -> Result<(), WireError> {
        if self.at != self.b.len() {
            return Err(WireError::Corrupt {
                kind: "layout",
                detail: format!("{} trailing bytes after payload", self.b.len() - self.at),
            });
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop;
    use crate::util::rng::Rng;

    fn random_delta(rng: &mut Rng) -> DeltaMsg {
        let k = 1 + rng.gen_range(8);
        let n_doc = rng.gen_range(4);
        let n_emit = rng.gen_range(4);
        let z_len = rng.gen_range(16);
        DeltaMsg {
            ticket: rng.gen_range(64) as u32,
            partition: rng.gen_range(1 << 20) as u64,
            nanos: rng.gen_range(1 << 30) as u64,
            delta: (0..k).map(|_| rng.gen_range(2001) as i64 - 1000).collect(),
            doc_rows: (0..n_doc * k).map(|_| rng.f64() as f32).collect(),
            emit_rows: (0..n_emit * k).map(|_| rng.f64() as f32).collect(),
            z: (0..z_len).map(|_| rng.gen_range(256) as u32).collect(),
        }
    }

    fn framed(msg: &DeltaMsg) -> Vec<u8> {
        let mut bytes = Vec::new();
        send_frame(&mut bytes, KIND_DELTA, &msg.encode()).unwrap();
        bytes
    }

    /// Satellite: random deltas round-trip the frame + payload encoding
    /// exactly (f32 bit patterns included — `PartialEq` on the structs
    /// compares the decoded floats, and the generator only produces
    /// non-NaN values).
    #[test]
    fn delta_frames_round_trip_exactly() {
        prop::check("wire_delta_round_trip", 0xD157_0001, prop::DEFAULT_CASES, |rng| {
            let msg = random_delta(rng);
            let bytes = framed(&msg);
            let mut r = io::BufReader::new(&bytes[..]);
            match recv_mixed(&mut r).expect("clean frame decodes") {
                Incoming::Frame { kind, payload } => {
                    assert_eq!(kind, KIND_DELTA);
                    let back = DeltaMsg::decode(&payload).expect("payload decodes");
                    assert_eq!(back, msg);
                }
                other => panic!("expected a frame, got {other:?}"),
            }
            // The stream position is exact: a second read sees clean EOF.
            assert!(matches!(recv_mixed(&mut r).unwrap(), Incoming::Eof));
        });
    }

    /// Satellite: every truncation of a valid frame surfaces as a typed
    /// error (torn header or torn payload), never a panic and never a
    /// successful decode.
    #[test]
    fn truncations_surface_as_typed_errors() {
        prop::check("wire_truncation", 0xD157_0002, prop::DEFAULT_CASES, |rng| {
            let bytes = framed(&random_delta(rng));
            let cut = rng.gen_range(bytes.len()); // strictly shorter
            let mut r = io::BufReader::new(&bytes[..cut]);
            match recv_mixed(&mut r) {
                Ok(Incoming::Eof) => assert_eq!(cut, 0, "only an empty stream is clean EOF"),
                Ok(other) => panic!("torn frame decoded: {other:?}"),
                Err(WireError::Truncated { want, got }) => {
                    assert!(got < want, "truncation reports got {got} < want {want}")
                }
                Err(e) => panic!("torn frame misclassified: {e}"),
            }
        });
    }

    /// Satellite: a single flipped bit anywhere in the frame is either
    /// detected as a typed [`WireError`] or diverts the plane sniff (a
    /// magic byte flipped to `{` reads as a — then unparseable — control
    /// line). It never panics and never yields the original message via
    /// a clean decode of a *different* byte stream.
    #[test]
    fn bit_flips_never_pass_silently_and_never_panic() {
        prop::check("wire_bit_flip", 0xD157_0003, prop::DEFAULT_CASES, |rng| {
            let msg = random_delta(rng);
            let mut bytes = framed(&msg);
            let at = rng.gen_range(bytes.len());
            let bit = 1u8 << rng.gen_range(8);
            bytes[at] ^= bit;
            let mut r = io::BufReader::new(&bytes[..]);
            match recv_mixed(&mut r) {
                // Typed detection: the expected outcome.
                Err(
                    WireError::BadMagic(_)
                    | WireError::BadKind(_)
                    | WireError::TooLarge(_)
                    | WireError::Truncated { .. }
                    | WireError::Corrupt { .. },
                ) => {}
                Err(e) => panic!("unexpected error class: {e}"),
                // First byte flipped to '{': sniffed as a control line;
                // the JSON layer rejects it (it is binary garbage).
                Ok(Incoming::Line(l)) => {
                    assert!(crate::util::json::Json::parse(&l).is_err());
                }
                Ok(Incoming::Eof) => panic!("flip cannot empty the stream"),
                Ok(Incoming::Frame { kind, payload }) => {
                    // A flip the frame CRC cannot see must be confined to
                    // the CRC field colliding — impossible for one bit —
                    // or to header bytes that do not alter acceptance.
                    // The only such byte is the kind (1 <-> 2 is one bit
                    // flip... but 1^2 = 3, i.e. *two* bits differ), so a
                    // surviving frame must decode to the original.
                    assert_eq!(kind, KIND_DELTA, "kind flip must be rejected");
                    assert_eq!(
                        DeltaMsg::decode(&payload).expect("surviving frame decodes"),
                        msg,
                        "accepted frame must be byte-identical"
                    );
                    panic!("a one-bit flip was accepted — CRC missed it");
                }
            }
        });
    }

    #[test]
    fn mixed_stream_interleaves_lines_and_frames() {
        let msg = DeltaMsg {
            ticket: 3,
            partition: 9,
            nanos: 17,
            delta: vec![1, -2],
            doc_rows: vec![0.5, 1.5],
            emit_rows: vec![],
            z: vec![0, 1, 1],
        };
        let mut bytes = Vec::new();
        bytes.extend_from_slice(b"{\"cmd\":\"pong\",\"seq\":4}\n");
        send_frame(&mut bytes, KIND_DELTA, &msg.encode()).unwrap();
        bytes.extend_from_slice(b"{\"cmd\":\"shutdown\"}\n");
        let mut r = io::BufReader::new(&bytes[..]);
        assert!(matches!(recv_mixed(&mut r).unwrap(), Incoming::Line(l) if l.contains("pong")));
        match recv_mixed(&mut r).unwrap() {
            Incoming::Frame { kind, payload } => {
                assert_eq!(kind, KIND_DELTA);
                assert_eq!(DeltaMsg::decode(&payload).unwrap(), msg);
            }
            other => panic!("expected frame, got {other:?}"),
        }
        assert!(matches!(recv_mixed(&mut r).unwrap(), Incoming::Line(l) if l.contains("shutdown")));
        assert!(matches!(recv_mixed(&mut r).unwrap(), Incoming::Eof));
    }

    #[test]
    fn task_round_trip_with_embedded_block() {
        let mut block = TokenBlock::with_capacity(3);
        block.docs.extend_from_slice(&[0, 1, 0]);
        block.words.extend_from_slice(&[2, 0, 1]);
        block.z.extend_from_slice(&[5, 6, 7]);
        let msg = TaskMsg {
            ticket: 1,
            epoch: 2,
            sweep: 3,
            partition: 42,
            family: 0,
            kernel: KernelKind::Sparse,
            k: 2,
            alpha: 0.5,
            beta: 0.1,
            wbeta: 0.1 * 3.0,
            seed: 0xABCD,
            snapshot: vec![10, 20],
            doc_ids: vec![100, 200],
            doc_rows: vec![1.0, 2.0, 3.0, 4.0],
            emit_ids: vec![7, 8, 9],
            emit_rows: vec![0.0; 6],
            block: shard::encode_block(&block, 42),
        };
        let back = TaskMsg::decode(&msg.encode()).unwrap();
        assert_eq!(back, msg);
        let decoded = back.decode_task_block(Path::new("wire://test")).unwrap();
        assert_eq!(decoded.docs, block.docs);
        assert_eq!(decoded.words, block.words);
        assert_eq!(decoded.z, block.z);
    }

    #[test]
    fn corrupt_embedded_block_is_a_typed_error() {
        let mut block = TokenBlock::with_capacity(1);
        block.docs.push(0);
        block.words.push(0);
        block.z.push(1);
        let mut image = shard::encode_block(&block, 7);
        let last = image.len() - 1;
        image[last] ^= 0x01; // flip inside the z section
        let msg = TaskMsg {
            ticket: 0,
            epoch: 0,
            sweep: 0,
            partition: 7,
            family: 0,
            kernel: KernelKind::Dense,
            k: 1,
            alpha: 0.1,
            beta: 0.1,
            wbeta: 0.1,
            seed: 1,
            snapshot: vec![1],
            doc_ids: vec![0],
            doc_rows: vec![1.0],
            emit_ids: vec![0],
            emit_rows: vec![1.0],
            block: image,
        };
        let err = msg.decode_task_block(Path::new("wire://test")).unwrap_err();
        assert!(matches!(err, WireError::Corrupt { kind: "block", .. }), "{err}");
    }

    #[test]
    fn kernel_codes_are_total_and_stable() {
        for kind in KernelKind::all() {
            assert_eq!(kernel_from_code(kernel_code(kind)), Some(kind));
        }
        assert_eq!(kernel_from_code(250), None);
    }
}
