//! Coordinator side of distributed training: [`DistExec`], an
//! [`Executor`] whose "workers" are remote `pplda worker` processes
//! reached over TCP instead of threads in a local pool.
//!
//! # Architecture
//!
//! The coordinator owns everything global — the schedule, the shared
//! `n_dw`/`n_wt` rows, checkpointing, tracing — and ships each epoch
//! task to a worker as a self-contained [`TaskMsg`]: hyperparameters,
//! the pre-salted RNG seed, the topic snapshot, the *slices* of the
//! shared rows the task's block touches (gathered by id), and the block
//! itself as a checksummed `PPSHARD3` image. Workers are stateless
//! between tasks; the reply ([`DeltaMsg`]) carries **absolute** row
//! values, so a duplicate delivery (speculation, replay after a
//! reconnect) is idempotent — applying it twice writes the same bytes.
//!
//! # Determinism
//!
//! A task's sampling stream is keyed only by `(seed, sweep, partition)`
//! (see [`crate::scheduler::pool::task_rng`]), never by which node runs
//! it or how many times it is retried. Reassignment after a crash,
//! speculative duplicates, and the no-workers-left local fallback all
//! replay the *same* stream over the *same* input block, so the result
//! is bit-identical to a single-process run — the property the chaos
//! tests in `integration_dist.rs` assert.
//!
//! # Failure handling
//!
//! * Per-node reader threads turn frames, pongs, EOFs and decode errors
//!   into [`NodeEvent`]s on one channel; the epoch driver is a single
//!   event loop, so there is no locking on the hot path.
//! * A node is declared **dead** on: send failure, connection EOF, an
//!   undecodable frame, or a liveness timeout (no pong while it holds
//!   in-flight work). Its in-flight tickets rejoin the dispatch queue —
//!   each requeue counts one *reassign* (surfaced as
//!   `SweepStats::task_retries` through [`Executor::retries`]).
//! * Stragglers: once a node's EWMA task time is established, a task
//!   exceeding `spec_factor ×` the estimate is speculatively duplicated
//!   onto an idle node; the first reply wins, the loser is dropped by
//!   the `completed` set.
//! * Dead nodes get one reconnect attempt per epoch while
//!   `max_reconnects` lasts; with no live node left, tasks run locally
//!   through the same [`pool::run_task`] the workers use.
//!
//! Fault injection: [`fault::sites::DIST_SEND`] fires before a task
//! frame is written (TornWrite/IoError → node dead), and
//! [`fault::sites::DIST_RECV`] fires when a delta arrives (any kind →
//! delta discarded, node dead). Both are keyed `(node, sweep, ticket)`.

use std::collections::VecDeque;
use std::io::{BufReader, Write};
use std::net::{Shutdown, SocketAddr, TcpStream};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::dist::wire::{
    self, send_frame, DeltaMsg, Incoming, TaskMsg, KIND_DELTA, KIND_TASK,
};
use crate::dist::worker::PROTO_VERSION;
use crate::gibbs::tokens::TokenBlock;
use crate::kernel::Kernel;
use crate::obs::EventKind;
use crate::scheduler::pool::{self, EpochSpec, EpochTasks, Executor};
use crate::scheduler::shared::SharedRows;
use crate::util::fault::{self, FaultKind};
use crate::util::json::Json;
use crate::util::net::{connect, send_line};

/// Tuning knobs for the coordinator's failure detector and straggler
/// mitigation. Defaults suit a LAN; tests shrink the timeouts.
#[derive(Clone, Debug)]
pub struct DistOptions {
    /// Ping period while an epoch is in flight.
    pub heartbeat_ms: u64,
    /// A node holding in-flight work that has not been heard from (no
    /// pong, no delta) for this long is declared dead.
    pub liveness_timeout_ms: u64,
    /// Speculative re-execution threshold: a task is duplicated onto an
    /// idle node once it has run `spec_factor ×` the owner's EWMA task
    /// time. `f64::INFINITY` disables speculation.
    pub spec_factor: f64,
    /// Connection attempts per node at startup (with deterministic
    /// exponential backoff between attempts).
    pub connect_attempts: u32,
    /// Lifetime budget of reconnect attempts per node after it dies
    /// (one try per epoch start).
    pub max_reconnects: u32,
}

impl Default for DistOptions {
    fn default() -> Self {
        DistOptions {
            heartbeat_ms: 500,
            liveness_timeout_ms: 2000,
            spec_factor: 3.0,
            connect_attempts: 10,
            max_reconnects: 3,
        }
    }
}

/// Why a worker node could not be brought up.
#[derive(Debug)]
pub enum NodeError {
    /// TCP connect kept failing after all startup attempts.
    Connect {
        addr: String,
        attempts: u32,
        last: String,
    },
    /// Connected, but the hello/hello_ack exchange went wrong.
    Handshake { addr: String, detail: String },
}

impl std::fmt::Display for NodeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            NodeError::Connect {
                addr,
                attempts,
                last,
            } => write!(f, "connect to {addr} failed after {attempts} attempts: {last}"),
            NodeError::Handshake { addr, detail } => {
                write!(f, "handshake with {addr} failed: {detail}")
            }
        }
    }
}

impl std::error::Error for NodeError {}

/// What a per-node reader thread can report to the epoch driver.
enum Ev {
    /// A decoded worker reply.
    Delta(DeltaMsg),
    /// Heartbeat answer.
    Pong,
    /// Clean or crash hangup — the socket reached EOF.
    Eof,
    /// Protocol damage: undecodable frame, unexpected kind, IO error.
    Bad(String),
}

struct NodeEvent {
    node: usize,
    ev: Ev,
}

/// One remote worker as the coordinator sees it. `writer: None` means
/// dead (until a reconnect succeeds).
struct Node {
    addr: SocketAddr,
    writer: Option<TcpStream>,
    reader: Option<JoinHandle<()>>,
    /// Last time we heard *anything* from the node (pong or delta).
    last_seen: Instant,
    /// EWMA of reported task nanos — the speculation baseline.
    ewma_nanos: f64,
    reconnects_left: u32,
    /// Tasks currently assigned (primary or speculative copy); used to
    /// find idle nodes for speculation.
    busy: usize,
}

/// Per-ticket dispatch state while an epoch is in flight.
struct Flight {
    node: usize,
    spec_node: Option<usize>,
    sent_at: Instant,
    speculated: bool,
}

/// The id lists a ticket's rows were gathered by — kept per ticket (not
/// per flight) so a late delta from an already-buried node can still be
/// scattered back, and so re-sends reuse the same (deterministic) maps.
struct TicketIds {
    doc: Vec<u64>,
    emit: Vec<u64>,
}

/// A distributed [`Executor`]: drives remote workers over TCP with
/// heartbeats, deterministic reassignment, speculation, and a local
/// fallback. Construct with [`DistExec::connect`], then hand to
/// `ParallelLda::sweep_with` / `ParallelBot::sweep_with`.
pub struct DistExec {
    nodes: Vec<Node>,
    opts: DistOptions,
    tx: Sender<NodeEvent>,
    rx: Receiver<NodeEvent>,
    reassigns: u64,
    speculations: u64,
    local_tasks: u64,
    pings: u64,
    ping_seq: u64,
    /// Kernel for the no-workers-left local fallback, cached across
    /// epochs like a pool worker's.
    local_kernel: Option<Box<dyn Kernel>>,
}

impl DistExec {
    /// Connect to every worker address and complete the hello handshake
    /// with each. Node index == position in `addrs`; the worker learns
    /// its index from the hello, so lanes and failpoint keys agree on
    /// both sides. Fails hard if any node cannot be brought up — a
    /// degraded *start* is a config error, unlike a mid-run death.
    pub fn connect(addrs: &[SocketAddr], opts: DistOptions) -> Result<DistExec, NodeError> {
        assert!(!addrs.is_empty(), "need at least one worker address");
        let (tx, rx) = channel();
        let mut exec = DistExec {
            nodes: Vec::with_capacity(addrs.len()),
            opts,
            tx,
            rx,
            reassigns: 0,
            speculations: 0,
            local_tasks: 0,
            pings: 0,
            ping_seq: 0,
            local_kernel: None,
        };
        for &addr in addrs {
            exec.nodes.push(Node {
                addr,
                writer: None,
                reader: None,
                last_seen: Instant::now(),
                ewma_nanos: 0.0,
                reconnects_left: exec.opts.max_reconnects,
                busy: 0,
            });
        }
        for i in 0..exec.nodes.len() {
            exec.connect_node(i, exec.opts.connect_attempts)?;
        }
        Ok(exec)
    }

    /// Number of configured nodes (live or dead).
    pub fn nodes(&self) -> usize {
        self.nodes.len()
    }

    /// Nodes currently connected.
    pub fn live_nodes(&self) -> usize {
        self.nodes.iter().filter(|n| n.writer.is_some()).count()
    }

    /// Tasks re-dispatched because their node died (== what
    /// [`Executor::retries`] reports).
    pub fn reassigns(&self) -> u64 {
        self.reassigns
    }

    /// Speculative duplicates dispatched for suspected stragglers.
    pub fn speculations(&self) -> u64 {
        self.speculations
    }

    /// Tasks run on the coordinator because no worker was live.
    pub fn local_fallbacks(&self) -> u64 {
        self.local_tasks
    }

    /// Heartbeat pings sent (telemetry; tests assert it advances).
    pub fn pings_sent(&self) -> u64 {
        self.pings
    }

    /// Politely shut every worker down (send `shutdown`, close sockets,
    /// join readers). Idempotent; also runs on drop.
    pub fn shutdown(&mut self) {
        for i in 0..self.nodes.len() {
            if let Some(w) = &mut self.nodes[i].writer {
                let mut bye = Json::obj();
                bye.set("cmd", "shutdown");
                let _ = send_line(w, &bye);
                let _ = w.shutdown(Shutdown::Both);
            }
            self.nodes[i].writer = None;
            if let Some(h) = self.nodes[i].reader.take() {
                let _ = h.join();
            }
        }
    }

    /// Bring node `i` up: connect (with deterministic backoff between
    /// attempts), handshake, spawn its reader thread.
    fn connect_node(&mut self, i: usize, attempts: u32) -> Result<(), NodeError> {
        let addr = self.nodes[i].addr;
        let mut last = String::from("no attempt made");
        for attempt in 0..attempts.max(1) {
            if attempt > 0 {
                std::thread::sleep(Duration::from_millis(backoff_ms(i as u64, attempt)));
            }
            match self.try_handshake(&addr, i) {
                Ok((writer, reader)) => {
                    self.spawn_reader(i, reader);
                    self.nodes[i].writer = Some(writer);
                    self.nodes[i].last_seen = Instant::now();
                    self.nodes[i].busy = 0;
                    return Ok(());
                }
                Err(e) => last = e,
            }
        }
        Err(NodeError::Connect {
            addr: addr.to_string(),
            attempts: attempts.max(1),
            last,
        })
    }

    /// One connect + hello/hello_ack exchange. The handshake read runs
    /// under a timeout (a hung accept loop must not wedge startup);
    /// the timeout is cleared before the stream becomes the reader
    /// thread's, which blocks indefinitely by design.
    fn try_handshake(
        &self,
        addr: &SocketAddr,
        node: usize,
    ) -> Result<(TcpStream, BufReader<TcpStream>), String> {
        let stream = connect(addr).map_err(|e| e.to_string())?;
        stream
            .set_read_timeout(Some(Duration::from_millis(
                self.opts.liveness_timeout_ms.max(100),
            )))
            .map_err(|e| e.to_string())?;
        let mut writer = stream.try_clone().map_err(|e| e.to_string())?;
        let mut hello = Json::obj();
        hello.set("cmd", "hello");
        hello.set("node", node as u64);
        hello.set("proto", PROTO_VERSION);
        send_line(&mut writer, &hello).map_err(|e| e.to_string())?;
        let mut reader = BufReader::new(stream);
        match wire::recv_mixed(&mut reader) {
            Ok(Incoming::Line(line)) => {
                let ack = Json::parse(&line)?;
                if ack.get("cmd").and_then(Json::as_str) != Some("hello_ack") {
                    return Err(format!("expected hello_ack, got: {line}"));
                }
                if ack.get("node").and_then(Json::as_u64) != Some(node as u64) {
                    return Err(format!("hello_ack for wrong node: {line}"));
                }
            }
            Ok(other) => return Err(format!("expected hello_ack line, got {other:?}")),
            Err(e) => return Err(e.to_string()),
        }
        reader
            .get_ref()
            .set_read_timeout(None)
            .map_err(|e| e.to_string())?;
        Ok((writer, reader))
    }

    /// Reader thread: everything the node says becomes a [`NodeEvent`].
    /// The thread exits after reporting EOF or any damage — a damaged
    /// stream has lost framing and cannot be resynchronised.
    fn spawn_reader(&mut self, i: usize, mut reader: BufReader<TcpStream>) {
        let tx = self.tx.clone();
        let handle = std::thread::Builder::new()
            .name(format!("dist-coord-reader-{i}"))
            .spawn(move || loop {
                let ev = match wire::recv_mixed(&mut reader) {
                    Ok(Incoming::Frame { kind, payload }) if kind == KIND_DELTA => {
                        match DeltaMsg::decode(&payload) {
                            Ok(msg) => Ev::Delta(msg),
                            Err(e) => Ev::Bad(format!("bad delta frame: {e}")),
                        }
                    }
                    Ok(Incoming::Frame { kind, .. }) => {
                        Ev::Bad(format!("unexpected frame kind {kind} from worker"))
                    }
                    Ok(Incoming::Line(line)) => match Json::parse(&line) {
                        Ok(msg) => match msg.get("cmd").and_then(Json::as_str) {
                            Some("pong") => Ev::Pong,
                            Some(other) => Ev::Bad(format!("unexpected command {other:?}")),
                            None => Ev::Bad(format!("line without cmd: {line}")),
                        },
                        Err(e) => Ev::Bad(format!("unparseable line: {e}")),
                    },
                    Ok(Incoming::Eof) => Ev::Eof,
                    Err(e) => Ev::Bad(e.to_string()),
                };
                let fatal = matches!(ev, Ev::Eof | Ev::Bad(_));
                if tx.send(NodeEvent { node: i, ev }).is_err() || fatal {
                    break;
                }
            })
            .expect("spawn coordinator reader thread");
        self.nodes[i].reader = Some(handle);
    }

    /// Declare node `i` dead: close its socket (which unblocks its
    /// reader) and drop the writer. The reader handle is detached here
    /// and joined at shutdown.
    fn kill_node(&mut self, i: usize) {
        if let Some(w) = self.nodes[i].writer.take() {
            let _ = w.shutdown(Shutdown::Both);
        }
        self.nodes[i].busy = 0;
    }

    /// Round-robin over live nodes in index order. Deterministic given
    /// the failure sequence: with no faults, ticket `t` lands on live
    /// node `t mod live_count`.
    fn pick_node(&self, rr: &mut usize) -> Option<usize> {
        let live: Vec<usize> = (0..self.nodes.len())
            .filter(|&i| self.nodes[i].writer.is_some())
            .collect();
        if live.is_empty() {
            return None;
        }
        let n = live[*rr % live.len()];
        *rr += 1;
        Some(n)
    }

    /// Write one task frame to a node, honouring the `dist.send`
    /// failpoint. On any failure the caller must bury the node.
    fn send_task(&mut self, node: usize, sweep: u64, msg: &TaskMsg) -> Result<(), String> {
        let payload = msg.encode();
        let w = self.nodes[node]
            .writer
            .as_mut()
            .ok_or_else(|| "node is dead".to_string())?;
        match fault::fire(
            fault::sites::DIST_SEND,
            [node as u64, sweep, msg.ticket as u64],
        ) {
            Some(FaultKind::TornWrite) => {
                // Write a believable prefix — magic + kind + a length
                // that promises more than will ever come — then hang up.
                // The worker sees Truncated, the coordinator a dead node.
                let mut head = Vec::with_capacity(wire::HEADER);
                head.extend_from_slice(&wire::MAGIC);
                head.push(KIND_TASK);
                head.extend_from_slice(&(payload.len() as u32).to_le_bytes());
                let _ = w.write_all(&head[..wire::HEADER.min(head.len())]);
                let _ = w.flush();
                return Err(format!(
                    "injected torn write to node {node} (sweep {sweep}, ticket {})",
                    msg.ticket
                ));
            }
            Some(_) => {
                return Err(format!(
                    "injected send fault to node {node} (sweep {sweep}, ticket {})",
                    msg.ticket
                ));
            }
            None => {}
        }
        send_frame(w, KIND_TASK, &payload).map_err(|e| e.to_string())
    }

    /// Ping every live node. A failed ping write buries the node and
    /// returns its index so the caller can requeue its flights.
    fn ping_all(&mut self) -> Vec<usize> {
        let mut died = Vec::new();
        self.ping_seq += 1;
        let seq = self.ping_seq;
        for i in 0..self.nodes.len() {
            let Some(w) = self.nodes[i].writer.as_mut() else {
                continue;
            };
            let mut ping = Json::obj();
            ping.set("cmd", "ping");
            ping.set("seq", seq);
            if send_line(w, &ping).is_err() {
                died.push(i);
            }
        }
        for &i in &died {
            self.kill_node(i);
        }
        self.pings += 1;
        died
    }

    /// Run one ticket on the coordinator itself — the degraded mode
    /// when every worker is gone. Same `pool::run_task`, same RNG key,
    /// so the result is bit-identical to a remote execution.
    fn run_local(
        &mut self,
        spec: &EpochSpec<'_>,
        partition: u64,
        block: &mut TokenBlock,
        delta: &mut [i64],
    ) -> u64 {
        let kern = match &mut self.local_kernel {
            Some(k) if k.kind() == spec.kernel => k,
            slot => slot.insert(spec.kernel.build()),
        };
        self.local_tasks += 1;
        pool::run_task(spec, partition, block, delta, kern.as_mut())
    }

    /// EWMA update for a node's task-time estimate (α = 0.25).
    fn observe_nanos(&mut self, node: usize, nanos: u64) {
        let e = &mut self.nodes[node].ewma_nanos;
        *e = if *e <= 0.0 {
            nanos as f64
        } else {
            0.75 * *e + 0.25 * nanos as f64
        };
    }

    /// The epoch driver shared by the barrier and ticketed paths: the
    /// ticketed path passes `overlap`/`commit`, the barrier path runs
    /// with both `None` and simply leaves results in `deltas`/`blocks`.
    fn drive_epoch(
        &mut self,
        spec: &EpochSpec<'_>,
        tasks: EpochTasks<'_>,
        deltas: &mut [Vec<i64>],
        mut overlap: Option<&mut dyn FnMut()>,
        mut commit: Option<&mut dyn FnMut(usize, &[i64], usize)>,
    ) {
        pool::check_tasks(&tasks, deltas);
        let EpochTasks {
            blocks,
            ids,
            assign: _,
            nanos,
            worker_nanos,
            steal: _,
        } = tasks;
        let n = blocks.len();
        for x in nanos.iter_mut() {
            *x = 0;
        }
        for x in worker_nanos.iter_mut() {
            *x = 0;
        }
        if n == 0 {
            if let Some(ov) = overlap.as_mut() {
                ov();
            }
            return;
        }

        // Epoch-start housekeeping: one reconnect attempt per dead node
        // while its budget lasts, then reset the liveness clocks — time
        // spent between epochs (perplexity, checkpoints) must not count
        // against the workers.
        for i in 0..self.nodes.len() {
            if self.nodes[i].writer.is_none() && self.nodes[i].reconnects_left > 0 {
                self.nodes[i].reconnects_left -= 1;
                if let Some(h) = self.nodes[i].reader.take() {
                    let _ = h.join();
                }
                let _ = self.connect_node(i, 1);
            }
        }
        let now = Instant::now();
        for node in &mut self.nodes {
            node.last_seen = now;
            node.busy = 0;
        }
        // Drain stale events from buried connections of past epochs.
        while self.rx.try_recv().is_ok() {}

        let mut flights: Vec<Option<Flight>> = (0..n).map(|_| None).collect();
        let mut ticket_ids: Vec<Option<TicketIds>> = (0..n).map(|_| None).collect();
        let mut completed = vec![false; n];
        let mut done = 0usize;
        let mut watermark = 0usize;
        let mut rr = 0usize;
        let mut queue: VecDeque<usize> = (0..n).collect();
        let mut overlap_pending = true;

        let hb = Duration::from_millis(self.opts.heartbeat_ms.max(1));
        let liveness = Duration::from_millis(self.opts.liveness_timeout_ms.max(1));
        let tick = Duration::from_millis(self.opts.heartbeat_ms.clamp(1, 20));
        let mut last_ping = Instant::now();

        while done < n {
            // Phase 1: (re)dispatch everything queued. A send failure
            // buries the node and requeues its whole in-flight set.
            while let Some(t) = queue.pop_front() {
                if completed[t] {
                    continue;
                }
                match self.pick_node(&mut rr) {
                    Some(node) => {
                        let (msg, tids) = build_task(spec, t, ids[t], &blocks[t]);
                        if ticket_ids[t].is_none() {
                            ticket_ids[t] = Some(tids);
                        }
                        match self.send_task(node, spec.sweep as u64, &msg) {
                            Ok(()) => {
                                self.nodes[node].busy += 1;
                                flights[t] = Some(Flight {
                                    node,
                                    spec_node: None,
                                    sent_at: Instant::now(),
                                    speculated: false,
                                });
                            }
                            Err(_) => {
                                self.kill_node(node);
                                self.reassigns += 1;
                                pool::trace_instant(spec, 0, EventKind::Retry, t, ids[t], 1);
                                requeue_node(node, &mut flights, &mut queue, &mut self.reassigns, spec, ids);
                                queue.push_front(t);
                            }
                        }
                    }
                    None => {
                        // No live workers: degraded local execution.
                        let dt = self.run_local(spec, ids[t], &mut blocks[t], &mut deltas[t]);
                        nanos[t] = dt;
                        worker_nanos[0] += dt;
                        pool::trace_task(spec, 0, t, ids[t], dt, false);
                        completed[t] = true;
                        done += 1;
                        flights[t] = None;
                        advance_watermark(&mut commit, &mut watermark, &completed, deltas, done, n);
                    }
                }
            }
            if overlap_pending {
                // First full dispatch is out: the coordinator's own
                // shadow work (snapshot rebuilds etc.) overlaps with
                // remote sampling, mirroring the in-process executors.
                overlap_pending = false;
                if let Some(ov) = overlap.as_mut() {
                    ov();
                }
            }
            if done >= n {
                break;
            }

            // Phase 2: wait for worker events, with a heartbeat tick.
            match self.rx.recv_timeout(tick) {
                Ok(NodeEvent { node, ev }) => match ev {
                    Ev::Delta(msg) => {
                        self.nodes[node].last_seen = Instant::now();
                        let t = msg.ticket as usize;
                        if let Some(kind) = fault::fire(
                            fault::sites::DIST_RECV,
                            [node as u64, spec.sweep as u64, msg.ticket as u64],
                        ) {
                            let _ = kind;
                            self.kill_node(node);
                            requeue_node(node, &mut flights, &mut queue, &mut self.reassigns, spec, ids);
                            continue;
                        }
                        if t >= n || completed[t] {
                            continue; // speculation loser or stale replay
                        }
                        let Some(tids) = ticket_ids[t].as_ref() else {
                            continue;
                        };
                        if let Err(detail) = apply_delta(
                            spec, &msg, ids[t], tids, &mut blocks[t], &mut deltas[t],
                        ) {
                            // The frame decoded but its shape is wrong —
                            // a protocol bug or silent corruption. Treat
                            // the node as compromised.
                            let _ = detail;
                            self.kill_node(node);
                            requeue_node(node, &mut flights, &mut queue, &mut self.reassigns, spec, ids);
                            continue;
                        }
                        nanos[t] = msg.nanos;
                        worker_nanos[node % worker_nanos.len()] += msg.nanos;
                        self.observe_nanos(node, msg.nanos);
                        pool::trace_task(spec, node, t, ids[t], msg.nanos, false);
                        if let Some(f) = flights[t].take() {
                            self.nodes[f.node].busy = self.nodes[f.node].busy.saturating_sub(1);
                            if let Some(s) = f.spec_node {
                                self.nodes[s].busy = self.nodes[s].busy.saturating_sub(1);
                            }
                        }
                        completed[t] = true;
                        done += 1;
                        advance_watermark(&mut commit, &mut watermark, &completed, deltas, done, n);
                    }
                    Ev::Pong => {
                        self.nodes[node].last_seen = Instant::now();
                    }
                    Ev::Eof | Ev::Bad(_) => {
                        if self.nodes[node].writer.is_some() {
                            self.kill_node(node);
                            requeue_node(node, &mut flights, &mut queue, &mut self.reassigns, spec, ids);
                        }
                    }
                },
                Err(RecvTimeoutError::Timeout) => {
                    let now = Instant::now();
                    if now.duration_since(last_ping) >= hb {
                        last_ping = now;
                        for node in self.ping_all() {
                            requeue_node(node, &mut flights, &mut queue, &mut self.reassigns, spec, ids);
                        }
                    }
                    // Liveness: only nodes holding work are on the
                    // clock; an idle frozen node is caught at next send.
                    for i in 0..self.nodes.len() {
                        let stale = self.nodes[i].writer.is_some()
                            && self.nodes[i].busy > 0
                            && now.duration_since(self.nodes[i].last_seen) > liveness;
                        if stale {
                            self.kill_node(i);
                            requeue_node(i, &mut flights, &mut queue, &mut self.reassigns, spec, ids);
                        }
                    }
                    self.maybe_speculate(spec, ids, blocks, &mut flights, &completed, now);
                }
                Err(RecvTimeoutError::Disconnected) => {
                    unreachable!("coordinator holds a sender; channel cannot disconnect")
                }
            }
        }
    }

    /// Duplicate suspected stragglers onto idle nodes. First reply
    /// wins; the duplicate is harmless because deltas are absolute.
    fn maybe_speculate(
        &mut self,
        spec: &EpochSpec<'_>,
        ids: &[u64],
        blocks: &[TokenBlock],
        flights: &mut [Option<Flight>],
        completed: &[bool],
        now: Instant,
    ) {
        if !self.opts.spec_factor.is_finite() {
            return;
        }
        for t in 0..flights.len() {
            if completed[t] {
                continue;
            }
            let Some(f) = &flights[t] else { continue };
            if f.speculated {
                continue;
            }
            let est = self.nodes[f.node].ewma_nanos;
            if est <= 0.0 {
                continue;
            }
            let elapsed = now.duration_since(f.sent_at).as_nanos() as f64;
            if elapsed < self.opts.spec_factor * est {
                continue;
            }
            let owner = f.node;
            let Some(idle) = (0..self.nodes.len())
                .find(|&i| i != owner && self.nodes[i].writer.is_some() && self.nodes[i].busy == 0)
            else {
                continue;
            };
            let (msg, _) = build_task(spec, t, ids[t], &blocks[t]);
            if self.send_task(idle, spec.sweep as u64, &msg).is_ok() {
                self.nodes[idle].busy += 1;
                self.speculations += 1;
                let f = flights[t].as_mut().expect("flight checked above");
                f.speculated = true;
                f.spec_node = Some(idle);
            } else {
                self.kill_node(idle);
                // The idle node held nothing in flight; nothing to requeue.
            }
        }
    }
}

impl Executor for DistExec {
    fn run_epoch(&mut self, spec: &EpochSpec<'_>, tasks: EpochTasks<'_>, deltas: &mut [Vec<i64>]) {
        self.drive_epoch(spec, tasks, deltas, None, None);
    }

    fn run_epoch_ticketed(
        &mut self,
        spec: &EpochSpec<'_>,
        tasks: EpochTasks<'_>,
        deltas: &mut [Vec<i64>],
        overlap: &mut dyn FnMut(),
        commit: &mut dyn FnMut(usize, &[i64], usize),
    ) {
        self.drive_epoch(spec, tasks, deltas, Some(overlap), Some(commit));
    }

    fn retries(&self) -> u64 {
        self.reassigns
    }
}

impl Drop for DistExec {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Requeue every ticket whose primary copy sits on a now-dead node.
/// Each requeued ticket is one *reassign*. Tickets whose only copy on
/// the dead node was speculative keep their primary flight.
fn requeue_node(
    node: usize,
    flights: &mut [Option<Flight>],
    queue: &mut VecDeque<usize>,
    reassigns: &mut u64,
    spec: &EpochSpec<'_>,
    ids: &[u64],
) {
    for t in 0..flights.len() {
        let requeue = match &flights[t] {
            Some(f) if f.node == node => true,
            _ => false,
        };
        if requeue {
            flights[t] = None;
            queue.push_back(t);
            *reassigns += 1;
            pool::trace_instant(spec, node, EventKind::Retry, t, ids[t], 1);
        } else if let Some(f) = flights[t].as_mut() {
            if f.spec_node == Some(node) {
                f.spec_node = None;
            }
        }
    }
}

/// Commit every contiguous completed ticket at the watermark (ticketed
/// mode only). `in_flight` mirrors the in-process executors: tasks not
/// yet finished at the instant this commit runs.
fn advance_watermark(
    commit: &mut Option<&mut dyn FnMut(usize, &[i64], usize)>,
    watermark: &mut usize,
    completed: &[bool],
    deltas: &[Vec<i64>],
    done: usize,
    n: usize,
) {
    let Some(cb) = commit.as_mut() else { return };
    while *watermark < n && completed[*watermark] {
        cb(*watermark, &deltas[*watermark], n - done);
        *watermark += 1;
    }
}

/// Build the self-contained wire task for ticket `t`: gather the block's
/// touched doc/word rows by id, remap the block's global ids onto the
/// gathered (sorted-unique) lists, and serialise the block as a
/// checksummed `PPSHARD3` image stamped with its partition id.
fn build_task(
    spec: &EpochSpec<'_>,
    ticket: usize,
    partition: u64,
    block: &TokenBlock,
) -> (TaskMsg, TicketIds) {
    let mut doc_ids: Vec<u64> = block.docs.iter().map(|&d| d as u64).collect();
    doc_ids.sort_unstable();
    doc_ids.dedup();
    let mut emit_ids: Vec<u64> = block.words.iter().map(|&w| w as u64).collect();
    emit_ids.sort_unstable();
    emit_ids.dedup();
    let doc_rows = gather_rows(&spec.doc, &doc_ids);
    let emit_rows = gather_rows(&spec.emit, &emit_ids);
    let mut local = TokenBlock::with_capacity(block.len());
    for &d in &block.docs {
        let j = doc_ids
            .binary_search(&(d as u64))
            .expect("doc id came from this block");
        local.docs.push(j as u32);
    }
    for &w in &block.words {
        let j = emit_ids
            .binary_search(&(w as u64))
            .expect("word id came from this block");
        local.words.push(j as u32);
    }
    local.z.extend_from_slice(&block.z);
    let image = crate::corpus::shard::encode_block(&local, partition);
    let msg = TaskMsg {
        ticket: ticket as u32,
        epoch: spec.obs.epoch,
        sweep: spec.sweep as u64,
        partition,
        family: spec.obs.family,
        kernel: spec.kernel,
        k: spec.h.k as u32,
        alpha: spec.h.alpha,
        beta: spec.h.beta,
        wbeta: spec.h.wbeta,
        seed: spec.seed,
        snapshot: spec.snapshot.to_vec(),
        doc_ids: doc_ids.clone(),
        doc_rows,
        emit_ids: emit_ids.clone(),
        emit_rows,
        block: image,
    };
    (
        msg,
        TicketIds {
            doc: doc_ids,
            emit: emit_ids,
        },
    )
}

/// Copy the rows named by `ids` out of the shared matrix, in id order.
fn gather_rows(shared: &SharedRows<'_>, row_ids: &[u64]) -> Vec<f32> {
    let k = shared.k();
    let mut out = Vec::with_capacity(row_ids.len() * k);
    for &id in row_ids {
        debug_assert!((id as usize) < shared.rows());
        // SAFETY: the coordinator is the only writer of these rows
        // while the epoch is in flight (task rows are disjoint by the
        // diagonal-schedule invariant), and `id` indexes a row of this
        // matrix because it came from a scheduled block.
        unsafe {
            let p = shared.row_ptr(id as usize);
            out.extend_from_slice(std::slice::from_raw_parts(p, k));
        }
    }
    out
}

/// Scatter a worker's absolute result rows back into the shared
/// matrices, and take its z assignments and count delta. Validates
/// every length against the coordinator's own records first, so a
/// malformed (but checksum-clean) reply cannot write out of bounds.
fn apply_delta(
    spec: &EpochSpec<'_>,
    msg: &DeltaMsg,
    partition: u64,
    tids: &TicketIds,
    block: &mut TokenBlock,
    delta: &mut [i64],
) -> Result<(), String> {
    if msg.partition != partition {
        return Err(format!(
            "delta for partition {} on a ticket scheduled as {partition}",
            msg.partition
        ));
    }
    let k = spec.h.k;
    if msg.delta.len() != k || delta.len() != k {
        return Err(format!("delta length {} != k {k}", msg.delta.len()));
    }
    if msg.doc_rows.len() != tids.doc.len() * k {
        return Err(format!(
            "doc rows {} != {} ids x {k}",
            msg.doc_rows.len(),
            tids.doc.len()
        ));
    }
    if msg.emit_rows.len() != tids.emit.len() * k {
        return Err(format!(
            "emit rows {} != {} ids x {k}",
            msg.emit_rows.len(),
            tids.emit.len()
        ));
    }
    if msg.z.len() != block.z.len() {
        return Err(format!(
            "z length {} != block length {}",
            msg.z.len(),
            block.z.len()
        ));
    }
    scatter_rows(&spec.doc, &tids.doc, &msg.doc_rows)?;
    scatter_rows(&spec.emit, &tids.emit, &msg.emit_rows)?;
    block.z.copy_from_slice(&msg.z);
    delta.copy_from_slice(&msg.delta);
    Ok(())
}

/// Write absolute rows back by id — the inverse of [`gather_rows`].
fn scatter_rows(shared: &SharedRows<'_>, row_ids: &[u64], rows: &[f32]) -> Result<(), String> {
    let k = shared.k();
    if rows.len() != row_ids.len() * k {
        return Err("row payload length mismatch".into());
    }
    for (j, &id) in row_ids.iter().enumerate() {
        if id as usize >= shared.rows() {
            return Err(format!("row id {id} out of range ({})", shared.rows()));
        }
        // SAFETY: same exclusivity argument as [`gather_rows`]; bounds
        // checked just above. Absolute values make re-application (a
        // speculative duplicate, a replay) idempotent.
        unsafe {
            let dst = shared.row_ptr(id as usize);
            std::ptr::copy_nonoverlapping(rows.as_ptr().add(j * k), dst, k);
        }
    }
    Ok(())
}

/// Deterministic exponential backoff with a node-keyed jitter, so a
/// fleet of coordinators retrying a shared worker does not thundering-
/// herd it. Attempt 1 → ~10ms, doubling, capped near 640ms.
fn backoff_ms(node: u64, attempt: u32) -> u64 {
    let base = 10u64 << (attempt - 1).min(6);
    let mut x = node
        .wrapping_mul(0x9E37_79B9_7F4A_7C15)
        .wrapping_add(attempt as u64);
    x ^= x >> 30;
    x = x.wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x ^= x >> 27;
    base + x % base.max(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_grows_and_stays_bounded() {
        let mut prev_base = 0;
        for attempt in 1..10 {
            let d = backoff_ms(3, attempt);
            let base = 10u64 << (attempt - 1).min(6);
            assert!(d >= base && d < 2 * base, "attempt {attempt}: {d}");
            assert!(base >= prev_base);
            prev_base = base;
        }
        // Node-keyed jitter: two nodes retrying in lockstep spread out.
        assert_ne!(backoff_ms(0, 3), backoff_ms(1, 3));
    }

    #[test]
    fn default_options_are_sane() {
        let o = DistOptions::default();
        assert!(o.heartbeat_ms < o.liveness_timeout_ms);
        assert!(o.spec_factor > 1.0);
        assert!(o.connect_attempts >= 1 && o.max_reconnects >= 1);
    }
}
