//! The worker process: executes shipped tasks, answers heartbeats.
//!
//! A worker is deliberately thin — it owns no schedule, no corpus, and
//! no persistent model state. It accepts one coordinator connection at
//! a time, handshakes (the coordinator assigns its node id), and then
//! runs two loops over the shared stream:
//!
//! - a **reader thread** that answers `ping` control lines immediately
//!   (so heartbeats stay responsive while a long task samples) and
//!   forwards task frames to the compute loop over a channel, and
//! - the **compute loop**, which decodes each [`TaskMsg`], rebuilds the
//!   task's compact local state (doc/emit row matrices, snapshot,
//!   checksummed token block), and hands it to the *same*
//!   `scheduler::pool::run_task` body every in-process executor uses —
//!   same failpoint sites, same `(seed, sweep, partition)` RNG stream —
//!   so a task's result is bit-identical wherever it runs.
//!
//! Crash semantics: the compute loop runs tasks **unguarded**. A panic
//! (organic, or injected at the `dist.worker` failpoint) unwinds through
//! a drop guard that shuts the socket down, so the coordinator observes
//! EOF promptly and reassigns — the distributed analogue of the
//! in-process containment-and-retry protocol, with the coordinator
//! playing the retrying side. The `dist.heartbeat` failpoint instead
//! latches the worker *frozen* (it stops answering pings and stops
//! accepting tasks, but keeps the socket open), which is how the chaos
//! tests exercise the liveness-timeout path as opposed to the EOF path.

use crate::dist::wire::{
    self, recv_mixed, send_frame, DeltaMsg, Incoming, TaskMsg, WireError, KIND_TASK,
};
use crate::gibbs::sampler::Hyper;
use crate::kernel::Kernel;
use crate::obs::trace::{Event, EventKind, Tracer};
use crate::scheduler::pool;
use crate::scheduler::shared::SharedRows;
use crate::util::fault;
use crate::util::interrupt;
use crate::util::json::Json;
use crate::util::net::send_line;
use std::io::{self, BufReader};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Accept/compute poll period (interrupt-latch latency bound).
const POLL: Duration = Duration::from_millis(20);

/// Protocol version spoken in the hello handshake.
pub const PROTO_VERSION: u64 = 1;

#[derive(Clone, Default)]
pub struct WorkerOptions {
    /// Exit after serving one coordinator connection (tests, CI smoke).
    pub once: bool,
    /// Write this worker's own trace (its task spans) here on exit, for
    /// merging with the coordinator's via `pplda analyze-trace a b ...`.
    pub trace_out: Option<PathBuf>,
    /// Label stamped into the trace meta (defaults to `worker-<node>`).
    pub label: Option<String>,
}

/// Bind `addr` (port 0 picks a free port), announce
/// `worker: listening on <addr>` on stdout, and serve coordinator
/// connections until SIGINT/SIGTERM (or after one connection with
/// [`WorkerOptions::once`]).
pub fn serve_worker(addr: &str, opts: &WorkerOptions) -> io::Result<()> {
    let listener = TcpListener::bind(addr)?;
    serve_on(listener, opts)
}

/// [`serve_worker`] over an already-bound listener — the in-process
/// test entry (bind first, hand the coordinator the real port).
pub fn serve_on(listener: TcpListener, opts: &WorkerOptions) -> io::Result<()> {
    listener.set_nonblocking(true)?;
    println!("worker: listening on {}", listener.local_addr()?);
    loop {
        if interrupt::requested() {
            break;
        }
        match listener.accept() {
            Ok((stream, peer)) => {
                match serve_coordinator(stream, opts) {
                    Ok(node) => println!("worker: coordinator {peer} done (node {node})"),
                    Err(e) => eprintln!("worker: connection {peer} failed: {e}"),
                }
                if opts.once {
                    break;
                }
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => std::thread::sleep(POLL),
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
    Ok(())
}

/// Shuts the connection down when dropped — including a drop during
/// panic unwind, which is what turns an injected worker crash into a
/// prompt coordinator-visible EOF instead of a dangling open socket
/// (the reader thread and any in-process test clone share the fd).
struct HangupGuard(TcpStream);

impl Drop for HangupGuard {
    fn drop(&mut self) {
        let _ = self.0.shutdown(Shutdown::Both);
    }
}

/// Serve one coordinator over `stream`; returns the node id this worker
/// was assigned. See the module docs for the thread layout.
fn serve_coordinator(stream: TcpStream, opts: &WorkerOptions) -> Result<u64, WireError> {
    stream.set_nodelay(true).map_err(WireError::Io)?;
    let _hangup = HangupGuard(stream.try_clone().map_err(WireError::Io)?);
    let writer = Arc::new(Mutex::new(stream.try_clone().map_err(WireError::Io)?));
    let mut reader = BufReader::new(stream);

    // Handshake: the coordinator leads with hello and assigns our id.
    let node = match recv_mixed(&mut reader)? {
        Incoming::Line(line) => {
            let msg = Json::parse(&line).map_err(WireError::Protocol)?;
            if msg.get("cmd").and_then(Json::as_str) != Some("hello") {
                return Err(WireError::Protocol("expected hello".into()));
            }
            let proto = msg.get("proto").and_then(Json::as_u64).unwrap_or(0);
            if proto != PROTO_VERSION {
                return Err(WireError::Protocol(format!("protocol version {proto}")));
            }
            msg.get("node")
                .and_then(Json::as_u64)
                .ok_or_else(|| WireError::Protocol("hello without node id".into()))?
        }
        Incoming::Eof => return Err(WireError::Protocol("hangup before hello".into())),
        other => return Err(WireError::Protocol(format!("expected hello, got {other:?}"))),
    };
    {
        let mut ack = Json::obj();
        ack.set("cmd", "hello_ack");
        ack.set("node", node);
        ack.set("pid", std::process::id() as u64);
        let mut w = writer.lock().unwrap();
        send_line(&mut *w, &ack).map_err(WireError::Io)?;
    }

    // Reader thread: pings answered inline, tasks forwarded, shutdown
    // latched. `frozen` models a stalled process (dist.heartbeat).
    let (task_tx, task_rx): (Sender<Vec<u8>>, Receiver<Vec<u8>>) = channel();
    let stop = Arc::new(AtomicBool::new(false));
    let reader_writer = Arc::clone(&writer);
    let reader_stop = Arc::clone(&stop);
    let reader_handle = std::thread::Builder::new()
        .name(format!("dist-worker-{node}-reader"))
        .spawn(move || {
            let mut frozen = false;
            loop {
                match recv_mixed(&mut reader) {
                    Ok(Incoming::Line(line)) => {
                        let Ok(msg) = Json::parse(&line) else { continue };
                        match msg.get("cmd").and_then(Json::as_str) {
                            Some("ping") => {
                                let seq = msg.get("seq").and_then(Json::as_u64).unwrap_or(0);
                                if fault::fire(fault::sites::DIST_HEARTBEAT, [node, seq, 0])
                                    .is_some()
                                {
                                    frozen = true;
                                }
                                if frozen {
                                    continue;
                                }
                                let mut pong = Json::obj();
                                pong.set("cmd", "pong");
                                pong.set("seq", seq);
                                pong.set("node", node);
                                let mut w = reader_writer.lock().unwrap();
                                if send_line(&mut *w, &pong).is_err() {
                                    break;
                                }
                            }
                            Some("shutdown") => break,
                            _ => {}
                        }
                    }
                    Ok(Incoming::Frame { kind: KIND_TASK, payload }) => {
                        // A frozen worker also stops taking work: the
                        // coordinator must detect it via the liveness
                        // timeout, not via a trickle of late results.
                        if !frozen && task_tx.send(payload).is_err() {
                            break;
                        }
                    }
                    Ok(Incoming::Frame { .. }) => break, // not ours to receive
                    Ok(Incoming::Eof) | Err(_) => break,
                }
            }
            reader_stop.store(true, Ordering::SeqCst);
        })
        .expect("spawn worker reader thread");

    // Compute loop. Long-lived kernel (scratch persists across tasks,
    // rebuilt only when the kind changes) and an optional local tracer.
    let tracer = opts.trace_out.as_ref().map(|_| Tracer::new(1));
    let mut kernel: Option<Box<dyn Kernel>> = None;
    let mut tasks_run = 0u64;
    while !(stop.load(Ordering::SeqCst) || interrupt::requested()) {
        let payload = match task_rx.recv_timeout(POLL) {
            Ok(p) => p,
            Err(RecvTimeoutError::Timeout) => continue,
            Err(RecvTimeoutError::Disconnected) => break,
        };
        let reply = run_one(node, &payload, &mut kernel, tracer.as_ref())?;
        tasks_run += 1;
        let mut w = writer.lock().unwrap();
        send_frame(&mut *w, wire::KIND_DELTA, &reply.encode()).map_err(WireError::Io)?;
    }

    // Unblock and join the reader (EOF via the shared-socket shutdown).
    drop(_hangup);
    let _ = reader_handle.join();
    if let (Some(path), Some(tr)) = (&opts.trace_out, &tracer) {
        let label = opts
            .label
            .clone()
            .unwrap_or_else(|| format!("worker-{node}"));
        let meta = crate::obs::TraceMeta { workers: 1, dropped: tr.dropped(), label };
        crate::obs::export::write_trace(path, &tr.take(), &meta).map_err(WireError::Io)?;
    }
    println!("worker: node {node} ran {tasks_run} tasks");
    Ok(node)
}

/// Decode and execute one task, returning its delta reply. Split out of
/// the serve loop so the failpoint fires with the full task coordinates
/// and the unguarded-panic surface is exactly one function.
fn run_one(
    node: u64,
    payload: &[u8],
    kernel: &mut Option<Box<dyn Kernel>>,
    tracer: Option<&Tracer>,
) -> Result<DeltaMsg, WireError> {
    let msg = TaskMsg::decode(payload)?;
    // Failpoint: an injected worker crash right before the kernel runs —
    // unguarded on purpose (see module docs).
    if fault::fire(fault::sites::DIST_WORKER, [node, msg.sweep, msg.partition]).is_some() {
        panic!(
            "injected fault: worker {node} crash at sweep {}, partition {}",
            msg.sweep, msg.partition
        );
    }
    let origin = PathBuf::from(format!("wire://node-{node}/part-{}", msg.partition));
    let mut block = msg.decode_task_block(&origin)?;
    let k = msg.k as usize;
    let mut doc_rows = msg.doc_rows.clone();
    let mut emit_rows = msg.emit_rows.clone();
    let h = Hyper { k, alpha: msg.alpha, beta: msg.beta, wbeta: msg.wbeta };
    let kern = match kernel {
        Some(kern) if kern.kind() == msg.kernel => kern,
        slot => slot.insert(msg.kernel.build()),
    };
    let mut delta = vec![0i64; k];
    let spec = pool::EpochSpec {
        doc: SharedRows::new(&mut doc_rows, k),
        emit: SharedRows::new(&mut emit_rows, k),
        snapshot: &msg.snapshot,
        h,
        seed: msg.seed,
        sweep: msg.sweep as usize,
        kernel: msg.kernel,
        obs: pool::TaskObs::default(),
    };
    let nanos = pool::run_task(&spec, msg.partition, &mut block, &mut delta, kern.as_mut());
    if let Some(tr) = tracer {
        // This worker's own view of the task (lane 0 of its private
        // tracer). The coordinator emits the authoritative span; the
        // trace merger dedups by (family, sweep, epoch, ticket).
        tr.emit(Event {
            kind: EventKind::Task,
            family: msg.family,
            lane: 0,
            sweep: msg.sweep as u32,
            epoch: msg.epoch,
            ticket: msg.ticket,
            partition: msg.partition,
            t0_ns: tr.now().saturating_sub(nanos),
            dur_ns: nanos,
            arg: 0,
        });
        tr.drain();
    }
    Ok(DeltaMsg {
        ticket: msg.ticket,
        partition: msg.partition,
        nanos,
        delta,
        doc_rows,
        emit_rows,
        z: block.z,
    })
}
