//! The dense O(K) kernel — the incremental-reciprocal scan the system
//! shipped with, repackaged behind the [`Kernel`] trait.
//!
//! Per token it rebuilds the full unnormalized conditional
//! `p(t) = (n_dk+α)(n_kw+β)·inv(t)` over all `K` topics (vectorized;
//! see [`crate::gibbs::sampler::sweep_partition`]) and draws by inverse
//! CDF. It is the cross-kernel reference: exact, branch-free, fastest
//! at small `K`, and bit-identical to the pre-kernel-subsystem hot path
//! (the executor determinism tests pin this).

use crate::gibbs::sampler;
use crate::gibbs::tokens::TokenBlock;
use crate::kernel::{Kernel, KernelKind, TaskCtx};
use crate::util::rng::Rng;

/// Dense scan with owned `probs`/`inv` scratch, sized on first task and
/// reused forever after.
#[derive(Default)]
pub struct DenseKernel {
    probs: Vec<f32>,
    inv: Vec<f32>,
}

impl Kernel for DenseKernel {
    fn kind(&self) -> KernelKind {
        KernelKind::Dense
    }

    fn sweep_task(
        &mut self,
        ctx: &TaskCtx<'_>,
        block: &mut TokenBlock,
        delta: &mut [i64],
        rng: &mut Rng,
    ) {
        sampler::sweep_partition(
            block,
            // SAFETY: the diagonal non-conflict invariant — every token
            // of this task's block lies in one `(J_m, V_n)` cell, so its
            // doc and emission rows are exclusively this task's for the
            // epoch (see `scheduler::shared::SharedRows`).
            |d| unsafe { ctx.doc.row_ptr(d) },
            |w| unsafe { ctx.emit.row_ptr(w) },
            ctx.snapshot,
            delta,
            &ctx.h,
            rng,
            &mut self.probs,
            &mut self.inv,
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::tests_support::{merge_delta, run_kernel, task_fixture};

    #[test]
    fn dense_matches_raw_sweep_partition_bitwise() {
        // The kernel is a repackaging, not a reimplementation: same
        // assignments as calling the sampler directly with the same RNG.
        let mut fx_a = task_fixture(4, 9);
        let mut fx_b = task_fixture(4, 9);

        let mut kernel = DenseKernel::default();
        run_kernel(&mut fx_a, &mut kernel, 77);

        let mut rng_b = Rng::new(77);
        let k = fx_b.h.k;
        let dt = fx_b.counts.doc_topic.as_mut_ptr();
        let wt = fx_b.counts.word_topic.as_mut_ptr();
        let (mut probs, mut inv) = (Vec::new(), Vec::new());
        sampler::sweep_partition(
            &mut fx_b.block,
            |d| unsafe { dt.add(d * k) },
            |w| unsafe { wt.add(w * k) },
            &fx_b.snapshot,
            &mut fx_b.delta,
            &fx_b.h,
            &mut rng_b,
            &mut probs,
            &mut inv,
        );

        assert_eq!(fx_a.block.z, fx_b.block.z);
        assert_eq!(fx_a.counts.doc_topic, fx_b.counts.doc_topic);
        assert_eq!(fx_a.counts.word_topic, fx_b.counts.word_topic);
        assert_eq!(fx_a.delta, fx_b.delta);
    }

    #[test]
    fn dense_preserves_invariants_across_tasks() {
        let mut fx = task_fixture(8, 10);
        let mut kernel = DenseKernel::default();
        for sweep in 0..5u64 {
            run_kernel(&mut fx, &mut kernel, 100 + sweep);
            merge_delta(&mut fx);
        }
        assert!(fx.counts.check_consistency(&[&fx.block]).is_ok());
    }
}
