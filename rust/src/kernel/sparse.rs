//! SparseLDA kernel: Yao-style s/r/q bucket decomposition of the
//! collapsed conditional (Yao, Mimno & McCallum, KDD'09), adapted to
//! the partition setting (stale `n_k` snapshot + local delta).
//!
//! With `inv(t) = 1/(n_k_eff(t) + Wβ)` the conditional splits exactly:
//!
//! ```text
//! p(t) ∝ (n_dk + α)(n_kw + β)·inv(t)
//!      =  αβ·inv(t)                  — "s" smoothing bucket, all K
//!      +  β·n_dk·inv(t)              — "r" doc bucket, n_dk > 0 only
//!      +  (n_dk + α)·n_kw·inv(t)     — "q" word bucket, n_kw > 0 only
//! ```
//!
//! `s` is maintained incrementally (only the two topics a token moves
//! between change `inv`); `r` and `q` are rebuilt per token by walking
//! the doc/word nonzero-topic lists ([`NzCache`]), which are themselves
//! maintained incrementally as counts enter/leave zero. Per-token cost
//! is therefore O(k_doc + k_word) — against the dense kernel's O(K) —
//! which wins once topics concentrate (k_doc ≪ K) and `K` is large.
//!
//! The draw walks the buckets largest-typical-mass first (q, r, s).
//! Bucket sums accumulate in f64 over the same f32 terms the walks
//! re-accumulate, so a drawn uniform that lands inside a bucket always
//! terminates inside it; only the incrementally-maintained `s` can
//! drift (≈1 ulp/token), which at worst nudges the smoothing bucket's
//! width — deterministically, so the executor bit-identity contract
//! holds exactly.

use crate::gibbs::sampler::Hyper;
use crate::gibbs::tokens::TokenBlock;
use crate::kernel::{Kernel, KernelKind, NzCache, TaskCtx};
use crate::util::rng::Rng;

/// Sparse bucket kernel with owned scratch: reciprocal cache, doc/word
/// nonzero lists, and per-token bucket term buffers — all reused across
/// tasks, invalidated per task (determinism contract).
#[derive(Default)]
pub struct SparseLdaKernel {
    /// `inv[t] = 1/(snapshot[t] + delta[t] + Wβ)`.
    inv: Vec<f32>,
    /// Running `Σ_t inv[t]` (f64; the s bucket is `αβ·sum_inv`).
    sum_inv: f64,
    doc_nz: NzCache,
    word_nz: NzCache,
    /// r-bucket terms, parallel to the current doc's nonzero list.
    rterms: Vec<f32>,
    /// q-bucket terms, parallel to the current word's nonzero list.
    qterms: Vec<f32>,
}

impl SparseLdaKernel {
    /// Select the topic for a uniform `u ∈ [0, q+r+s)`, walking buckets
    /// in q, r, s order. The trailing dense walk recomputes the
    /// smoothing terms, so fp drift in the running `s` at worst clamps
    /// to the last topic (deterministically).
    fn pick(&self, u: f64, q: f64, r: f64, d: usize, w: usize, h: &Hyper) -> usize {
        if u < q {
            let mut acc = 0.0f64;
            let list = self.word_nz.list(w);
            for (i, &term) in self.qterms.iter().enumerate() {
                acc += term as f64;
                if u < acc {
                    return list[i] as usize;
                }
            }
            if let Some(&t) = list.last() {
                return t as usize;
            }
        }
        let u = (u - q).max(0.0);
        if u < r {
            let mut acc = 0.0f64;
            let list = self.doc_nz.list(d);
            for (i, &term) in self.rterms.iter().enumerate() {
                acc += term as f64;
                if u < acc {
                    return list[i] as usize;
                }
            }
            if let Some(&t) = list.last() {
                return t as usize;
            }
        }
        let u = (u - r).max(0.0);
        let ab = h.alpha as f64 * h.beta as f64;
        let mut acc = 0.0f64;
        for (t, &iv) in self.inv.iter().enumerate() {
            acc += ab * iv as f64;
            if u < acc {
                return t;
            }
        }
        h.k - 1
    }
}

impl Kernel for SparseLdaKernel {
    fn kind(&self) -> KernelKind {
        KernelKind::Sparse
    }

    fn sweep_task(
        &mut self,
        ctx: &TaskCtx<'_>,
        block: &mut TokenBlock,
        delta: &mut [i64],
        rng: &mut Rng,
    ) {
        let h = ctx.h;
        debug_assert_eq!(delta.len(), h.k);
        self.doc_nz.begin_task(ctx.doc.rows());
        self.word_nz.begin_task(ctx.emit.rows());
        // Rebuild the reciprocal cache over the effective totals
        // (`delta` arrives zeroed from the executor, but fold it anyway
        // so the kernel is self-contained).
        self.inv.clear();
        self.inv.extend(
            ctx.snapshot
                .iter()
                .zip(delta.iter())
                .map(|(&nk, &dl)| 1.0 / ((nk as i64 + dl) as f32 + h.wbeta)),
        );
        self.sum_inv = self.inv.iter().map(|&v| v as f64).sum();

        for i in 0..block.len() {
            let d = block.docs[i] as usize;
            let w = block.words[i] as usize;
            let old = block.z[i] as usize;
            // SAFETY: the diagonal non-conflict invariant — this task's
            // partition exclusively owns doc row `d` and emission row
            // `w` for the epoch.
            let (drow, wrow) = unsafe { (ctx.doc_row(d), ctx.emit_row(w)) };
            self.doc_nz.ensure(d, drow);
            self.word_nz.ensure(w, wrow);

            // Remove the token.
            drow[old] -= 1.0;
            if drow[old] == 0.0 {
                self.doc_nz.remove(d, old as u32);
            }
            wrow[old] -= 1.0;
            if wrow[old] == 0.0 {
                self.word_nz.remove(w, old as u32);
            }
            delta[old] -= 1;
            self.sum_inv -= self.inv[old] as f64;
            self.inv[old] = 1.0 / ((ctx.snapshot[old] as i64 + delta[old]) as f32 + h.wbeta);
            self.sum_inv += self.inv[old] as f64;

            // Buckets.
            let s = h.alpha as f64 * h.beta as f64 * self.sum_inv;
            self.rterms.clear();
            let mut r = 0.0f64;
            for &t in self.doc_nz.list(d) {
                let t = t as usize;
                let term = drow[t] * h.beta * self.inv[t];
                self.rterms.push(term);
                r += term as f64;
            }
            self.qterms.clear();
            let mut q = 0.0f64;
            for &t in self.word_nz.list(w) {
                let t = t as usize;
                let term = (drow[t] + h.alpha) * wrow[t] * self.inv[t];
                self.qterms.push(term);
                q += term as f64;
            }

            let u = rng.f32_open() as f64 * (q + r + s);
            let new = self.pick(u, q, r, d, w, &h);

            // Add the token back under its new topic.
            if drow[new] == 0.0 {
                self.doc_nz.insert(d, new as u32);
            }
            drow[new] += 1.0;
            if wrow[new] == 0.0 {
                self.word_nz.insert(w, new as u32);
            }
            wrow[new] += 1.0;
            delta[new] += 1;
            self.sum_inv -= self.inv[new] as f64;
            self.inv[new] = 1.0 / ((ctx.snapshot[new] as i64 + delta[new]) as f32 + h.wbeta);
            self.sum_inv += self.inv[new] as f64;
            block.z[i] = new as u32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::dense::DenseKernel;
    use crate::kernel::tests_support::{
        doc_purity, merge_delta, one_token_distribution, run_kernel, task_fixture,
    };

    #[test]
    fn sparse_preserves_invariants_across_tasks() {
        let mut fx = task_fixture(8, 21);
        let mut kernel = SparseLdaKernel::default();
        for sweep in 0..6u64 {
            run_kernel(&mut fx, &mut kernel, 500 + sweep);
            merge_delta(&mut fx);
        }
        assert!(fx.counts.check_consistency(&[&fx.block]).is_ok());
        assert_eq!(fx.delta.iter().sum::<i64>(), 0);
    }

    #[test]
    fn sparse_matches_dense_conditional_distribution() {
        // The bucket decomposition must reproduce the dense conditional
        // exactly (up to Monte-Carlo error): same per-topic frequencies
        // when resampling one token from identical counts.
        let k = 8;
        let runs = 8_000;
        let dense = one_token_distribution(&mut DenseKernel::default(), k, runs, 40_000);
        let sparse = one_token_distribution(&mut SparseLdaKernel::default(), k, runs, 40_000);
        for t in 0..k {
            assert!(
                (dense[t] - sparse[t]).abs() < 0.04,
                "topic {t}: dense {} vs sparse {}",
                dense[t],
                sparse[t]
            );
        }
    }

    #[test]
    fn sparse_concentrates_on_planted_structure() {
        // Same canary as the dense sampler's: disjoint doc/word groups
        // must separate into distinct topics under repeated sweeps
        // (sharp priors, as in the dense sampler's concentration test).
        let mut fx = task_fixture(2, 7);
        fx.h = crate::gibbs::sampler::Hyper::new(2, 0.1, 0.05, 10);
        let mut kernel = SparseLdaKernel::default();
        for sweep in 0..60u64 {
            run_kernel(&mut fx, &mut kernel, 900 + sweep);
            merge_delta(&mut fx);
        }
        let (p0, t0) = doc_purity(&fx, 0);
        let (p5, t5) = doc_purity(&fx, 5);
        assert!(p0 > 0.9 && p5 > 0.9, "purity {p0} {p5}");
        assert_ne!(t0, t5, "disjoint word groups should map to distinct topics");
    }
}
