//! Alias kernel: O(1) stale word-proposal draws with Metropolis–
//! Hastings correction (AliasLDA — Li, Ahmed, Ravi & Smola, KDD'14 —
//! adapted to the partition setting).
//!
//! The conditional splits into a doc-side part and a word-side part:
//!
//! ```text
//! p(t) ∝ n_dk·(n_kw + β)·inv(t)  +  α·(n_kw + β)·inv(t)
//!        └─ doc bucket, exact ─┘    └─ word bucket, stale table ─┘
//! ```
//!
//! The doc bucket is computed exactly per token over the doc's nonzero
//! topics (O(k_doc), reusing the [`NzCache`] doc-side structure the
//! sparse kernel introduced). The word bucket is drawn in O(1) from a
//! per-word alias table built on the word's *first token of the task*
//! (from the then-current row and reciprocal cache) and reused — stale
//! — for the word's remaining tokens. The proposal is therefore the
//! mixture `q(t) = docterm(t) + stale_word_weight(t)`, and each draw is
//! passed through a Metropolis–Hastings accept/reject against the true
//! current conditional `π`, so the chain's stationary distribution is
//! *exact* despite the staleness: accept `t` over the current topic `s`
//! with probability `min(1, π(t)·q(s) / (π(s)·q(t)))`. A fixed
//! [`MH_STEPS`] proposals are attempted per token (staleness only slows
//! mixing, never biases it).
//!
//! One uniform drives each proposal: the value that lands in the word
//! bucket is rescaled and fed to [`AliasTable::sample_with`], so the
//! table draw consumes no extra RNG state.

use crate::gibbs::sampler::Hyper;
use crate::gibbs::tokens::TokenBlock;
use crate::kernel::{Kernel, KernelKind, NzCache, TaskCtx};
use crate::util::alias::AliasTable;
use crate::util::rng::Rng;

/// Metropolis–Hastings proposals per token. One already preserves the
/// stationary distribution; a second substantially tightens mixing
/// toward the exact conditional at negligible cost (each step is
/// O(k_doc) at worst).
pub const MH_STEPS: usize = 2;

/// Per-word stale proposal state: the alias table over
/// `α·(n_kw+β)·inv(t)` plus the raw weights (needed to evaluate the
/// proposal density in the MH ratio) and their total mass.
#[derive(Default)]
struct WordAlias {
    weights: Vec<f64>,
    total: f64,
    table: AliasTable,
}

/// Per-word alias tables with per-task (versioned) invalidation.
///
/// Tables are *always* rebuilt on a word's first token of a task, so
/// caching a `WordAlias` per vocabulary word would buy only allocation
/// reuse while costing O(V·K) resident memory per worker (gigabytes at
/// NYTimes scale). Instead, entries live in a slot *pool* sized by the
/// maximum number of distinct words any single task touches (≈ V/P):
/// `begin_task` resets the slot cursor, and a word's first access
/// claims the next pool slot and rebuilds it in place. The per-word
/// side is just a 16-byte `(version, slot)` stamp.
#[derive(Default)]
struct AliasCache {
    /// Per emission row: (task version, slot index into `pool`).
    slot: Vec<(u64, u32)>,
    pool: Vec<WordAlias>,
    /// Pool slots claimed by the current task.
    used: usize,
    current: u64,
}

impl AliasCache {
    fn begin_task(&mut self, rows: usize) {
        if self.slot.len() < rows {
            self.slot.resize(rows, (0, 0));
        }
        self.current += 1;
        self.used = 0;
    }

    /// The word's proposal state, (re)built on first access within the
    /// current task from the current row and reciprocal cache.
    fn get(&mut self, w: usize, wrow: &[f32], inv: &[f32], h: &Hyper) -> &WordAlias {
        let (version, mut idx) = self.slot[w];
        if version != self.current {
            idx = self.used as u32;
            self.slot[w] = (self.current, idx);
            self.used += 1;
            if self.pool.len() <= idx as usize {
                self.pool.push(WordAlias::default());
            }
            let entry = &mut self.pool[idx as usize];
            entry.weights.clear();
            let mut total = 0.0f64;
            for t in 0..h.k {
                let wgt = (h.alpha * (wrow[t] + h.beta) * inv[t]) as f64;
                entry.weights.push(wgt);
                total += wgt;
            }
            entry.total = total;
            entry.table.rebuild(&entry.weights);
        }
        &self.pool[idx as usize]
    }
}

/// Alias sampler with owned scratch: reciprocal cache, doc-side
/// nonzero lists, doc-bucket terms, and the per-word table cache.
#[derive(Default)]
pub struct AliasKernel {
    /// `inv[t] = 1/(snapshot[t] + delta[t] + Wβ)`.
    inv: Vec<f32>,
    doc_nz: NzCache,
    /// Doc-bucket terms, parallel to the current doc's nonzero list.
    pterms: Vec<f32>,
    tables: AliasCache,
}

impl Kernel for AliasKernel {
    fn kind(&self) -> KernelKind {
        KernelKind::Alias
    }

    fn sweep_task(
        &mut self,
        ctx: &TaskCtx<'_>,
        block: &mut TokenBlock,
        delta: &mut [i64],
        rng: &mut Rng,
    ) {
        let h = ctx.h;
        debug_assert_eq!(delta.len(), h.k);
        self.doc_nz.begin_task(ctx.doc.rows());
        self.tables.begin_task(ctx.emit.rows());
        self.inv.clear();
        self.inv.extend(
            ctx.snapshot
                .iter()
                .zip(delta.iter())
                .map(|(&nk, &dl)| 1.0 / ((nk as i64 + dl) as f32 + h.wbeta)),
        );

        for i in 0..block.len() {
            let d = block.docs[i] as usize;
            let w = block.words[i] as usize;
            let old = block.z[i] as usize;
            // SAFETY: the diagonal non-conflict invariant — this task's
            // partition exclusively owns doc row `d` and emission row
            // `w` for the epoch.
            let (drow, wrow) = unsafe { (ctx.doc_row(d), ctx.emit_row(w)) };
            self.doc_nz.ensure(d, drow);

            // Remove the token.
            drow[old] -= 1.0;
            if drow[old] == 0.0 {
                self.doc_nz.remove(d, old as u32);
            }
            wrow[old] -= 1.0;
            delta[old] -= 1;
            self.inv[old] = 1.0 / ((ctx.snapshot[old] as i64 + delta[old]) as f32 + h.wbeta);

            // Stale word-side proposal table.
            let wa = self.tables.get(w, wrow, &self.inv, &h);
            let inv = &self.inv;

            // Exact doc-side bucket over current counts.
            self.pterms.clear();
            let mut pd = 0.0f64;
            for &t in self.doc_nz.list(d) {
                let t = t as usize;
                let term = drow[t] * (wrow[t] + h.beta) * inv[t];
                self.pterms.push(term);
                pd += term as f64;
            }

            // MH over the mixture proposal.
            let total = pd + wa.total;
            let mut cur = old;
            for _ in 0..MH_STEPS {
                let u = rng.f64() * total;
                let prop = if u < pd {
                    let mut chosen = None;
                    let list = self.doc_nz.list(d);
                    let mut acc = 0.0f64;
                    for (idx, &term) in self.pterms.iter().enumerate() {
                        acc += term as f64;
                        if u < acc {
                            chosen = Some(list[idx] as usize);
                            break;
                        }
                    }
                    // `u < pd` means the walk terminates (same f64
                    // accumulation order built `pd`); the fallback only
                    // guards an empty list, which implies pd == 0.
                    chosen.unwrap_or(cur)
                } else {
                    wa.table.sample_with((u - pd) / wa.total)
                };
                if prop != cur {
                    let pi_prop =
                        ((drow[prop] + h.alpha) * (wrow[prop] + h.beta) * inv[prop]) as f64;
                    let pi_cur = ((drow[cur] + h.alpha) * (wrow[cur] + h.beta) * inv[cur]) as f64;
                    let q_prop = (drow[prop] * (wrow[prop] + h.beta) * inv[prop]) as f64
                        + wa.weights[prop];
                    let q_cur = (drow[cur] * (wrow[cur] + h.beta) * inv[cur]) as f64
                        + wa.weights[cur];
                    let ratio = (pi_prop * q_cur) / (pi_cur * q_prop);
                    if ratio >= 1.0 || rng.f64() < ratio {
                        cur = prop;
                    }
                }
            }
            let new = cur;

            // Add the token back under its new topic.
            if drow[new] == 0.0 {
                self.doc_nz.insert(d, new as u32);
            }
            drow[new] += 1.0;
            wrow[new] += 1.0;
            delta[new] += 1;
            self.inv[new] = 1.0 / ((ctx.snapshot[new] as i64 + delta[new]) as f32 + h.wbeta);
            block.z[i] = new as u32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::dense::DenseKernel;
    use crate::kernel::tests_support::{
        doc_purity, merge_delta, one_token_distribution, run_kernel, task_fixture,
    };

    #[test]
    fn alias_preserves_invariants_across_tasks() {
        let mut fx = task_fixture(8, 31);
        let mut kernel = AliasKernel::default();
        for sweep in 0..6u64 {
            run_kernel(&mut fx, &mut kernel, 700 + sweep);
            merge_delta(&mut fx);
        }
        assert!(fx.counts.check_consistency(&[&fx.block]).is_ok());
        assert_eq!(fx.delta.iter().sum::<i64>(), 0);
    }

    #[test]
    fn alias_mh_matches_dense_conditional_distribution() {
        // With a *fresh* table per run the proposal is exact, but the
        // MH machinery must still leave the conditional untouched:
        // per-topic frequencies match the dense kernel's.
        let k = 8;
        let runs = 8_000;
        let dense = one_token_distribution(&mut DenseKernel::default(), k, runs, 60_000);
        let alias = one_token_distribution(&mut AliasKernel::default(), k, runs, 60_000);
        for t in 0..k {
            assert!(
                (dense[t] - alias[t]).abs() < 0.04,
                "topic {t}: dense {} vs alias {}",
                dense[t],
                alias[t]
            );
        }
    }

    #[test]
    fn alias_concentrates_on_planted_structure() {
        // Staleness + MH must not break convergence: disjoint doc/word
        // groups still separate into distinct topics. Here tables ARE
        // reused stale within each sweep (every word repeats).
        let mut fx = task_fixture(2, 7);
        fx.h = crate::gibbs::sampler::Hyper::new(2, 0.1, 0.05, 10);
        let mut kernel = AliasKernel::default();
        for sweep in 0..60u64 {
            run_kernel(&mut fx, &mut kernel, 1_300 + sweep);
            merge_delta(&mut fx);
        }
        let (p0, t0) = doc_purity(&fx, 0);
        let (p5, t5) = doc_purity(&fx, 5);
        assert!(p0 > 0.9 && p5 > 0.9, "purity {p0} {p5}");
        assert_ne!(t0, t5, "disjoint word groups should map to distinct topics");
    }
}
