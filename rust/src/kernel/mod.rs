//! Pluggable per-partition sampling kernels.
//!
//! The executor layer ([`crate::scheduler::pool`]) fixes *where* a
//! partition's tokens are sampled (which worker, which epoch); this
//! module fixes *how*: a [`Kernel`] is the per-token algorithm that
//! sweeps one partition's [`TokenBlock`] given exclusive access to the
//! partition's document and emission count rows, an epoch-start topic
//! snapshot, and a local signed topic delta. Three implementations
//! trade per-token cost against bookkeeping:
//!
//! * [`DenseKernel`] — the O(K) incremental-reciprocal scan (the
//!   original hot path, extracted from `gibbs/sampler.rs`). The
//!   cross-kernel reference: the other kernels are validated against it
//!   statistically, and it remains the default.
//! * [`SparseLdaKernel`] — Yao-style s/r/q bucket decomposition with
//!   sparse doc-topic and word-topic row iteration; O(k_doc + k_word)
//!   per token once topics concentrate.
//! * [`AliasKernel`] — per-word alias tables drawn in O(1) plus an
//!   exact O(k_doc) doc-side bucket, with Metropolis–Hastings
//!   correction for table staleness so the stationary distribution is
//!   exact despite reuse.
//!
//! # Determinism contract
//!
//! A kernel must be a *pure function of the task*: given the same row
//! contents, snapshot, delta, token order, and RNG stream, it must
//! produce identical assignments regardless of which executor, worker,
//! or schedule ran it. Concretely that means all scratch keyed on row
//! contents (sparse lists, alias tables) is invalidated at the start of
//! every [`Kernel::sweep_task`] call and rebuilt from the rows as first
//! touched — never carried over from another task, whose identity
//! depends on the schedule. Under this contract every kernel is
//! bit-identical across `Sequential`/`Threaded`/`Pooled` and any worker
//! count, exactly like the dense path (pinned by the kernel-matrix
//! tests in `scheduler/exec.rs`, `bot/parallel.rs`, and
//! `tests/integration_train.rs`). Different kernels draw different
//! numbers of uniforms per token, so *across* kernels the chains
//! differ — they agree in distribution, not bit for bit.
//!
//! See `docs/kernels.md` for the bucket math, the MH correction, and
//! the scratch-ownership rules.

pub mod alias;
pub mod dense;
pub mod sparse;

pub use alias::AliasKernel;
pub use dense::DenseKernel;
pub use sparse::SparseLdaKernel;

use crate::gibbs::sampler::Hyper;
use crate::gibbs::tokens::TokenBlock;
use crate::scheduler::shared::SharedRows;
use crate::util::rng::Rng;

/// Which sampling kernel runs the per-token hot path.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum KernelKind {
    /// Dense O(K) scan (reference; default).
    Dense,
    /// SparseLDA s/r/q bucket decomposition.
    Sparse,
    /// Alias-table sampler with MH staleness correction.
    Alias,
}

impl KernelKind {
    /// Parse a CLI/config spelling.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "dense" => Some(Self::Dense),
            "sparse" | "sparselda" | "sparse-lda" => Some(Self::Sparse),
            "alias" => Some(Self::Alias),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Self::Dense => "dense",
            Self::Sparse => "sparse",
            Self::Alias => "alias",
        }
    }

    /// All kinds, for test/bench matrices.
    pub fn all() -> [Self; 3] {
        [Self::Dense, Self::Sparse, Self::Alias]
    }

    /// Construct a fresh kernel of this kind with empty scratch. The
    /// instance is long-lived: executors build one per worker and reuse
    /// it for every task of every epoch, so steady-state sweeps do not
    /// allocate (alias-table rebuilds amortize over each word's tokens).
    pub fn build(self) -> Box<dyn Kernel> {
        match self {
            Self::Dense => Box::<DenseKernel>::default(),
            Self::Sparse => Box::<SparseLdaKernel>::default(),
            Self::Alias => Box::<AliasKernel>::default(),
        }
    }
}

/// Everything one task (= one partition of one diagonal epoch) exposes
/// to its kernel: shared count matrices with exclusive row ownership,
/// the epoch-start topic snapshot, and the hyperparameters.
///
/// `doc` rows are grouped by document partition; `emit` rows by the
/// emission-side partition (words for LDA and the BoT word phase,
/// timestamps for the BoT timestamp phase — the timestamp factor enters
/// through [`Hyper`], with γ in place of β, so every kernel serves both
/// phases unchanged).
pub struct TaskCtx<'a> {
    pub doc: SharedRows<'a>,
    pub emit: SharedRows<'a>,
    /// Epoch-start view of the `k` topic totals backing `emit`; the
    /// effective total is `snapshot[t] + delta[t]`.
    pub snapshot: &'a [u32],
    pub h: Hyper,
}

impl<'a> TaskCtx<'a> {
    /// The partition-owned document row `d`.
    ///
    /// # Safety
    /// The caller must be sweeping a task whose partition owns document
    /// row `d` for the current epoch (diagonal non-conflict invariant —
    /// every token of the task's block satisfies this by construction).
    #[inline]
    pub unsafe fn doc_row(&self, d: usize) -> &'a mut [f32] {
        std::slice::from_raw_parts_mut(self.doc.row_ptr(d), self.h.k)
    }

    /// The partition-owned emission row `w` (word or timestamp).
    ///
    /// # Safety
    /// As [`Self::doc_row`], for emission row `w`.
    #[inline]
    pub unsafe fn emit_row(&self, w: usize) -> &'a mut [f32] {
        std::slice::from_raw_parts_mut(self.emit.row_ptr(w), self.h.k)
    }
}

/// A per-partition sampling algorithm with owned, reusable scratch.
///
/// One call to [`Self::sweep_task`] resamples every token of `block`,
/// mirroring all count changes into the partition-owned rows of
/// `ctx.doc`/`ctx.emit` and the signed topic `delta` (which the caller
/// has zeroed; the barrier merges it into the authoritative totals).
/// Implementations own whatever scratch they need and must uphold the
/// module-level determinism contract.
pub trait Kernel: Send {
    fn kind(&self) -> KernelKind;

    fn sweep_task(
        &mut self,
        ctx: &TaskCtx<'_>,
        block: &mut TokenBlock,
        delta: &mut [i64],
        rng: &mut Rng,
    );
}

/// Per-row topic nonzero lists with per-task (versioned) invalidation —
/// the doc-side sparse structure shared by [`SparseLdaKernel`] and
/// [`AliasKernel`] (and the word-side structure of the former).
///
/// A row's list is rebuilt from the dense row on first access within a
/// task and maintained incrementally afterwards; entries from previous
/// tasks are invalidated by a version stamp rather than cleared, so
/// `begin_task` is O(1) and steady-state sweeps reuse all allocations.
#[derive(Default)]
pub(crate) struct NzCache {
    version: Vec<u64>,
    lists: Vec<Vec<u32>>,
    current: u64,
}

impl NzCache {
    /// Start a new task over a matrix of `rows` rows: invalidate every
    /// cached list (lazily) and make sure the cache covers the matrix.
    pub fn begin_task(&mut self, rows: usize) {
        if self.version.len() < rows {
            self.version.resize(rows, 0);
            self.lists.resize_with(rows, Vec::new);
        }
        self.current += 1;
    }

    /// Ensure `row_id`'s list is built for the current task from the
    /// dense `row` (topics with count > 0, ascending).
    pub fn ensure(&mut self, row_id: usize, row: &[f32]) {
        if self.version[row_id] != self.current {
            self.version[row_id] = self.current;
            let list = &mut self.lists[row_id];
            list.clear();
            for (t, &c) in row.iter().enumerate() {
                if c > 0.0 {
                    list.push(t as u32);
                }
            }
        }
    }

    /// The current-task list for `row_id` (must be `ensure`d first).
    #[inline]
    pub fn list(&self, row_id: usize) -> &[u32] {
        debug_assert_eq!(self.version[row_id], self.current, "list not built");
        &self.lists[row_id]
    }

    /// Record that topic `t` left the row (count hit zero).
    #[inline]
    pub fn remove(&mut self, row_id: usize, t: u32) {
        let list = &mut self.lists[row_id];
        if let Some(pos) = list.iter().position(|&x| x == t) {
            list.swap_remove(pos);
        }
    }

    /// Record that topic `t` entered the row (count left zero).
    #[inline]
    pub fn insert(&mut self, row_id: usize, t: u32) {
        self.lists[row_id].push(t);
    }
}

/// Shared fixtures for the per-kernel unit tests: a single-partition
/// task over a small corpus (the kernel owns every row), swept in place
/// with barrier-style delta merges between sweeps.
#[cfg(test)]
pub(crate) mod tests_support {
    use super::{Kernel, TaskCtx};
    use crate::corpus::bow::BagOfWords;
    use crate::gibbs::counts::LdaCounts;
    use crate::gibbs::sampler::Hyper;
    use crate::gibbs::tokens::TokenBlock;
    use crate::scheduler::shared::SharedRows;
    use crate::util::rng::Rng;

    pub struct TaskFixture {
        pub block: TokenBlock,
        pub counts: LdaCounts,
        pub snapshot: Vec<u32>,
        pub delta: Vec<i64>,
        pub h: Hyper,
    }

    /// Whole-corpus-as-one-partition fixture (two doc groups, two word
    /// groups' worth of structure, K topics).
    pub fn task_fixture(k: usize, seed: u64) -> TaskFixture {
        let mut triplets = Vec::new();
        for d in 0..6u32 {
            for w in 0..5u32 {
                let word = if d < 3 { w } else { w + 5 };
                triplets.push((d, word, 3 + (d + w) % 4));
            }
        }
        let bow = BagOfWords::from_triplets(6, 10, triplets);
        let mut rng = Rng::new(seed);
        let block = TokenBlock::from_corpus(&bow, k, &mut rng);
        let mut counts = LdaCounts::zeros(6, 10, k);
        counts.absorb(&block);
        let snapshot = counts.topic.clone();
        TaskFixture {
            block,
            counts,
            snapshot,
            delta: vec![0i64; k],
            h: Hyper::new(k, 0.5, 0.1, 10),
        }
    }

    /// Run one task sweep with a fresh RNG stream (the fixture's delta
    /// must be zeroed, as the executor guarantees).
    pub fn run_kernel(fx: &mut TaskFixture, kernel: &mut dyn Kernel, rng_seed: u64) {
        let k = fx.h.k;
        let ctx = TaskCtx {
            doc: SharedRows::new(&mut fx.counts.doc_topic, k),
            emit: SharedRows::new(&mut fx.counts.word_topic, k),
            snapshot: &fx.snapshot,
            h: fx.h,
        };
        let mut rng = Rng::new(rng_seed);
        kernel.sweep_task(&ctx, &mut fx.block, &mut fx.delta, &mut rng);
    }

    /// Barrier: fold the task delta into the topic totals and snapshot,
    /// then zero it for the next sweep.
    pub fn merge_delta(fx: &mut TaskFixture) {
        for t in 0..fx.h.k {
            let v = fx.counts.topic[t] as i64 + fx.delta[t];
            assert!(v >= 0, "topic total went negative");
            fx.counts.topic[t] = v as u32;
            fx.snapshot[t] = v as u32;
            fx.delta[t] = 0;
        }
    }

    /// Empirical conditional of the fixture's first token under a
    /// kernel: rebuild the same fixture state and resample that single
    /// token `runs` times with fresh RNG streams from `seed0`. All
    /// kernels are exact for a first-touch token (fresh sparse lists /
    /// fresh alias table), so the histograms must agree up to
    /// Monte-Carlo error.
    pub fn one_token_distribution(
        kernel: &mut dyn Kernel,
        k: usize,
        runs: u64,
        seed0: u64,
    ) -> Vec<f64> {
        let mut hist = vec![0usize; k];
        for run in 0..runs {
            let mut fx = task_fixture(k, 3);
            fx.block.docs.truncate(1);
            fx.block.words.truncate(1);
            fx.block.z.truncate(1);
            run_kernel(&mut fx, kernel, seed0 + run);
            hist[fx.block.z[0] as usize] += 1;
        }
        hist.iter().map(|&c| c as f64 / runs as f64).collect()
    }

    /// `(purity, argmax topic)` of document `j`'s topic counts — the
    /// planted-structure concentration metric.
    pub fn doc_purity(fx: &TaskFixture, j: usize) -> (f64, Option<usize>) {
        let row = fx.counts.doc_row(j);
        let total: f32 = row.iter().sum();
        let max = row.iter().fold(0.0f32, |a, &b| a.max(b));
        (max as f64 / total as f64, row.iter().position(|&c| c == max))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_parses_cli_spellings() {
        assert_eq!(KernelKind::parse("dense"), Some(KernelKind::Dense));
        assert_eq!(KernelKind::parse("sparse"), Some(KernelKind::Sparse));
        assert_eq!(KernelKind::parse("sparse-lda"), Some(KernelKind::Sparse));
        assert_eq!(KernelKind::parse("alias"), Some(KernelKind::Alias));
        assert_eq!(KernelKind::parse("gpu"), None);
        assert_eq!(KernelKind::Sparse.name(), "sparse");
        for kind in KernelKind::all() {
            assert_eq!(kind.build().kind(), kind);
            assert_eq!(KernelKind::parse(kind.name()), Some(kind));
        }
    }

    #[test]
    fn nz_cache_builds_and_maintains_lists() {
        let mut cache = NzCache::default();
        cache.begin_task(2);
        let row = [0.0f32, 2.0, 0.0, 1.0];
        cache.ensure(1, &row);
        assert_eq!(cache.list(1), &[1, 3]);
        // Incremental maintenance.
        cache.remove(1, 3);
        assert_eq!(cache.list(1), &[1]);
        cache.insert(1, 2);
        assert_eq!(cache.list(1), &[1, 2]);
        // A repeated ensure within the same task is a no-op (the list is
        // authoritative, not the passed row).
        cache.ensure(1, &row);
        assert_eq!(cache.list(1), &[1, 2]);
        // A new task invalidates and rebuilds from the row.
        cache.begin_task(2);
        cache.ensure(1, &row);
        assert_eq!(cache.list(1), &[1, 3]);
    }
}
