//! Corpus substrate: sparse bag-of-words storage, loaders, synthetic
//! generators, and the timestamped-corpus extension used by Bag of
//! Timestamps.
//!
//! The paper evaluates on NIPS, NYTimes (UCI bag-of-words) and a
//! 1.18M-document Microsoft Academic Search crawl. None of those ship with
//! this repo, so [`synthetic`] provides generators whose *marginals* match
//! Table I (document counts, vocabulary sizes, token counts, Zipf word
//! frequencies, document-length skew, publication-year growth curve) — the
//! properties that determine partitioning difficulty. [`uci`] loads the
//! real UCI `docword.*.txt` files unchanged when available.

pub mod bow;
pub mod shard;
pub mod stats;
pub mod synthetic;
pub mod timestamps;
pub mod uci;

pub use bow::{BagOfWords, Entry};
pub use shard::{BlockError, Residency, ShardStore};
pub use timestamps::TimestampedCorpus;
