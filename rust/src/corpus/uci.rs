//! Loader for the UCI "Bag of Words" format used by the paper's NIPS and
//! NYTimes datasets (https://archive.ics.uci.edu/ml/datasets/Bag+of+Words):
//!
//! ```text
//! D
//! W
//! NNZ
//! docID wordID count        # 1-based ids, one triplet per line
//! ...
//! ```
//!
//! Drop `docword.nips.txt` / `docword.nytimes.txt` next to the binary and
//! pass `--uci <path>` to run the experiments on the real data instead of
//! the synthetic profiles.

use std::io::{BufRead, BufReader, Read};
use std::path::Path;

use crate::util::error::{bail, Context, Result};

use crate::corpus::bow::BagOfWords;

/// Parse a UCI bag-of-words stream.
pub fn read_bow(reader: impl Read) -> Result<BagOfWords> {
    let mut lines = BufReader::new(reader).lines();
    let mut next_header = |what: &str| -> Result<usize> {
        loop {
            let line = lines
                .next()
                .with_context(|| format!("missing {what} header"))??;
            let t = line.trim();
            if !t.is_empty() {
                return t.parse().with_context(|| format!("bad {what}: {t:?}"));
            }
        }
    };
    let num_docs: usize = next_header("D")?;
    let num_words: usize = next_header("W")?;
    let nnz: usize = next_header("NNZ")?;

    let mut triplets = Vec::with_capacity(nnz);
    for line in lines {
        let line = line?;
        let t = line.trim();
        if t.is_empty() {
            continue;
        }
        let mut it = t.split_ascii_whitespace();
        let (d, w, c) = match (it.next(), it.next(), it.next()) {
            (Some(d), Some(w), Some(c)) => (d, w, c),
            _ => bail!("malformed triplet line: {t:?}"),
        };
        let d: usize = d.parse().with_context(|| format!("bad doc id {d:?}"))?;
        let w: usize = w.parse().with_context(|| format!("bad word id {w:?}"))?;
        let c: u32 = c.parse().with_context(|| format!("bad count {c:?}"))?;
        if d == 0 || d > num_docs {
            bail!("doc id {d} outside 1..={num_docs}");
        }
        if w == 0 || w > num_words {
            bail!("word id {w} outside 1..={num_words}");
        }
        triplets.push(((d - 1) as u32, (w - 1) as u32, c));
    }
    if triplets.len() != nnz {
        bail!("NNZ header says {nnz}, file has {}", triplets.len());
    }
    Ok(BagOfWords::from_triplets(num_docs, num_words, triplets))
}

/// Load a UCI bag-of-words file from disk.
pub fn load_bow(path: impl AsRef<Path>) -> Result<BagOfWords> {
    let path = path.as_ref();
    let file = std::fs::File::open(path)
        .with_context(|| format!("open {}", path.display()))?;
    read_bow(file).with_context(|| format!("parse {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "3\n4\n4\n1 1 2\n1 3 1\n3 2 3\n3 4 1\n";

    #[test]
    fn parses_sample() {
        let b = read_bow(SAMPLE.as_bytes()).unwrap();
        assert_eq!(b.num_docs(), 3);
        assert_eq!(b.num_words(), 4);
        assert_eq!(b.nnz(), 4);
        assert_eq!(b.num_tokens(), 7);
        // ids are converted to 0-based.
        assert_eq!(b.doc(0)[0].word, 0);
        assert_eq!(b.col_sum(1), 3);
    }

    #[test]
    fn tolerates_blank_lines() {
        let s = "2\n\n2\n1\n1 1 1\n\n";
        let b = read_bow(s.as_bytes()).unwrap();
        assert_eq!(b.num_tokens(), 1);
    }

    #[test]
    fn rejects_bad_nnz() {
        let s = "1\n1\n2\n1 1 1\n";
        assert!(read_bow(s.as_bytes()).is_err());
    }

    #[test]
    fn rejects_out_of_range_ids() {
        let s = "1\n1\n1\n2 1 1\n";
        assert!(read_bow(s.as_bytes()).is_err());
        let s = "1\n1\n1\n1 9 1\n";
        assert!(read_bow(s.as_bytes()).is_err());
        let s = "1\n1\n1\n0 1 1\n"; // ids are 1-based
        assert!(read_bow(s.as_bytes()).is_err());
    }

    #[test]
    fn rejects_malformed_triplet() {
        let s = "1\n1\n1\n1 1\n";
        assert!(read_bow(s.as_bytes()).is_err());
    }

    #[test]
    fn roundtrip_via_tempfile() {
        let dir = std::env::temp_dir();
        let path = dir.join("pplda_uci_test.txt");
        std::fs::write(&path, SAMPLE).unwrap();
        let b = load_bow(&path).unwrap();
        assert_eq!(b.num_tokens(), 7);
        std::fs::remove_file(&path).ok();
    }
}
