//! Loader for the UCI "Bag of Words" format used by the paper's NIPS and
//! NYTimes datasets (https://archive.ics.uci.edu/ml/datasets/Bag+of+Words):
//!
//! ```text
//! D
//! W
//! NNZ
//! docID wordID count        # 1-based ids, one triplet per line
//! ...
//! ```
//!
//! Drop `docword.nips.txt` / `docword.nytimes.txt` next to the binary and
//! pass `--uci <path>` to run the experiments on the real data instead of
//! the synthetic profiles.

use std::io::{BufRead, BufReader, Read};
use std::path::Path;

use crate::util::error::{bail, Context, Result};

use crate::corpus::bow::BagOfWords;

/// Parse a UCI bag-of-words stream.
///
/// Tolerates blank and whitespace-only lines anywhere (some mirrors
/// terminate files with them), reports parse failures with their
/// 1-based line number, and *sums* duplicate `(doc, word)` triplets at
/// load — real exports occasionally split a cell across lines, and the
/// loader's contract should not depend on downstream construction
/// details to coalesce them. The `NNZ` header is checked against the
/// raw triplet-line count, before merging.
///
/// Peak memory is one 12-byte triplet per nonzero plus the final CSR:
/// the stream lands in a single flat buffer that is sorted and
/// dedup-summed in place, then handed to
/// [`BagOfWords::from_sorted_triplets`] — no hash map and no
/// `Vec<Vec<Entry>>` row staging, which at NYTimes/PubMed scale is the
/// difference between loading and OOM-ing before training even starts.
/// (Duplicate-sum overflow is detected at the merge, so that error names
/// the cell rather than a line number.)
pub fn read_bow(reader: impl Read) -> Result<BagOfWords> {
    let mut lines = BufReader::new(reader).lines().enumerate();
    let mut next_header = |what: &str| -> Result<usize> {
        loop {
            let (idx, line) = lines
                .next()
                .with_context(|| format!("missing {what} header"))?;
            let line = line?;
            let t = line.trim();
            if !t.is_empty() {
                return t.parse().with_context(|| format!("line {}: bad {what}: {t:?}", idx + 1));
            }
        }
    };
    let num_docs: usize = next_header("D")?;
    let num_words: usize = next_header("W")?;
    let nnz: usize = next_header("NNZ")?;

    let mut triplets: Vec<(u32, u32, u32)> = Vec::with_capacity(nnz);
    for (idx, line) in lines {
        let line = line?;
        let ln = idx + 1;
        let t = line.trim();
        if t.is_empty() {
            continue;
        }
        let mut it = t.split_ascii_whitespace();
        let (d, w, c) = match (it.next(), it.next(), it.next()) {
            (Some(d), Some(w), Some(c)) => (d, w, c),
            _ => bail!("line {ln}: malformed triplet line: {t:?}"),
        };
        let d: usize = d.parse().with_context(|| format!("line {ln}: bad doc id {d:?}"))?;
        let w: usize = w.parse().with_context(|| format!("line {ln}: bad word id {w:?}"))?;
        let c: u32 = c.parse().with_context(|| format!("line {ln}: bad count {c:?}"))?;
        if d == 0 || d > num_docs {
            bail!("line {ln}: doc id {d} outside 1..={num_docs}");
        }
        if w == 0 || w > num_words {
            bail!("line {ln}: word id {w} outside 1..={num_words}");
        }
        triplets.push(((d - 1) as u32, (w - 1) as u32, c));
    }
    if triplets.len() != nnz {
        bail!("NNZ header says {nnz}, file has {} triplet lines", triplets.len());
    }
    // Sort, then sum duplicate cells in place (two-cursor compaction) —
    // deterministic order with no auxiliary allocation.
    triplets.sort_unstable();
    let mut out = 0usize;
    for i in 0..triplets.len() {
        if out > 0 && triplets[out - 1].0 == triplets[i].0 && triplets[out - 1].1 == triplets[i].1
        {
            let (d, w, prev) = triplets[out - 1];
            triplets[out - 1].2 = match prev.checked_add(triplets[i].2) {
                Some(v) => v,
                None => bail!(
                    "summed count for doc {} word {} overflows u32",
                    d + 1,
                    w + 1
                ),
            };
        } else {
            triplets[out] = triplets[i];
            out += 1;
        }
    }
    triplets.truncate(out);
    Ok(BagOfWords::from_sorted_triplets(num_docs, num_words, triplets))
}

/// Load a UCI bag-of-words file from disk.
pub fn load_bow(path: impl AsRef<Path>) -> Result<BagOfWords> {
    let path = path.as_ref();
    let file = std::fs::File::open(path)
        .with_context(|| format!("open {}", path.display()))?;
    read_bow(file).with_context(|| format!("parse {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "3\n4\n4\n1 1 2\n1 3 1\n3 2 3\n3 4 1\n";

    #[test]
    fn parses_sample() {
        let b = read_bow(SAMPLE.as_bytes()).unwrap();
        assert_eq!(b.num_docs(), 3);
        assert_eq!(b.num_words(), 4);
        assert_eq!(b.nnz(), 4);
        assert_eq!(b.num_tokens(), 7);
        // ids are converted to 0-based.
        assert_eq!(b.doc(0)[0].word, 0);
        assert_eq!(b.col_sum(1), 3);
    }

    #[test]
    fn tolerates_blank_lines() {
        let s = "2\n\n2\n1\n1 1 1\n\n";
        let b = read_bow(s.as_bytes()).unwrap();
        assert_eq!(b.num_tokens(), 1);
    }

    #[test]
    fn tolerates_trailing_whitespace_and_crlf_lines() {
        let s = "2\r\n3\r\n2\r\n1 1 2   \r\n   \r\n2 3 1\t\r\n   \n";
        let b = read_bow(s.as_bytes()).unwrap();
        assert_eq!(b.num_docs(), 2);
        assert_eq!(b.nnz(), 2);
        assert_eq!(b.num_tokens(), 3);
    }

    #[test]
    fn duplicate_triplets_are_summed() {
        // The same (doc, word) cell split across lines must merge into
        // one entry with the summed count; NNZ counts the raw lines.
        let s = "2\n2\n4\n1 1 2\n2 2 5\n1 1 3\n1 2 1\n";
        let b = read_bow(s.as_bytes()).unwrap();
        assert_eq!(b.nnz(), 3, "merged entries, not raw lines");
        assert_eq!(b.num_tokens(), 11);
        assert_eq!(b.doc(0).len(), 2);
        assert_eq!(b.doc(0)[0].word, 0);
        assert_eq!(b.doc(0)[0].count, 5, "2 + 3 summed");
        assert_eq!(b.col_sum(0), 5);
    }

    #[test]
    fn duplicate_sum_overflow_is_rejected() {
        // Summing duplicates must not silently clamp: a pair of counts
        // overflowing u32 is a loader error naming the cell (duplicates
        // merge after the streaming pass, so there is no single
        // offending line — both 1-based ids identify it instead).
        let s = "1\n1\n2\n1 1 4000000000\n1 1 4000000000\n";
        let e = read_bow(s.as_bytes()).unwrap_err().to_string();
        assert!(e.contains("overflows u32"), "{e}");
        assert!(e.contains("doc 1 word 1"), "{e}");
    }

    #[test]
    fn parse_errors_carry_line_numbers() {
        // Bad triplet on (1-based) line 5.
        let s = "2\n2\n2\n1 1 1\n1 x 1\n";
        let e = read_bow(s.as_bytes()).unwrap_err().to_string();
        assert!(e.contains("line 5"), "{e}");
        assert!(e.contains("bad word id"), "{e}");

        // Out-of-range doc id on line 4 (after a blank line 3... headers
        // occupy lines 1-3 here).
        let s = "1\n1\n1\n9 1 1\n";
        let e = read_bow(s.as_bytes()).unwrap_err().to_string();
        assert!(e.contains("line 4"), "{e}");
        assert!(e.contains("doc id 9"), "{e}");

        // Malformed triplet line number survives leading blank lines.
        let s = "1\n1\n1\n\n\n1 1\n";
        let e = read_bow(s.as_bytes()).unwrap_err().to_string();
        assert!(e.contains("line 6"), "{e}");
        assert!(e.contains("malformed"), "{e}");

        // Bad header also carries its line.
        let s = "1\nxyz\n1\n1 1 1\n";
        let e = read_bow(s.as_bytes()).unwrap_err().to_string();
        assert!(e.contains("line 2"), "{e}");
        assert!(e.contains("bad W"), "{e}");
    }

    #[test]
    fn rejects_bad_nnz() {
        let s = "1\n1\n2\n1 1 1\n";
        assert!(read_bow(s.as_bytes()).is_err());
    }

    #[test]
    fn rejects_out_of_range_ids() {
        let s = "1\n1\n1\n2 1 1\n";
        assert!(read_bow(s.as_bytes()).is_err());
        let s = "1\n1\n1\n1 9 1\n";
        assert!(read_bow(s.as_bytes()).is_err());
        let s = "1\n1\n1\n0 1 1\n"; // ids are 1-based
        assert!(read_bow(s.as_bytes()).is_err());
    }

    #[test]
    fn rejects_malformed_triplet() {
        let s = "1\n1\n1\n1 1\n";
        assert!(read_bow(s.as_bytes()).is_err());
    }

    #[test]
    fn roundtrip_via_tempfile() {
        let dir = std::env::temp_dir();
        let path = dir.join("pplda_uci_test.txt");
        std::fs::write(&path, SAMPLE).unwrap();
        let b = load_bow(&path).unwrap();
        assert_eq!(b.num_tokens(), 7);
        std::fs::remove_file(&path).ok();
    }
}
