//! Out-of-core token-block storage: per-partition spill files with
//! overlapped prefetch and bounded resident memory.
//!
//! The partition grid is the natural sharding unit (CLDA-style:
//! partition-local state makes placement free), and the diagonal-epoch
//! barrier is the natural synchronization point. This module turns those
//! two facts into an out-of-core execution layer:
//!
//! * [`Residency`] — the policy knob. `InCore` keeps every [`TokenBlock`]
//!   in RAM (the historical behavior, still the default); `Spill` bounds
//!   resident token bytes to a budget, keeping roughly two diagonals
//!   resident (the one being sampled plus the prefetched next one).
//! * [`ShardStore`] — a run directory holding one file per partition
//!   (`part-<id>.blk`): a checksummed header (magic + token count +
//!   sweep stamp + per-section CRC32s), then the SoA `docs`/`words`/`z`
//!   arrays as little-endian `u32`s. Only `z` mutates during training,
//!   so write-back rewrites the `z` section in place, then commits the
//!   re-checksummed header (stamp last).
//! * [`Prefetcher`] — a long-lived IO thread that loads the next
//!   diagonal's blocks while the executor samples the current one; the
//!   epoch barrier already sequences everything else, so the overlap
//!   costs one channel send per epoch.
//! * [`ShardedBlocks`] — the diagonal-major block container both parallel
//!   trainers own. In-core it is a plain `Vec<Vec<TokenBlock>>`; in spill
//!   mode it loads/evicts diagonals on demand, tracks resident bytes
//!   against the budget, and reports the peak for the memory-bound
//!   acceptance tests.
//!
//! # Determinism contract
//!
//! Spilled execution is bit-identical to in-core: blocks round-trip
//! through the store as exact `u32` arrays, task RNG streams are keyed by
//! `(sweep, partition)` (never by residency, worker, or IO timing), and
//! write-back happens after the barrier that already sequences count
//! merging. Residency is therefore a pure capacity/performance knob —
//! pinned by the spill ≡ in-core matrix tests in `scheduler/exec.rs`,
//! `bot/parallel.rs`, and `tests/integration_train.rs`. Because every
//! partition's full state (`docs`/`words`/`z`) persists in the store, a
//! re-opened store also supports crash-safe resume: counts are
//! reconstructed by re-absorbing the stored blocks (see
//! `ParallelLda::resume_spilled`), and each block carries the sweep
//! count it was written after, so resuming from a store a crash left
//! mid-sweep (mixed stamps) is rejected instead of silently training
//! from a state no uninterrupted run produces.
//!
//! # Integrity
//!
//! Every read is verified and every failure is typed ([`BlockError`]):
//! the header carries a CRC32 per section (`docs`/`words`/`z`) plus a
//! CRC32 over the header itself, full-block writes go through
//! write-temp-then-rename (a crash mid-write can never tear a
//! *committed* block — the rename is atomic and a [`TempGuard`] removes
//! the partial temp file on every error path), and the in-place `z`
//! write-back commits the re-checksummed header only after the data, so
//! a kill inside the rewrite leaves a stale stamp or a checksum
//! mismatch a resume rejects instead of a silently-torn block.
//! Transient IO errors are retried with bounded backoff
//! (`io_retries()` counts them) before surfacing; corruption is never
//! retried. Fault injection for all of this lives behind the
//! `failpoints` cargo feature (`util::fault`).
//!
//! See `docs/out_of_core.md` for the residency modes, the
//! prefetch/barrier overlap, and the write-back protocol, and
//! `docs/fault_tolerance.md` for the integrity format and retry
//! policy.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use crate::gibbs::tokens::TokenBlock;
use crate::util::crc::crc32;
use crate::util::error::{bail, Context, Error, Result};
use crate::util::fault::{self, FaultKind};

/// Where token blocks live during training.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Residency {
    /// Every block stays in RAM (the historical behavior; default).
    InCore,
    /// Blocks spill to a [`ShardStore`]; at most ~two diagonals are
    /// resident. `budget_bytes` bounds resident token bytes: prefetching
    /// the next diagonal is skipped whenever it would exceed the budget
    /// (0 = no bound — always keep current + next). The floor is one
    /// diagonal: the one being sampled must be resident.
    Spill { budget_bytes: u64 },
}

impl Residency {
    /// Parse a CLI/config spelling; `budget_bytes` applies to `spill`.
    pub fn parse(name: &str, budget_bytes: u64) -> Option<Self> {
        match name {
            "in-core" | "incore" | "ram" => Some(Self::InCore),
            "spill" | "out-of-core" | "ooc" => Some(Self::Spill { budget_bytes }),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Self::InCore => "in-core",
            Self::Spill { .. } => "spill",
        }
    }

    /// Human label including the budget, e.g. `spill(256.00MiB)`.
    pub fn label(self) -> String {
        match self {
            Self::InCore => "in-core".to_string(),
            Self::Spill { budget_bytes: 0 } => "spill".to_string(),
            Self::Spill { budget_bytes } => {
                format!("spill({})", crate::util::human_bytes(budget_bytes as usize))
            }
        }
    }
}

/// Parse a byte count with an optional `k`/`m`/`g` suffix (powers of
/// 1024, case-insensitive): `"512"`, `"64m"`, `"2G"`.
pub fn parse_bytes(s: &str) -> Option<u64> {
    let t = s.trim();
    let (digits, mult) = match t.char_indices().last()? {
        (i, 'k') | (i, 'K') => (&t[..i], 1u64 << 10),
        (i, 'm') | (i, 'M') => (&t[..i], 1u64 << 20),
        (i, 'g') | (i, 'G') => (&t[..i], 1u64 << 30),
        _ => (t, 1),
    };
    let n: u64 = digits.trim().parse().ok()?;
    n.checked_mul(mult)
}

/// Bytes one token occupies in a [`TokenBlock`]'s SoA arrays (doc + word
/// + z, each `u32`) — the unit of the resident-memory accounting and of
/// the on-disk format.
pub const BYTES_PER_TOKEN: u64 = 12;

const MAGIC: &[u8; 8] = b"PPSHARD3";
/// Header layout (40 bytes): magic (8) | token count `n` (u64 LE) |
/// sweep stamp (u64 LE, the number of completed sweeps the block's `z`
/// state corresponds to) | CRC32 of the `docs` section (u32 LE) | CRC32
/// of `words` | CRC32 of `z` | CRC32 of header bytes `0..36`. The
/// trailing header CRC makes a torn header self-evident; the section
/// CRCs make a torn or bit-rotted payload self-evident.
const HEADER: u64 = 40;
const STAMP_OFFSET: usize = 16;
const CRC_DOCS_OFFSET: usize = 24;
const CRC_WORDS_OFFSET: usize = 28;
const CRC_Z_OFFSET: usize = 32;
const HEADER_CRC_OFFSET: usize = 36;

/// Transient-IO retry budget: attempts per store operation.
const MAX_IO_ATTEMPTS: u32 = 3;

/// Typed failure from the shard-store block IO paths. Only
/// [`BlockError::Io`] is considered transient by the retry layer;
/// every corruption variant is terminal and surfaces immediately.
#[derive(Debug)]
pub enum BlockError {
    /// The operating system failed the read/write/rename itself.
    Io {
        path: PathBuf,
        op: &'static str,
        source: std::io::Error,
    },
    /// The file is shorter than its header or its declared payload.
    Truncated { path: PathBuf, len: u64, expected: u64 },
    /// The leading bytes are not a `PPSHARD` header at all.
    BadMagic { path: PathBuf, found: [u8; 8] },
    /// A `PPSHARD` header from a different format version.
    BadVersion { path: PathBuf, found: u8 },
    /// A checksum did not verify: the named section's bytes disagree
    /// with the CRC32 the header recorded for them.
    Corrupt {
        path: PathBuf,
        section: &'static str,
        stored: u32,
        computed: u32,
    },
    /// The block's sweep stamp disagrees with the resume's expectation.
    StampMismatch {
        path: PathBuf,
        id: u64,
        stamp: u64,
        expected: u64,
    },
}

impl std::fmt::Display for BlockError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::Io { path, op, source } => {
                write!(f, "shard {}: {op}: {source}", path.display())
            }
            Self::Truncated { path, len, expected } => write!(
                f,
                "shard {}: truncated at {len} bytes (expected {expected})",
                path.display()
            ),
            Self::BadMagic { path, found } => write!(
                f,
                "shard {}: bad header (magic {:?}, expected {:?})",
                path.display(),
                String::from_utf8_lossy(found),
                String::from_utf8_lossy(MAGIC),
            ),
            Self::BadVersion { path, found } => write!(
                f,
                "shard {}: bad header (format version {:?}, this build reads {:?})",
                path.display(),
                *found as char,
                MAGIC[7] as char,
            ),
            Self::Corrupt { path, section, stored, computed } => write!(
                f,
                "shard {}: corrupt {section} section (checksum stored {stored:#010x}, \
                 computed {computed:#010x})",
                path.display()
            ),
            Self::StampMismatch { path, id, stamp, expected } => write!(
                f,
                "partition {id}: sweep stamp {stamp} != expected {expected} \
                 (store was left mid-sweep or belongs to a different run: {})",
                path.display()
            ),
        }
    }
}

impl std::error::Error for BlockError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Self::Io { source, .. } => Some(source),
            _ => None,
        }
    }
}

/// Transient errors worth retrying: OS-level IO failures other than
/// `NotFound` (a missing block will not appear on retry).
fn retryable(e: &BlockError) -> bool {
    matches!(
        e,
        BlockError::Io { source, .. } if source.kind() != std::io::ErrorKind::NotFound
    )
}

fn io_err(path: &Path, op: &'static str, source: std::io::Error) -> BlockError {
    BlockError::Io { path: path.to_path_buf(), op, source }
}

/// Backoff before retry `attempt` (1-based): the exponential base
/// `2 << attempt` ms plus a deterministic jitter in `[0, base)`, i.e.
/// bounded to `[base, 2·base)`. The jitter decorrelates the workers of
/// one run (they share a store but arrive with distinct retry sequence
/// numbers `seq`) without sacrificing reproducibility: it is a pure
/// hash of `(store token, attempt, seq)`, so a rerun under the same
/// injected faults sleeps the same schedule and retry *counts* are
/// bit-stable.
fn backoff_ms(token: u64, attempt: u32, seq: u64) -> u64 {
    let base = 2u64 << attempt;
    // splitmix64-style finalizer over the three inputs.
    let mut x = token
        .wrapping_mul(0x9E37_79B9_7F4A_7C15)
        .wrapping_add(u64::from(attempt))
        .wrapping_add(seq.wrapping_mul(0xBF58_476D_1CE4_E5B9));
    x ^= x >> 30;
    x = x.wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x ^= x >> 27;
    x = x.wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^= x >> 31;
    base + x % base
}

/// The error an injected `IoError`/`TornWrite` fault surfaces as —
/// kind `Other`, so the retry layer treats it as transient.
fn injected_io(path: &Path, op: &'static str) -> BlockError {
    io_err(path, op, std::io::Error::other("injected fault"))
}

/// Validate the 8-byte magic, distinguishing "not a shard file at all"
/// from "a shard file of a different format version".
fn check_magic(bytes: &[u8], path: &Path) -> Result<(), BlockError> {
    if bytes.len() < 8 {
        return Err(BlockError::Truncated {
            path: path.to_path_buf(),
            len: bytes.len() as u64,
            expected: HEADER,
        });
    }
    if &bytes[..8] == MAGIC {
        return Ok(());
    }
    if bytes[..7] == MAGIC[..7] {
        return Err(BlockError::BadVersion { path: path.to_path_buf(), found: bytes[7] });
    }
    let mut found = [0u8; 8];
    found.copy_from_slice(&bytes[..8]);
    Err(BlockError::BadMagic { path: path.to_path_buf(), found })
}

/// Read a little-endian `u64` out of a length-validated header.
fn le_u64_in(header: &[u8; HEADER as usize], offset: usize) -> u64 {
    let mut le = [0u8; 8];
    le.copy_from_slice(&header[offset..offset + 8]);
    u64::from_le_bytes(le)
}

/// Read a little-endian `u32` out of a length-validated header.
fn le_u32_in(header: &[u8; HEADER as usize], offset: usize) -> u32 {
    let mut le = [0u8; 4];
    le.copy_from_slice(&header[offset..offset + 4]);
    u32::from_le_bytes(le)
}

fn u32s_to_le(arr: &[u32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(4 * arr.len());
    for &x in arr {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

/// Removes a temp spill file on drop unless disarmed — every error path
/// out of [`ShardStore::write_block`] cleans up its partial write.
struct TempGuard {
    path: PathBuf,
    armed: bool,
}

impl TempGuard {
    fn new(path: PathBuf) -> Self {
        Self { path, armed: true }
    }

    /// The temp file was renamed into place; nothing to clean up.
    fn disarm(mut self) {
        self.armed = false;
    }
}

impl Drop for TempGuard {
    fn drop(&mut self) {
        if self.armed {
            let _ = std::fs::remove_file(&self.path);
        }
    }
}

/// A run directory of per-partition spill files.
///
/// Files are keyed by the grid-global partition id
/// ([`crate::scheduler::schedule::partition_id`]) and are independent of
/// each other, so concurrent access to *different* partitions (the
/// prefetch thread reading diagonal `l+1` while the coordinator writes
/// back diagonal `l`) needs no locking. Temp-created stores delete their
/// directory on drop; [`ShardStore::open`]ed (or [`ShardStore::keep`]t)
/// stores persist, which is what crash-safe resume builds on.
pub struct ShardStore {
    dir: PathBuf,
    keep: bool,
    /// Transient-IO retries this store has absorbed (telemetry).
    io_retries: AtomicU64,
    /// Fault-injection key for this store (see `util::fault`): probes
    /// fire with `[token, partition_id, 0]`, so a fault aimed at one
    /// store can never be consumed by another that reuses an id.
    token: u64,
}

impl ShardStore {
    fn from_dir(dir: PathBuf, keep: bool) -> Self {
        Self {
            token: fault::path_token(&dir),
            dir,
            keep,
            io_retries: AtomicU64::new(0),
        }
    }

    /// Create (or reuse) `dir` as a shard directory. The store deletes
    /// the directory on drop unless [`Self::keep`] is called.
    pub fn create(dir: impl Into<PathBuf>) -> Result<Self> {
        let dir = dir.into();
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("create shard dir {}", dir.display()))?;
        Ok(Self::from_dir(dir, false))
    }

    /// Create a uniquely-named store under `$PPLDA_SPILL_DIR` (or the
    /// system temp dir), tagged for debuggability.
    pub fn create_temp(tag: &str) -> Result<Self> {
        static COUNTER: AtomicU64 = AtomicU64::new(0);
        let root = std::env::var_os("PPLDA_SPILL_DIR")
            .map(PathBuf::from)
            .unwrap_or_else(std::env::temp_dir);
        let n = COUNTER.fetch_add(1, Ordering::Relaxed);
        Self::create(root.join(format!("pplda-shards-{}-{tag}-{n}", std::process::id())))
    }

    /// Open an existing shard directory (e.g. to resume after a crash).
    /// Opened stores never delete their files.
    pub fn open(dir: impl Into<PathBuf>) -> Result<Self> {
        let dir = dir.into();
        if !dir.is_dir() {
            bail!("shard dir {} does not exist", dir.display());
        }
        Ok(Self::from_dir(dir, true))
    }

    /// Transient IO retries this store has performed (0 in a fault-free
    /// run) — surfaced through the trainers' sweep statistics.
    pub fn io_retries(&self) -> u64 {
        self.io_retries.load(Ordering::Relaxed)
    }

    /// Run `op`, retrying transient IO failures (see [`retryable`])
    /// with a short jittered backoff. Corruption is never retried: a
    /// checksum mismatch is the same on every read, and retrying would
    /// only delay the refusal.
    fn with_io_retry<T>(
        &self,
        mut op: impl FnMut() -> Result<T, BlockError>,
    ) -> Result<T, BlockError> {
        let mut attempt = 1;
        loop {
            match op() {
                Err(e) if attempt < MAX_IO_ATTEMPTS && retryable(&e) => {
                    let seq = self.io_retries.fetch_add(1, Ordering::Relaxed);
                    let ms = backoff_ms(self.token, attempt, seq);
                    std::thread::sleep(std::time::Duration::from_millis(ms));
                    attempt += 1;
                }
                done => return done,
            }
        }
    }

    /// Keep the directory on drop (for resume / inspection).
    pub fn keep(&mut self) {
        self.keep = true;
    }

    pub fn path(&self) -> &Path {
        &self.dir
    }

    fn file(&self, id: u64) -> PathBuf {
        self.dir.join(format!("part-{id:08}.blk"))
    }

    /// Whether partition `id` has a spill file.
    pub fn has_block(&self, id: u64) -> bool {
        self.file(id).is_file()
    }

    /// Write a partition's full block (header + docs + words + z),
    /// stamped with the sweep count its `z` state corresponds to. The
    /// bytes go to a temp file first and are renamed into place, so a
    /// failure part-way (crash, injected fault, full disk) can never
    /// tear a *committed* `part-<id>.blk` — and the temp file itself is
    /// removed on every error path. Transient IO errors are retried.
    pub fn write_block(&self, id: u64, block: &TokenBlock, stamp: u64) -> Result<(), BlockError> {
        self.with_io_retry(|| self.write_block_once(id, block, stamp))
    }

    fn write_block_once(&self, id: u64, block: &TokenBlock, stamp: u64) -> Result<(), BlockError> {
        let path = self.file(id);
        if fault::fire("shard.write_block", [self.token, id, 0]).is_some() {
            return Err(injected_io(&path, "write (injected fault)"));
        }
        let buf = encode_block(block, stamp);

        static TMP: AtomicU64 = AtomicU64::new(0);
        let tmp = self
            .dir
            .join(format!("part-{id:08}.blk.tmp-{}", TMP.fetch_add(1, Ordering::Relaxed)));
        let guard = TempGuard::new(tmp.clone());
        std::fs::write(&tmp, &buf).map_err(|e| io_err(&tmp, "write temp", e))?;
        std::fs::rename(&tmp, &path).map_err(|e| io_err(&path, "rename temp into place", e))?;
        guard.disarm();
        Ok(())
    }

    /// Rewrite only the `z` section of partition `id`'s file in place —
    /// the write-back path (docs/words never change after init) — then
    /// commit the re-checksummed header carrying the new sweep stamp.
    /// Data-before-header ordering keeps the mid-*process-kill* window
    /// detectable: a kill inside the `z` rewrite leaves the old header,
    /// whose stale stamp (and now-mismatched `z` checksum) a resume
    /// rejects. Across a *system* crash the page cache may reorder the
    /// two writes, so power-loss durability would additionally need a
    /// `sync_data` between them (deliberately not paid on the per-epoch
    /// hot path — see `docs/out_of_core.md`). Transient IO errors are
    /// retried; a torn attempt is repaired by its retry because the
    /// full `z` section is rewritten each time.
    pub fn write_z(&self, id: u64, block: &TokenBlock, stamp: u64) -> Result<(), BlockError> {
        self.with_io_retry(|| self.write_z_once(id, block, stamp))
    }

    fn write_z_once(&self, id: u64, block: &TokenBlock, stamp: u64) -> Result<(), BlockError> {
        use std::io::{Read, Seek, SeekFrom, Write};
        let path = self.file(id);
        let torn = match fault::fire("shard.write_z", [self.token, id, 0]) {
            Some(FaultKind::TornWrite) => true,
            Some(_) => return Err(injected_io(&path, "write-back (injected fault)")),
            None => false,
        };
        let mut f = std::fs::OpenOptions::new()
            .read(true)
            .write(true)
            .open(&path)
            .map_err(|e| io_err(&path, "open for write-back", e))?;
        let len = f
            .metadata()
            .map_err(|e| io_err(&path, "stat for write-back", e))?
            .len();
        if len < HEADER {
            return Err(BlockError::Truncated { path, len, expected: HEADER });
        }
        let mut header = [0u8; HEADER as usize];
        f.read_exact(&mut header)
            .map_err(|e| io_err(&path, "read header for write-back", e))?;
        check_magic(&header, &path)?;
        let n = le_u64_in(&header, 8);
        assert_eq!(
            n as usize,
            block.len(),
            "write-back token count mismatch for partition {id}"
        );
        let expected_len = HEADER + BYTES_PER_TOKEN * n;
        if len < expected_len {
            return Err(BlockError::Truncated { path, len, expected: expected_len });
        }
        let z = u32s_to_le(&block.z);
        f.seek(SeekFrom::Start(HEADER + 8 * n))
            .map_err(|e| io_err(&path, "seek to z section", e))?;
        if torn {
            // Injected torn write: half the payload lands, then the
            // "device" fails. The old header (old stamp, old z checksum)
            // still governs the file, so the tear stays detectable.
            let _ = f.write_all(&z[..z.len() / 2]);
            return Err(injected_io(&path, "write-back (injected torn write)"));
        }
        f.write_all(&z)
            .map_err(|e| io_err(&path, "write back z section", e))?;
        header[STAMP_OFFSET..CRC_DOCS_OFFSET].copy_from_slice(&stamp.to_le_bytes());
        header[CRC_Z_OFFSET..HEADER_CRC_OFFSET].copy_from_slice(&crc32(&z).to_le_bytes());
        let hcrc = crc32(&header[..HEADER_CRC_OFFSET]);
        header[HEADER_CRC_OFFSET..].copy_from_slice(&hcrc.to_le_bytes());
        f.seek(SeekFrom::Start(0))
            .map_err(|e| io_err(&path, "seek to header", e))?;
        f.write_all(&header)
            .map_err(|e| io_err(&path, "commit header", e))?;
        Ok(())
    }

    /// Load partition `id`'s block, verifying every checksum.
    pub fn read_block(&self, id: u64) -> Result<TokenBlock, BlockError> {
        Ok(self.read_block_stamped(id)?.0)
    }

    /// Load partition `id`'s block and verify its sweep stamp — the one
    /// copy of the resume-validation rule (a mixed-stamp store was left
    /// mid-sweep by a kill and cannot be resumed bit-identically).
    pub fn read_block_verified(&self, id: u64, expected: u64) -> Result<TokenBlock, BlockError> {
        let (b, stamp) = self.read_block_stamped(id)?;
        if stamp != expected {
            return Err(BlockError::StampMismatch { path: self.file(id), id, stamp, expected });
        }
        Ok(b)
    }

    /// Load partition `id`'s block plus its sweep stamp — the resume
    /// path, which must verify every block is from the same sweep.
    /// Transient IO errors are retried; any magic, version, length, or
    /// checksum violation surfaces as the matching [`BlockError`].
    pub fn read_block_stamped(&self, id: u64) -> Result<(TokenBlock, u64), BlockError> {
        self.with_io_retry(|| self.read_block_once(id))
    }

    fn read_block_once(&self, id: u64) -> Result<(TokenBlock, u64), BlockError> {
        let path = self.file(id);
        if fault::fire("shard.read", [self.token, id, 0]).is_some() {
            return Err(injected_io(&path, "read (injected fault)"));
        }
        let bytes = std::fs::read(&path).map_err(|e| io_err(&path, "read", e))?;
        decode_block(&bytes, &path)
    }
}

fn read_u32s(bytes: &[u8], out: &mut Vec<u32>) {
    for c in bytes.chunks_exact(4) {
        let mut le = [0u8; 4];
        le.copy_from_slice(c);
        out.push(u32::from_le_bytes(le));
    }
}

/// Serialize a block to its `PPSHARD3` byte image (checksummed header +
/// docs + words + z sections). The one copy of the layout: the spill
/// store's atomic file writes and the distributed wire protocol
/// ([`crate::dist::wire`], which ships partitions to workers as exactly
/// these bytes) both call it.
pub(crate) fn encode_block(block: &TokenBlock, stamp: u64) -> Vec<u8> {
    let docs = u32s_to_le(&block.docs);
    let words = u32s_to_le(&block.words);
    let z = u32s_to_le(&block.z);
    let mut header = [0u8; HEADER as usize];
    header[..8].copy_from_slice(MAGIC);
    header[8..STAMP_OFFSET].copy_from_slice(&(block.len() as u64).to_le_bytes());
    header[STAMP_OFFSET..CRC_DOCS_OFFSET].copy_from_slice(&stamp.to_le_bytes());
    header[CRC_DOCS_OFFSET..CRC_WORDS_OFFSET].copy_from_slice(&crc32(&docs).to_le_bytes());
    header[CRC_WORDS_OFFSET..CRC_Z_OFFSET].copy_from_slice(&crc32(&words).to_le_bytes());
    header[CRC_Z_OFFSET..HEADER_CRC_OFFSET].copy_from_slice(&crc32(&z).to_le_bytes());
    let hcrc = crc32(&header[..HEADER_CRC_OFFSET]);
    header[HEADER_CRC_OFFSET..].copy_from_slice(&hcrc.to_le_bytes());
    let cap = HEADER as usize + (BYTES_PER_TOKEN as usize) * block.len();
    let mut buf = Vec::with_capacity(cap);
    buf.extend_from_slice(&header);
    buf.extend_from_slice(&docs);
    buf.extend_from_slice(&words);
    buf.extend_from_slice(&z);
    buf
}

/// Decode a `PPSHARD3` byte image produced by [`encode_block`] (a spill
/// file's contents, or a block section of a wire frame), verifying the
/// magic, header CRC, declared length, and all three section CRCs.
/// `origin` labels the error (a filesystem path, or a pseudo-path like
/// `wire://node-3/part-7` for frames).
pub(crate) fn decode_block(bytes: &[u8], origin: &Path) -> Result<(TokenBlock, u64), BlockError> {
    check_magic(bytes, origin)?;
    if bytes.len() < HEADER as usize {
        return Err(BlockError::Truncated {
            path: origin.to_path_buf(),
            len: bytes.len() as u64,
            expected: HEADER,
        });
    }
    let mut header = [0u8; HEADER as usize];
    header.copy_from_slice(&bytes[..HEADER as usize]);
    let stored_hcrc = le_u32_in(&header, HEADER_CRC_OFFSET);
    let computed_hcrc = crc32(&header[..HEADER_CRC_OFFSET]);
    if stored_hcrc != computed_hcrc {
        return Err(BlockError::Corrupt {
            path: origin.to_path_buf(),
            section: "header",
            stored: stored_hcrc,
            computed: computed_hcrc,
        });
    }
    let n = le_u64_in(&header, 8) as usize;
    let stamp = le_u64_in(&header, STAMP_OFFSET);
    if bytes.len() as u64 != HEADER + BYTES_PER_TOKEN * n as u64 {
        return Err(BlockError::Truncated {
            path: origin.to_path_buf(),
            len: bytes.len() as u64,
            expected: HEADER + BYTES_PER_TOKEN * n as u64,
        });
    }
    let h = HEADER as usize;
    let sections = [
        ("docs", CRC_DOCS_OFFSET, h),
        ("words", CRC_WORDS_OFFSET, h + 4 * n),
        ("z", CRC_Z_OFFSET, h + 8 * n),
    ];
    for (section, crc_at, start) in sections {
        let stored = le_u32_in(&header, crc_at);
        let computed = crc32(&bytes[start..start + 4 * n]);
        if stored != computed {
            return Err(BlockError::Corrupt {
                path: origin.to_path_buf(),
                section,
                stored,
                computed,
            });
        }
    }
    let mut block = TokenBlock::with_capacity(n);
    read_u32s(&bytes[h..h + 4 * n], &mut block.docs);
    read_u32s(&bytes[h + 4 * n..h + 8 * n], &mut block.words);
    read_u32s(&bytes[h + 8 * n..h + 12 * n], &mut block.z);
    Ok((block, stamp))
}

impl Drop for ShardStore {
    fn drop(&mut self) {
        if !self.keep {
            let _ = std::fs::remove_dir_all(&self.dir);
        }
    }
}

/// The overlapped-load IO thread: one long-lived worker that reads a
/// requested id list from the store and hands the blocks back over a
/// channel. At most one request is in flight; the trainer issues it just
/// before dispatching an epoch and collects it at (or after) the epoch
/// barrier, so the load overlaps sampling.
pub struct Prefetcher {
    tx: Option<Sender<Vec<u64>>>,
    rx: Receiver<Result<Vec<TokenBlock>>>,
    handle: Option<JoinHandle<()>>,
}

impl Prefetcher {
    pub fn new(store: Arc<ShardStore>) -> Self {
        let (tx, req_rx) = channel::<Vec<u64>>();
        let (res_tx, rx) = channel();
        let handle = std::thread::spawn(move || {
            while let Ok(ids) = req_rx.recv() {
                let mut out = Vec::with_capacity(ids.len());
                let mut failed = None;
                for id in ids {
                    match store.read_block(id) {
                        Ok(b) => out.push(b),
                        Err(e) => {
                            failed = Some(Error::from(e));
                            break;
                        }
                    }
                }
                let msg = match failed {
                    None => Ok(out),
                    Some(e) => Err(e),
                };
                if res_tx.send(msg).is_err() {
                    break; // trainer gone
                }
            }
        });
        Self {
            tx: Some(tx),
            rx,
            handle: Some(handle),
        }
    }

    /// Start loading `ids`. The caller must collect the previous request
    /// with [`Self::take`] first (enforced by [`ShardedBlocks`]).
    pub fn request(&mut self, ids: Vec<u64>) {
        self.tx
            .as_ref()
            .expect("prefetcher shut down")
            .send(ids)
            .expect("prefetcher thread died");
    }

    /// Block until the in-flight request completes and return its blocks.
    pub fn take(&mut self) -> Result<Vec<TokenBlock>> {
        self.rx
            .recv()
            .map_err(|_| Error::msg("prefetcher thread died"))?
    }
}

impl Drop for Prefetcher {
    fn drop(&mut self) {
        self.tx.take(); // close the request channel; the worker exits
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Diagonal-major token blocks under a residency policy — the block
/// container both parallel trainers own.
///
/// The per-sweep protocol (spill mode; everything is a no-op in-core):
///
/// ```text
/// for l in 0..P {
///     acquire(l)            // sync load, or collect the prefetch
///     prefetch((l+1) % P)   // overlapped with the epoch below
///     run_epoch(l); merge barrier
///     release(l)            // write back z, evict
/// }
/// ```
///
/// Resident-byte accounting counts a prefetched diagonal from the moment
/// its request is issued (the IO thread holds the blocks while the
/// current diagonal is still resident), so `peak_resident_bytes` is an
/// honest peak and `prefetch` can gate on the budget before starting.
pub struct ShardedBlocks {
    // Field order matters for Drop: join the prefetcher (which holds an
    // `Arc<ShardStore>` clone) before the store can delete its directory.
    prefetcher: Option<Prefetcher>,
    store: Option<Arc<ShardStore>>,
    /// `blocks[l]` — diagonal `l`'s blocks; empty when non-resident.
    blocks: Vec<Vec<TokenBlock>>,
    /// Global partition ids, parallel to `blocks` (survive eviction).
    ids: Vec<Vec<u64>>,
    /// Token bytes per diagonal (12 bytes/token; survive eviction).
    diag_bytes: Vec<u64>,
    resident: Vec<bool>,
    residency: Residency,
    /// Diagonal index of the in-flight prefetch, if any.
    pending: Option<usize>,
    /// Sweep stamp written with every block (see [`Self::set_stamp`]).
    stamp: u64,
    resident_bytes: u64,
    peak_resident_bytes: u64,
}

impl ShardedBlocks {
    /// All blocks stay in RAM (the historical behavior).
    pub fn in_core() -> Self {
        Self {
            prefetcher: None,
            store: None,
            blocks: Vec::new(),
            ids: Vec::new(),
            diag_bytes: Vec::new(),
            resident: Vec::new(),
            residency: Residency::InCore,
            pending: None,
            stamp: 0,
            resident_bytes: 0,
            peak_resident_bytes: 0,
        }
    }

    /// Blocks spill to `store`; see [`Residency::Spill`] for the budget
    /// semantics.
    pub fn spill(store: ShardStore, budget_bytes: u64) -> Self {
        let store = Arc::new(store);
        Self {
            prefetcher: Some(Prefetcher::new(Arc::clone(&store))),
            store: Some(store),
            blocks: Vec::new(),
            ids: Vec::new(),
            diag_bytes: Vec::new(),
            resident: Vec::new(),
            residency: Residency::Spill { budget_bytes },
            pending: None,
            stamp: 0,
            resident_bytes: 0,
            peak_resident_bytes: 0,
        }
    }

    pub fn residency(&self) -> Residency {
        self.residency
    }

    /// Set the sweep stamp subsequent writes carry: the number of
    /// completed sweeps the written `z` state corresponds to (0 at
    /// init). Trainers set `sweep_no + 1` before each sweep, so an
    /// at-rest store has every block uniformly stamped and a resume can
    /// verify it is not mid-sweep.
    pub fn set_stamp(&mut self, stamp: u64) {
        self.stamp = stamp;
    }

    /// Number of diagonals pushed so far (== the grid size `P` once
    /// initialization finishes).
    pub fn p(&self) -> usize {
        self.blocks.len()
    }

    fn bump_resident(&mut self, bytes: u64) {
        self.resident_bytes += bytes;
        self.peak_resident_bytes = self.peak_resident_bytes.max(self.resident_bytes);
    }

    /// Append one diagonal during initialization. In-core the blocks are
    /// kept; in spill mode they are written to the store and dropped, so
    /// init peak memory stays at roughly one diagonal. The caller has
    /// already absorbed the blocks into its count matrices.
    pub fn push_diagonal(&mut self, diag: Vec<TokenBlock>, ids: Vec<u64>) -> Result<()> {
        assert_eq!(diag.len(), ids.len(), "one id per block");
        let bytes: u64 = diag.iter().map(TokenBlock::heap_bytes).sum();
        self.diag_bytes.push(bytes);
        match self.residency {
            Residency::InCore => {
                self.resident.push(true);
                self.bump_resident(bytes);
                self.blocks.push(diag);
            }
            Residency::Spill { .. } => {
                let store = self.store.as_ref().expect("spill store");
                for (b, &id) in diag.iter().zip(&ids) {
                    store.write_block(id, b, self.stamp)?;
                }
                self.resident.push(false);
                self.blocks.push(Vec::new());
            }
        }
        self.ids.push(ids);
        Ok(())
    }

    /// Append one diagonal whose blocks already live in the store (the
    /// resume path): each block is read, verified against
    /// `expected_stamp` (a mixed-stamp store was left mid-sweep by a
    /// crash and cannot be resumed bit-identically), shown to `visit`
    /// (count re-absorption), then kept or dropped per the residency.
    pub fn adopt_diagonal(
        &mut self,
        ids: Vec<u64>,
        expected_stamp: u64,
        mut visit: impl FnMut(&TokenBlock),
    ) -> Result<()> {
        let store = self.store.as_ref().expect("adopt_diagonal needs a store");
        let mut diag = Vec::with_capacity(ids.len());
        for &id in &ids {
            let b = store.read_block_verified(id, expected_stamp)?;
            visit(&b);
            diag.push(b);
        }
        let bytes: u64 = diag.iter().map(TokenBlock::heap_bytes).sum();
        self.diag_bytes.push(bytes);
        match self.residency {
            Residency::InCore => {
                self.resident.push(true);
                self.bump_resident(bytes);
                self.blocks.push(diag);
            }
            Residency::Spill { .. } => {
                self.resident.push(false);
                self.blocks.push(Vec::new());
            }
        }
        self.ids.push(ids);
        Ok(())
    }

    /// Make diagonal `l` resident: collect the in-flight prefetch if it
    /// targets `l`, otherwise load synchronously. Returns the seconds the
    /// caller stalled on IO (0 in-core, ≈0 when the prefetch finished
    /// under the sampling it overlapped).
    pub fn acquire(&mut self, l: usize) -> Result<f64> {
        if self.resident[l] {
            return Ok(0.0);
        }
        let started = Instant::now();
        if let Some(t) = self.pending.take() {
            let taken = self
                .prefetcher
                .as_mut()
                .expect("pending prefetch without a prefetcher")
                .take();
            let blocks = match taken {
                Ok(blocks) => blocks,
                Err(e) => {
                    // The response is consumed and the reservation void
                    // either way — never leave `pending` set on failure,
                    // or a retry would block on a reply that already
                    // arrived.
                    self.resident_bytes -= self.diag_bytes[t];
                    return Err(e);
                }
            };
            if t == l {
                self.blocks[l] = blocks;
                self.resident[l] = true; // bytes were counted at request
                return Ok(started.elapsed().as_secs_f64());
            }
            // A stale prefetch (schedule changed under us): the blocks
            // are clean copies of the store — discard and fall through.
            self.resident_bytes -= self.diag_bytes[t];
        }
        let store = self.store.as_ref().expect("non-resident diagonal without a store");
        let mut diag = Vec::with_capacity(self.ids[l].len());
        for &id in &self.ids[l] {
            diag.push(store.read_block(id)?);
        }
        self.blocks[l] = diag;
        self.resident[l] = true;
        self.bump_resident(self.diag_bytes[l]);
        Ok(started.elapsed().as_secs_f64())
    }

    /// Begin loading diagonal `t` on the IO thread, if the residency,
    /// budget, and in-flight state allow. The reserved bytes count as
    /// resident from this moment (the IO thread holds them).
    pub fn prefetch(&mut self, t: usize) {
        let Some(pf) = self.prefetcher.as_mut() else {
            return; // in-core, or the prefetcher was retired by keep_store
        };
        if self.resident[t] || self.pending.is_some() {
            return;
        }
        let budget = match self.residency {
            Residency::InCore => unreachable!("in-core has no prefetcher"),
            Residency::Spill { budget_bytes } => budget_bytes,
        };
        if budget > 0 && self.resident_bytes + self.diag_bytes[t] > budget {
            return; // over budget: acquire() will load synchronously
        }
        pf.request(self.ids[t].clone());
        self.pending = Some(t);
        self.bump_resident(self.diag_bytes[t]);
    }

    /// Write back diagonal `l`'s (dirty) `z` arrays and evict it. Called
    /// after the epoch barrier, so all sampling of `l` has completed.
    /// Returns the seconds spent on write-back IO (0 in-core).
    pub fn release(&mut self, l: usize) -> Result<f64> {
        if self.residency == Residency::InCore || !self.resident[l] {
            return Ok(0.0);
        }
        let started = Instant::now();
        let store = self.store.as_ref().expect("spill store");
        for (b, &id) in self.blocks[l].iter().zip(&self.ids[l]) {
            store.write_z(id, b, self.stamp)?;
        }
        self.blocks[l] = Vec::new();
        self.resident[l] = false;
        self.resident_bytes -= self.diag_bytes[l];
        Ok(started.elapsed().as_secs_f64())
    }

    /// Diagonal `l`'s blocks and ids (must be resident; see
    /// [`Self::acquire`]).
    pub fn diag_parts(&mut self, l: usize) -> (&mut [TokenBlock], &[u64]) {
        assert!(self.resident[l], "diagonal {l} is not resident");
        (&mut self.blocks[l], &self.ids[l])
    }

    /// Detach diagonal `l`'s resident blocks (plus a copy of their ids)
    /// so the caller can sample them while still scheduling IO on `self`
    /// — the ticketed-commit trainers release the previous diagonal and
    /// prefetch the next one *during* the epoch they are sampling, which
    /// a [`Self::diag_parts`] borrow would forbid. The diagonal stays
    /// accounted as resident (its bytes still count against the spill
    /// budget); only `l` itself must not be acquired/released/prefetched
    /// until [`Self::restore_diagonal`] puts the blocks back.
    pub fn take_diagonal(&mut self, l: usize) -> (Vec<TokenBlock>, Vec<u64>) {
        assert!(self.resident[l], "diagonal {l} is not resident");
        (std::mem::take(&mut self.blocks[l]), self.ids[l].clone())
    }

    /// Reattach blocks detached by [`Self::take_diagonal`].
    pub fn restore_diagonal(&mut self, l: usize, diag: Vec<TokenBlock>) {
        debug_assert!(self.resident[l], "restore of a non-resident diagonal");
        debug_assert!(self.blocks[l].is_empty(), "restore over live blocks");
        self.blocks[l] = diag;
    }

    /// Every diagonal is resident (always true in-core) — the
    /// precondition for whole-corpus consistency audits.
    pub fn fully_resident(&self) -> bool {
        self.resident.iter().all(|&r| r)
    }

    /// All currently-resident blocks, flattened (the whole corpus
    /// in-core).
    pub fn resident_blocks(&self) -> Vec<&TokenBlock> {
        self.blocks
            .iter()
            .zip(&self.resident)
            .filter(|(_, &r)| r)
            .flat_map(|(diag, _)| diag.iter())
            .collect()
    }

    /// Currently-resident token bytes (including in-flight prefetches).
    pub fn resident_bytes(&self) -> u64 {
        self.resident_bytes
    }

    /// Token bytes reserved by the in-flight prefetch (0 when none).
    /// Already counted inside [`Self::resident_bytes`]; surfaced
    /// separately so tracing can report prefetcher IO load.
    pub fn inflight_bytes(&self) -> u64 {
        self.pending.map_or(0, |t| self.diag_bytes[t])
    }

    /// High-water mark of [`Self::resident_bytes`] over the container's
    /// lifetime — what the memory-budget acceptance tests assert on.
    pub fn peak_resident_bytes(&self) -> u64 {
        self.peak_resident_bytes
    }

    /// Total token bytes across all diagonals (resident or not).
    pub fn total_bytes(&self) -> u64 {
        self.diag_bytes.iter().sum()
    }

    /// The spill directory, if this container spills.
    pub fn store_path(&self) -> Option<&Path> {
        self.store.as_deref().map(ShardStore::path)
    }

    /// Transient IO retries the underlying store has absorbed (0
    /// in-core) — surfaced through the trainers' sweep statistics.
    pub fn io_retries(&self) -> u64 {
        self.store.as_ref().map_or(0, |s| s.io_retries())
    }

    /// Write every partition's current state into `dst` — the
    /// checkpoint primitive. Resident diagonals are copied from memory;
    /// non-resident ones are read back from this container's own store
    /// (verified against the current stamp), so the export never needs
    /// more than one extra diagonal of memory and never mutates this
    /// container. Destination blocks carry the current sweep stamp.
    pub fn export_to(&self, dst: &ShardStore) -> Result<(), BlockError> {
        for l in 0..self.blocks.len() {
            if self.resident[l] {
                for (b, &id) in self.blocks[l].iter().zip(&self.ids[l]) {
                    dst.write_block(id, b, self.stamp)?;
                }
            } else {
                let store = self.store.as_ref().expect("non-resident diagonal without a store");
                for &id in &self.ids[l] {
                    let b = store.read_block_verified(id, self.stamp)?;
                    dst.write_block(id, &b, self.stamp)?;
                }
            }
        }
        Ok(())
    }

    /// Keep the spill directory on drop (resume / inspection). Retires
    /// the prefetch thread (it holds the other `Arc` clone of the
    /// store); subsequent sweeps fall back to synchronous loads.
    pub fn keep_store(&mut self) {
        if self.store.is_some() {
            if let Some(t) = self.pending.take() {
                // Collect (and discard) any in-flight load first.
                if let Some(pf) = self.prefetcher.as_mut() {
                    let _ = pf.take();
                }
                self.resident_bytes -= self.diag_bytes[t];
            }
            self.prefetcher = None; // joins the IO thread
            let store = self.store.as_mut().unwrap();
            Arc::get_mut(store)
                .expect("prefetcher joined; the store is uniquely owned")
                .keep();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn block(n: usize, seed: u64) -> TokenBlock {
        let mut rng = Rng::new(seed);
        let mut b = TokenBlock::with_capacity(n);
        for i in 0..n {
            b.docs.push(i as u32);
            b.words.push(rng.gen_range(50) as u32);
            b.z.push(rng.gen_range(8) as u32);
        }
        b
    }

    #[test]
    fn store_roundtrips_blocks_exactly() {
        let store = ShardStore::create_temp("roundtrip").unwrap();
        let b = block(1000, 1);
        store.write_block(7, &b, 0).unwrap();
        assert!(store.has_block(7));
        assert!(!store.has_block(8));
        let r = store.read_block(7).unwrap();
        assert_eq!(b.docs, r.docs);
        assert_eq!(b.words, r.words);
        assert_eq!(b.z, r.z);
    }

    #[test]
    fn write_z_rewrites_only_assignments() {
        let store = ShardStore::create_temp("writez").unwrap();
        let mut b = block(256, 2);
        store.write_block(0, &b, 0).unwrap();
        assert_eq!(store.read_block_stamped(0).unwrap().1, 0);
        for z in &mut b.z {
            *z = (*z + 1) % 8;
        }
        store.write_z(0, &b, 3).unwrap();
        let (r, stamp) = store.read_block_stamped(0).unwrap();
        assert_eq!(b.z, r.z, "z section rewritten");
        assert_eq!(b.docs, r.docs, "docs untouched");
        assert_eq!(b.words, r.words, "words untouched");
        assert_eq!(stamp, 3, "write-back commits the new sweep stamp");
    }

    #[test]
    fn reopened_store_sees_identical_state() {
        // The crash-safety primitive: drop the store (kept), reopen the
        // directory, read back bit-identical blocks.
        let dir = {
            let mut store = ShardStore::create_temp("reopen").unwrap();
            store.write_block(3, &block(100, 3), 2).unwrap();
            store.keep();
            store.path().to_path_buf()
        };
        assert!(dir.is_dir(), "kept store survives drop");
        let store = ShardStore::open(&dir).unwrap();
        let (b, stamp) = store.read_block_stamped(3).unwrap();
        assert_eq!(b, block(100, 3));
        assert_eq!(stamp, 2, "sweep stamp survives reopen");
        drop(store); // opened stores never delete
        assert!(dir.is_dir());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn temp_store_cleans_up_on_drop() {
        let dir = {
            let store = ShardStore::create_temp("cleanup").unwrap();
            store.write_block(0, &block(10, 4), 0).unwrap();
            store.path().to_path_buf()
        };
        assert!(!dir.exists(), "temp store removed its directory");
    }

    #[test]
    fn read_rejects_corrupt_files() {
        let store = ShardStore::create_temp("corrupt").unwrap();
        store.write_block(0, &block(10, 5), 0).unwrap();
        // Truncate the file below its declared token count.
        let path = store.path().join("part-00000000.blk");
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 4]).unwrap();
        let e = store.read_block(0).unwrap_err().to_string();
        assert!(e.contains("truncated"), "{e}");
        std::fs::write(&path, b"garbage!").unwrap();
        let e = store.read_block(0).unwrap_err().to_string();
        assert!(e.contains("bad header"), "{e}");
        assert!(store.read_block(99).is_err(), "missing file errors");
    }

    #[test]
    fn prefetcher_loads_in_background() {
        let store = Arc::new(ShardStore::create_temp("prefetch").unwrap());
        let (b0, b1) = (block(50, 6), block(70, 7));
        store.write_block(0, &b0, 0).unwrap();
        store.write_block(1, &b1, 0).unwrap();
        let mut pf = Prefetcher::new(Arc::clone(&store));
        pf.request(vec![1, 0]);
        let got = pf.take().unwrap();
        assert_eq!(got.len(), 2);
        assert_eq!(got[0], b1, "requested order preserved");
        assert_eq!(got[1], b0);
        pf.request(vec![42]);
        assert!(pf.take().is_err(), "missing block surfaces as an error");
    }

    #[test]
    fn parse_bytes_suffixes() {
        assert_eq!(parse_bytes("512"), Some(512));
        assert_eq!(parse_bytes("4k"), Some(4096));
        assert_eq!(parse_bytes("64M"), Some(64 << 20));
        assert_eq!(parse_bytes("2g"), Some(2 << 30));
        assert_eq!(parse_bytes(" 8m "), Some(8 << 20));
        assert_eq!(parse_bytes("x"), None);
        assert_eq!(parse_bytes(""), None);
    }

    #[test]
    fn residency_parses_and_labels() {
        assert_eq!(Residency::parse("in-core", 0), Some(Residency::InCore));
        assert_eq!(
            Residency::parse("spill", 64),
            Some(Residency::Spill { budget_bytes: 64 })
        );
        assert_eq!(Residency::parse("ooc", 0), Some(Residency::Spill { budget_bytes: 0 }));
        assert_eq!(Residency::parse("disk", 0), None);
        assert_eq!(Residency::InCore.label(), "in-core");
        assert_eq!(Residency::Spill { budget_bytes: 0 }.label(), "spill");
        assert_eq!(
            Residency::Spill { budget_bytes: 64 << 20 }.label(),
            "spill(64.00MiB)"
        );
        assert_eq!(Residency::Spill { budget_bytes: 1 }.name(), "spill");
    }

    fn two_diagonals() -> (Vec<Vec<TokenBlock>>, Vec<Vec<u64>>) {
        (
            vec![vec![block(100, 10), block(60, 11)], vec![block(80, 12), block(40, 13)]],
            vec![vec![0, 3], vec![1, 2]],
        )
    }

    #[test]
    fn in_core_container_is_always_resident() {
        let (diags, ids) = two_diagonals();
        let mut sb = ShardedBlocks::in_core();
        for (d, i) in diags.into_iter().zip(ids) {
            sb.push_diagonal(d, i).unwrap();
        }
        assert!(sb.fully_resident());
        assert_eq!(sb.resident_blocks().len(), 4);
        assert_eq!(sb.total_bytes(), 280 * BYTES_PER_TOKEN);
        assert_eq!(sb.peak_resident_bytes(), sb.total_bytes());
        assert_eq!(sb.acquire(0).unwrap(), 0.0);
        sb.prefetch(1); // no-op
        assert_eq!(sb.release(0).unwrap(), 0.0);
        assert!(sb.fully_resident(), "in-core release never evicts");
        let (blocks, pids) = sb.diag_parts(1);
        assert_eq!(blocks.len(), 2);
        assert_eq!(pids, &[1, 2]);
    }

    #[test]
    fn spill_container_bounds_residency_and_roundtrips() {
        let (diags, ids) = two_diagonals();
        let store = ShardStore::create_temp("sharded").unwrap();
        // Budget = both diagonals: prefetch allowed.
        let mut sb = ShardedBlocks::spill(store, 280 * BYTES_PER_TOKEN);
        for (d, i) in diags.into_iter().zip(ids) {
            sb.push_diagonal(d, i).unwrap();
        }
        assert!(!sb.fully_resident());
        assert_eq!(sb.resident_bytes(), 0, "init leaves nothing resident");

        // Sweep protocol: acquire 0, prefetch 1, mutate, release 0,
        // acquire 1 (collects the prefetch).
        sb.acquire(0).unwrap();
        assert_eq!(sb.resident_bytes(), 160 * BYTES_PER_TOKEN);
        sb.prefetch(1);
        assert_eq!(
            sb.resident_bytes(),
            280 * BYTES_PER_TOKEN,
            "prefetched bytes count from request time"
        );
        {
            let (blocks, _) = sb.diag_parts(0);
            for b in blocks.iter_mut() {
                for z in &mut b.z {
                    *z = 7;
                }
            }
        }
        sb.release(0).unwrap();
        assert_eq!(sb.resident_bytes(), 120 * BYTES_PER_TOKEN);
        sb.acquire(1).unwrap();
        let (blocks, _) = sb.diag_parts(1);
        assert_eq!(blocks[0], block(80, 12), "diagonal 1 round-tripped");
        sb.release(1).unwrap();
        assert_eq!(sb.resident_bytes(), 0);

        // The write-back persisted: re-acquire diagonal 0 and see z=7.
        sb.acquire(0).unwrap();
        let (blocks, _) = sb.diag_parts(0);
        assert!(blocks.iter().all(|b| b.z.iter().all(|&z| z == 7)));
        assert_eq!(sb.peak_resident_bytes(), 280 * BYTES_PER_TOKEN);
    }

    #[test]
    fn prefetch_respects_the_budget() {
        let (diags, ids) = two_diagonals();
        let store = ShardStore::create_temp("budget").unwrap();
        // Budget covers only the largest single diagonal (160 tokens):
        // prefetching while one is resident must be declined, and the
        // peak must stay within the budget.
        let budget = 160 * BYTES_PER_TOKEN;
        let mut sb = ShardedBlocks::spill(store, budget);
        for (d, i) in diags.into_iter().zip(ids) {
            sb.push_diagonal(d, i).unwrap();
        }
        for _ in 0..2 {
            for l in 0..2 {
                sb.acquire(l).unwrap();
                sb.prefetch((l + 1) % 2);
                sb.release(l).unwrap();
            }
        }
        assert!(
            sb.peak_resident_bytes() <= budget,
            "peak {} exceeded budget {budget}",
            sb.peak_resident_bytes()
        );
    }

    #[test]
    fn adopt_revisits_stored_blocks() {
        let store = ShardStore::create_temp("adopt").unwrap();
        let b = block(30, 20);
        store.write_block(5, &b, 4).unwrap();
        let mut sb = ShardedBlocks::spill(store, 0);
        let mut seen = 0u64;
        sb.adopt_diagonal(vec![5], 4, |blk| {
            seen += blk.len() as u64;
            assert_eq!(*blk, b);
        })
        .unwrap();
        assert_eq!(seen, 30);
        // A mismatched stamp (mid-sweep store) is refused.
        let e = sb.adopt_diagonal(vec![5], 9, |_| {}).unwrap_err().to_string();
        assert!(e.contains("sweep stamp 4"), "{e}");
        sb.acquire(0).unwrap();
        let (blocks, pids) = sb.diag_parts(0);
        assert_eq!(blocks[0], b);
        assert_eq!(pids, &[5]);
    }

    fn flip_byte(path: &Path, offset: usize) {
        let mut bytes = std::fs::read(path).unwrap();
        bytes[offset] ^= 0x01;
        std::fs::write(path, &bytes).unwrap();
    }

    #[test]
    fn typed_errors_name_each_corruption_mode() {
        let store = ShardStore::create_temp("typed").unwrap();
        store.write_block(1, &block(20, 8), 2).unwrap();
        let path = store.path().join("part-00000001.blk");
        let pristine = std::fs::read(&path).unwrap();

        // Bit-flip inside the docs payload: the section checksum names it.
        flip_byte(&path, HEADER as usize + 3);
        match store.read_block(1).unwrap_err() {
            BlockError::Corrupt { section, .. } => assert_eq!(section, "docs"),
            e => panic!("expected Corrupt, got {e}"),
        }
        std::fs::write(&path, &pristine).unwrap();

        // Bit-flip inside the z payload.
        flip_byte(&path, HEADER as usize + 8 * 20 + 5);
        match store.read_block(1).unwrap_err() {
            BlockError::Corrupt { section, .. } => assert_eq!(section, "z"),
            e => panic!("expected Corrupt, got {e}"),
        }
        std::fs::write(&path, &pristine).unwrap();

        // Bit-flip inside the header (the stamp): the header CRC
        // catches it before the stamp could be believed.
        flip_byte(&path, STAMP_OFFSET);
        match store.read_block_verified(1, 2).unwrap_err() {
            BlockError::Corrupt { section, .. } => assert_eq!(section, "header"),
            e => panic!("expected Corrupt, got {e}"),
        }
        std::fs::write(&path, &pristine).unwrap();

        // Truncated tail.
        std::fs::write(&path, &pristine[..pristine.len() - 4]).unwrap();
        let e = store.read_block(1).unwrap_err();
        assert!(matches!(e, BlockError::Truncated { .. }), "{e}");
        std::fs::write(&path, &pristine).unwrap();

        // A previous format version is refused by name, not misparsed.
        let mut old = pristine.clone();
        old[7] = b'2';
        std::fs::write(&path, &old).unwrap();
        match store.read_block(1).unwrap_err() {
            BlockError::BadVersion { found, .. } => assert_eq!(found, b'2'),
            e => panic!("expected BadVersion, got {e}"),
        }
        std::fs::write(&path, &pristine).unwrap();

        // A stale stamp is a typed mismatch (the resume refusal).
        match store.read_block_verified(1, 9).unwrap_err() {
            BlockError::StampMismatch { stamp, expected, .. } => {
                assert_eq!((stamp, expected), (2, 9));
            }
            e => panic!("expected StampMismatch, got {e}"),
        }
        // And the pristine file still reads cleanly.
        assert_eq!(store.read_block_verified(1, 2).unwrap(), block(20, 8));
    }

    #[test]
    fn torn_z_write_back_is_detected_on_read() {
        // Simulate a kill half-way through a z write-back: new z bytes
        // land, the old header still governs the file, so the stale z
        // checksum makes the tear loud instead of silent.
        let store = ShardStore::create_temp("torn").unwrap();
        let mut b = block(64, 21);
        store.write_block(2, &b, 1).unwrap();
        for z in &mut b.z {
            *z ^= 1;
        }
        let z = u32s_to_le(&b.z);
        let path = store.path().join("part-00000002.blk");
        let mut bytes = std::fs::read(&path).unwrap();
        let at = HEADER as usize + 8 * 64;
        bytes[at..at + z.len() / 2].copy_from_slice(&z[..z.len() / 2]);
        std::fs::write(&path, &bytes).unwrap();
        match store.read_block(2).unwrap_err() {
            BlockError::Corrupt { section, .. } => assert_eq!(section, "z"),
            e => panic!("expected Corrupt, got {e}"),
        }
    }

    #[test]
    fn failed_writes_leave_no_temp_files() {
        let store = ShardStore::create_temp("tempclean").unwrap();
        store.write_block(0, &block(10, 9), 0).unwrap();
        let leftovers = std::fs::read_dir(store.path())
            .unwrap()
            .filter(|e| e.as_ref().unwrap().file_name().to_string_lossy().contains(".tmp-"))
            .count();
        assert_eq!(leftovers, 0, "successful write leaves no temp file");

        // TempGuard is the error-path cleanup: armed guards remove the
        // file on drop, disarmed guards (post-rename) leave it alone.
        let tmp = store.path().join("part-00000000.blk.tmp-test");
        std::fs::write(&tmp, b"partial").unwrap();
        TempGuard::new(tmp.clone());
        assert!(!tmp.exists(), "armed guard removes the partial file");
        std::fs::write(&tmp, b"partial").unwrap();
        TempGuard::new(tmp.clone()).disarm();
        assert!(tmp.exists(), "disarmed guard leaves the file alone");
        std::fs::remove_file(&tmp).unwrap();
    }

    #[test]
    fn export_to_copies_blocks_under_both_residencies() {
        let expected = [
            (0u64, block(100, 10)),
            (3, block(60, 11)),
            (1, block(80, 12)),
            (2, block(40, 13)),
        ];

        // In-core source: blocks are exported straight from memory.
        let (diags, ids) = two_diagonals();
        let mut sb = ShardedBlocks::in_core();
        sb.set_stamp(5);
        for (d, i) in diags.into_iter().zip(ids) {
            sb.push_diagonal(d, i).unwrap();
        }
        let dst = ShardStore::create_temp("export-incore").unwrap();
        sb.export_to(&dst).unwrap();
        for (id, b) in &expected {
            assert_eq!(dst.read_block_verified(*id, 5).unwrap(), *b);
        }

        // Spill source with nothing resident: the export reads back
        // from its own store and copies, without mutating it.
        let (diags, ids) = two_diagonals();
        let store = ShardStore::create_temp("export-src").unwrap();
        let mut sb = ShardedBlocks::spill(store, 0);
        sb.set_stamp(5);
        for (d, i) in diags.into_iter().zip(ids) {
            sb.push_diagonal(d, i).unwrap();
        }
        let dst = ShardStore::create_temp("export-spill").unwrap();
        sb.export_to(&dst).unwrap();
        for (id, b) in &expected {
            assert_eq!(dst.read_block_verified(*id, 5).unwrap(), *b);
        }
        assert!(!sb.fully_resident(), "export left the source evicted");
    }

    #[test]
    fn backoff_is_jittered_bounded_and_deterministic() {
        for attempt in 1..MAX_IO_ATTEMPTS {
            let base = 2u64 << attempt;
            let mut seen = std::collections::HashSet::new();
            for seq in 0..64u64 {
                let ms = backoff_ms(0xDEAD_BEEF, attempt, seq);
                assert!(
                    (base..2 * base).contains(&ms),
                    "attempt {attempt} seq {seq}: {ms} outside [{base}, {})",
                    2 * base
                );
                // Pure function of its inputs: a rerun sleeps the same.
                assert_eq!(ms, backoff_ms(0xDEAD_BEEF, attempt, seq));
                seen.insert(ms);
            }
            assert!(seen.len() > 1, "attempt {attempt}: jitter never varied");
        }
        // Distinct stores decorrelate even at the same (attempt, seq).
        let spread: std::collections::HashSet<u64> =
            (0..64u64).map(|t| backoff_ms(t, 1, 0)).collect();
        assert!(spread.len() > 1, "token never moved the jitter");
    }

    #[cfg(feature = "failpoints")]
    mod fault_injection {
        use super::*;
        use crate::util::fault::{install, Fault, ANY};

        #[test]
        fn transient_read_faults_are_retried() {
            let store = ShardStore::create_temp("fp-read").unwrap();
            let b = block(40, 30);
            store.write_block(0xFA17_0001, &b, 0).unwrap();
            let _g = install(vec![Fault {
                site: "shard.read",
                key: [store.token, 0xFA17_0001, ANY],
                kind: FaultKind::IoError,
            }]);
            assert_eq!(store.read_block(0xFA17_0001).unwrap(), b);
            assert_eq!(store.io_retries(), 1, "one retry absorbed the fault");
        }

        #[test]
        fn torn_write_back_is_retried_to_success() {
            let store = ShardStore::create_temp("fp-torn").unwrap();
            let mut b = block(64, 31);
            store.write_block(7, &b, 0).unwrap();
            for z in &mut b.z {
                *z = (*z + 3) % 8;
            }
            let _g = install(vec![Fault {
                site: "shard.write_z",
                key: [store.token, 7, ANY],
                kind: FaultKind::TornWrite,
            }]);
            store.write_z(7, &b, 1).unwrap();
            assert_eq!(store.io_retries(), 1);
            let (r, stamp) = store.read_block_stamped(7).unwrap();
            assert_eq!(r.z, b.z, "the retry rewrote the full z section");
            assert_eq!(stamp, 1);
        }

        #[test]
        fn write_block_faults_are_retried() {
            let store = ShardStore::create_temp("fp-write").unwrap();
            let b = block(16, 32);
            let _g = install(vec![Fault {
                site: "shard.write_block",
                key: [store.token, 3, ANY],
                kind: FaultKind::IoError,
            }]);
            store.write_block(3, &b, 2).unwrap();
            assert_eq!(store.io_retries(), 1);
            assert_eq!(store.read_block_verified(3, 2).unwrap(), b);
        }

        #[test]
        fn a_persistent_fault_exhausts_the_retry_budget() {
            let store = ShardStore::create_temp("fp-budget").unwrap();
            store.write_block(9, &block(8, 33), 0).unwrap();
            let fault = Fault {
                site: "shard.read",
                key: [store.token, 9, ANY],
                kind: FaultKind::IoError,
            };
            let _g = install(vec![fault; MAX_IO_ATTEMPTS as usize]);
            let e = store.read_block(9).unwrap_err();
            assert!(matches!(e, BlockError::Io { .. }), "{e}");
            assert_eq!(store.io_retries(), u64::from(MAX_IO_ATTEMPTS) - 1);
            // The jittered backoff moves only the sleep, never the
            // count: an identical second burst costs the same budget.
            drop(_g);
            let _g = install(vec![fault; MAX_IO_ATTEMPTS as usize]);
            let e = store.read_block(9).unwrap_err();
            assert!(matches!(e, BlockError::Io { .. }), "{e}");
            assert_eq!(store.io_retries(), 2 * (u64::from(MAX_IO_ATTEMPTS) - 1));
        }
    }
}
