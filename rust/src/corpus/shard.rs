//! Out-of-core token-block storage: per-partition spill files with
//! overlapped prefetch and bounded resident memory.
//!
//! The partition grid is the natural sharding unit (CLDA-style:
//! partition-local state makes placement free), and the diagonal-epoch
//! barrier is the natural synchronization point. This module turns those
//! two facts into an out-of-core execution layer:
//!
//! * [`Residency`] — the policy knob. `InCore` keeps every [`TokenBlock`]
//!   in RAM (the historical behavior, still the default); `Spill` bounds
//!   resident token bytes to a budget, keeping roughly two diagonals
//!   resident (the one being sampled plus the prefetched next one).
//! * [`ShardStore`] — a run directory holding one file per partition
//!   (`part-<id>.blk`): magic + token count + sweep-stamp header, then
//!   the SoA `docs`/`words`/`z` arrays as little-endian `u32`s. Only `z`
//!   mutates during training, so write-back rewrites the `z` section in
//!   place (then commits the new sweep stamp).
//! * [`Prefetcher`] — a long-lived IO thread that loads the next
//!   diagonal's blocks while the executor samples the current one; the
//!   epoch barrier already sequences everything else, so the overlap
//!   costs one channel send per epoch.
//! * [`ShardedBlocks`] — the diagonal-major block container both parallel
//!   trainers own. In-core it is a plain `Vec<Vec<TokenBlock>>`; in spill
//!   mode it loads/evicts diagonals on demand, tracks resident bytes
//!   against the budget, and reports the peak for the memory-bound
//!   acceptance tests.
//!
//! # Determinism contract
//!
//! Spilled execution is bit-identical to in-core: blocks round-trip
//! through the store as exact `u32` arrays, task RNG streams are keyed by
//! `(sweep, partition)` (never by residency, worker, or IO timing), and
//! write-back happens after the barrier that already sequences count
//! merging. Residency is therefore a pure capacity/performance knob —
//! pinned by the spill ≡ in-core matrix tests in `scheduler/exec.rs`,
//! `bot/parallel.rs`, and `tests/integration_train.rs`. Because every
//! partition's full state (`docs`/`words`/`z`) persists in the store, a
//! re-opened store also supports crash-safe resume: counts are
//! reconstructed by re-absorbing the stored blocks (see
//! `ParallelLda::resume_spilled`), and each block carries the sweep
//! count it was written after, so resuming from a store a crash left
//! mid-sweep (mixed stamps) is rejected instead of silently training
//! from a state no uninterrupted run produces. The guarantee is scoped
//! to *process* kills: a kill inside one block's `z` rewrite (before
//! its stamp commits) is undetectable, and across a power loss the
//! page cache may write the stamp back before the data — closing those
//! windows would need per-block checksums or fsync'd
//! write-to-temp + rename, costs deliberately not paid on the
//! per-epoch hot path.
//!
//! See `docs/out_of_core.md` for the residency modes, the
//! prefetch/barrier overlap, and the write-back protocol.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use crate::gibbs::tokens::TokenBlock;
use crate::util::error::{bail, Context, Error, Result};

/// Where token blocks live during training.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Residency {
    /// Every block stays in RAM (the historical behavior; default).
    InCore,
    /// Blocks spill to a [`ShardStore`]; at most ~two diagonals are
    /// resident. `budget_bytes` bounds resident token bytes: prefetching
    /// the next diagonal is skipped whenever it would exceed the budget
    /// (0 = no bound — always keep current + next). The floor is one
    /// diagonal: the one being sampled must be resident.
    Spill { budget_bytes: u64 },
}

impl Residency {
    /// Parse a CLI/config spelling; `budget_bytes` applies to `spill`.
    pub fn parse(name: &str, budget_bytes: u64) -> Option<Self> {
        match name {
            "in-core" | "incore" | "ram" => Some(Self::InCore),
            "spill" | "out-of-core" | "ooc" => Some(Self::Spill { budget_bytes }),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Self::InCore => "in-core",
            Self::Spill { .. } => "spill",
        }
    }

    /// Human label including the budget, e.g. `spill(256.00MiB)`.
    pub fn label(self) -> String {
        match self {
            Self::InCore => "in-core".to_string(),
            Self::Spill { budget_bytes: 0 } => "spill".to_string(),
            Self::Spill { budget_bytes } => {
                format!("spill({})", crate::util::human_bytes(budget_bytes as usize))
            }
        }
    }
}

/// Parse a byte count with an optional `k`/`m`/`g` suffix (powers of
/// 1024, case-insensitive): `"512"`, `"64m"`, `"2G"`.
pub fn parse_bytes(s: &str) -> Option<u64> {
    let t = s.trim();
    let (digits, mult) = match t.char_indices().last()? {
        (i, 'k') | (i, 'K') => (&t[..i], 1u64 << 10),
        (i, 'm') | (i, 'M') => (&t[..i], 1u64 << 20),
        (i, 'g') | (i, 'G') => (&t[..i], 1u64 << 30),
        _ => (t, 1),
    };
    let n: u64 = digits.trim().parse().ok()?;
    n.checked_mul(mult)
}

/// Bytes one token occupies in a [`TokenBlock`]'s SoA arrays (doc + word
/// + z, each `u32`) — the unit of the resident-memory accounting and of
/// the on-disk format.
pub const BYTES_PER_TOKEN: u64 = 12;

const MAGIC: &[u8; 8] = b"PPSHARD2";
/// Header layout: magic (8) | token count `n` (u64 LE) | sweep stamp
/// (u64 LE) — the number of completed sweeps the block's `z` state
/// corresponds to.
const HEADER: u64 = 24;
const STAMP_OFFSET: u64 = 16;

/// A run directory of per-partition spill files.
///
/// Files are keyed by the grid-global partition id
/// ([`crate::scheduler::schedule::partition_id`]) and are independent of
/// each other, so concurrent access to *different* partitions (the
/// prefetch thread reading diagonal `l+1` while the coordinator writes
/// back diagonal `l`) needs no locking. Temp-created stores delete their
/// directory on drop; [`ShardStore::open`]ed (or [`ShardStore::keep`]t)
/// stores persist, which is what crash-safe resume builds on.
pub struct ShardStore {
    dir: PathBuf,
    keep: bool,
}

impl ShardStore {
    /// Create (or reuse) `dir` as a shard directory. The store deletes
    /// the directory on drop unless [`Self::keep`] is called.
    pub fn create(dir: impl Into<PathBuf>) -> Result<Self> {
        let dir = dir.into();
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("create shard dir {}", dir.display()))?;
        Ok(Self { dir, keep: false })
    }

    /// Create a uniquely-named store under `$PPLDA_SPILL_DIR` (or the
    /// system temp dir), tagged for debuggability.
    pub fn create_temp(tag: &str) -> Result<Self> {
        static COUNTER: AtomicU64 = AtomicU64::new(0);
        let root = std::env::var_os("PPLDA_SPILL_DIR")
            .map(PathBuf::from)
            .unwrap_or_else(std::env::temp_dir);
        let n = COUNTER.fetch_add(1, Ordering::Relaxed);
        Self::create(root.join(format!("pplda-shards-{}-{tag}-{n}", std::process::id())))
    }

    /// Open an existing shard directory (e.g. to resume after a crash).
    /// Opened stores never delete their files.
    pub fn open(dir: impl Into<PathBuf>) -> Result<Self> {
        let dir = dir.into();
        if !dir.is_dir() {
            bail!("shard dir {} does not exist", dir.display());
        }
        Ok(Self { dir, keep: true })
    }

    /// Keep the directory on drop (for resume / inspection).
    pub fn keep(&mut self) {
        self.keep = true;
    }

    pub fn path(&self) -> &Path {
        &self.dir
    }

    fn file(&self, id: u64) -> PathBuf {
        self.dir.join(format!("part-{id:08}.blk"))
    }

    /// Whether partition `id` has a spill file.
    pub fn has_block(&self, id: u64) -> bool {
        self.file(id).is_file()
    }

    /// Write a partition's full block (header + docs + words + z),
    /// stamped with the sweep count its `z` state corresponds to.
    pub fn write_block(&self, id: u64, block: &TokenBlock, stamp: u64) -> Result<()> {
        let n = block.len();
        let mut buf = Vec::with_capacity((HEADER + BYTES_PER_TOKEN * n as u64) as usize);
        buf.extend_from_slice(MAGIC);
        buf.extend_from_slice(&(n as u64).to_le_bytes());
        buf.extend_from_slice(&stamp.to_le_bytes());
        for arr in [&block.docs, &block.words, &block.z] {
            for &x in arr.iter() {
                buf.extend_from_slice(&x.to_le_bytes());
            }
        }
        let path = self.file(id);
        std::fs::write(&path, &buf)
            .with_context(|| format!("write shard {}", path.display()))?;
        Ok(())
    }

    /// Rewrite only the `z` section of partition `id`'s file in place —
    /// the write-back path (docs/words never change after init) — then
    /// commit the new sweep stamp. Stamp-after-data ordering keeps the
    /// mid-*process-kill* window to a partially-written `z` section
    /// whose stale stamp a resume will reject; across a *system* crash
    /// the page cache may reorder the two writes, so power-loss
    /// durability would additionally need a `sync_data` between them
    /// (deliberately not paid on the per-epoch hot path — see
    /// `docs/out_of_core.md`).
    pub fn write_z(&self, id: u64, block: &TokenBlock, stamp: u64) -> Result<()> {
        use std::io::{Seek, SeekFrom, Write};
        let n = block.len() as u64;
        let path = self.file(id);
        let mut f = std::fs::OpenOptions::new()
            .write(true)
            .open(&path)
            .with_context(|| format!("open shard {} for write-back", path.display()))?;
        f.seek(SeekFrom::Start(HEADER + 8 * n))
            .with_context(|| format!("seek shard {}", path.display()))?;
        let mut buf = Vec::with_capacity(4 * block.len());
        for &x in &block.z {
            buf.extend_from_slice(&x.to_le_bytes());
        }
        f.write_all(&buf)
            .with_context(|| format!("write back shard {}", path.display()))?;
        f.seek(SeekFrom::Start(STAMP_OFFSET))
            .with_context(|| format!("seek shard {}", path.display()))?;
        f.write_all(&stamp.to_le_bytes())
            .with_context(|| format!("stamp shard {}", path.display()))?;
        Ok(())
    }

    /// Load partition `id`'s block, validating the header.
    pub fn read_block(&self, id: u64) -> Result<TokenBlock> {
        Ok(self.read_block_stamped(id)?.0)
    }

    /// Load partition `id`'s block and verify its sweep stamp — the one
    /// copy of the resume-validation rule (a mixed-stamp store was left
    /// mid-sweep by a kill and cannot be resumed bit-identically).
    pub fn read_block_verified(&self, id: u64, expected_stamp: u64) -> Result<TokenBlock> {
        let (b, stamp) = self.read_block_stamped(id)?;
        if stamp != expected_stamp {
            bail!(
                "partition {id}: sweep stamp {stamp} != expected {expected_stamp} \
                 (store was left mid-sweep or belongs to a different run)"
            );
        }
        Ok(b)
    }

    /// Load partition `id`'s block plus its sweep stamp — the resume
    /// path, which must verify every block is from the same sweep.
    pub fn read_block_stamped(&self, id: u64) -> Result<(TokenBlock, u64)> {
        let path = self.file(id);
        let bytes = std::fs::read(&path)
            .with_context(|| format!("read shard {}", path.display()))?;
        if bytes.len() < HEADER as usize || &bytes[..8] != MAGIC {
            bail!("shard {}: bad header", path.display());
        }
        let n = u64::from_le_bytes(bytes[8..16].try_into().unwrap()) as usize;
        let stamp = u64::from_le_bytes(bytes[16..24].try_into().unwrap());
        if bytes.len() as u64 != HEADER + BYTES_PER_TOKEN * n as u64 {
            bail!(
                "shard {}: {} bytes for {n} tokens (truncated or corrupt)",
                path.display(),
                bytes.len()
            );
        }
        let h = HEADER as usize;
        let mut block = TokenBlock::with_capacity(n);
        read_u32s(&bytes[h..h + 4 * n], &mut block.docs);
        read_u32s(&bytes[h + 4 * n..h + 8 * n], &mut block.words);
        read_u32s(&bytes[h + 8 * n..h + 12 * n], &mut block.z);
        Ok((block, stamp))
    }
}

fn read_u32s(bytes: &[u8], out: &mut Vec<u32>) {
    for c in bytes.chunks_exact(4) {
        out.push(u32::from_le_bytes(c.try_into().unwrap()));
    }
}

impl Drop for ShardStore {
    fn drop(&mut self) {
        if !self.keep {
            let _ = std::fs::remove_dir_all(&self.dir);
        }
    }
}

/// The overlapped-load IO thread: one long-lived worker that reads a
/// requested id list from the store and hands the blocks back over a
/// channel. At most one request is in flight; the trainer issues it just
/// before dispatching an epoch and collects it at (or after) the epoch
/// barrier, so the load overlaps sampling.
pub struct Prefetcher {
    tx: Option<Sender<Vec<u64>>>,
    rx: Receiver<Result<Vec<TokenBlock>>>,
    handle: Option<JoinHandle<()>>,
}

impl Prefetcher {
    pub fn new(store: Arc<ShardStore>) -> Self {
        let (tx, req_rx) = channel::<Vec<u64>>();
        let (res_tx, rx) = channel();
        let handle = std::thread::spawn(move || {
            while let Ok(ids) = req_rx.recv() {
                let mut out = Vec::with_capacity(ids.len());
                let mut failed = None;
                for id in ids {
                    match store.read_block(id) {
                        Ok(b) => out.push(b),
                        Err(e) => {
                            failed = Some(e);
                            break;
                        }
                    }
                }
                let msg = match failed {
                    None => Ok(out),
                    Some(e) => Err(e),
                };
                if res_tx.send(msg).is_err() {
                    break; // trainer gone
                }
            }
        });
        Self {
            tx: Some(tx),
            rx,
            handle: Some(handle),
        }
    }

    /// Start loading `ids`. The caller must collect the previous request
    /// with [`Self::take`] first (enforced by [`ShardedBlocks`]).
    pub fn request(&mut self, ids: Vec<u64>) {
        self.tx
            .as_ref()
            .expect("prefetcher shut down")
            .send(ids)
            .expect("prefetcher thread died");
    }

    /// Block until the in-flight request completes and return its blocks.
    pub fn take(&mut self) -> Result<Vec<TokenBlock>> {
        self.rx
            .recv()
            .map_err(|_| Error::msg("prefetcher thread died"))?
    }
}

impl Drop for Prefetcher {
    fn drop(&mut self) {
        self.tx.take(); // close the request channel; the worker exits
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Diagonal-major token blocks under a residency policy — the block
/// container both parallel trainers own.
///
/// The per-sweep protocol (spill mode; everything is a no-op in-core):
///
/// ```text
/// for l in 0..P {
///     acquire(l)            // sync load, or collect the prefetch
///     prefetch((l+1) % P)   // overlapped with the epoch below
///     run_epoch(l); merge barrier
///     release(l)            // write back z, evict
/// }
/// ```
///
/// Resident-byte accounting counts a prefetched diagonal from the moment
/// its request is issued (the IO thread holds the blocks while the
/// current diagonal is still resident), so `peak_resident_bytes` is an
/// honest peak and `prefetch` can gate on the budget before starting.
pub struct ShardedBlocks {
    // Field order matters for Drop: join the prefetcher (which holds an
    // `Arc<ShardStore>` clone) before the store can delete its directory.
    prefetcher: Option<Prefetcher>,
    store: Option<Arc<ShardStore>>,
    /// `blocks[l]` — diagonal `l`'s blocks; empty when non-resident.
    blocks: Vec<Vec<TokenBlock>>,
    /// Global partition ids, parallel to `blocks` (survive eviction).
    ids: Vec<Vec<u64>>,
    /// Token bytes per diagonal (12 bytes/token; survive eviction).
    diag_bytes: Vec<u64>,
    resident: Vec<bool>,
    residency: Residency,
    /// Diagonal index of the in-flight prefetch, if any.
    pending: Option<usize>,
    /// Sweep stamp written with every block (see [`Self::set_stamp`]).
    stamp: u64,
    resident_bytes: u64,
    peak_resident_bytes: u64,
}

impl ShardedBlocks {
    /// All blocks stay in RAM (the historical behavior).
    pub fn in_core() -> Self {
        Self {
            prefetcher: None,
            store: None,
            blocks: Vec::new(),
            ids: Vec::new(),
            diag_bytes: Vec::new(),
            resident: Vec::new(),
            residency: Residency::InCore,
            pending: None,
            stamp: 0,
            resident_bytes: 0,
            peak_resident_bytes: 0,
        }
    }

    /// Blocks spill to `store`; see [`Residency::Spill`] for the budget
    /// semantics.
    pub fn spill(store: ShardStore, budget_bytes: u64) -> Self {
        let store = Arc::new(store);
        Self {
            prefetcher: Some(Prefetcher::new(Arc::clone(&store))),
            store: Some(store),
            blocks: Vec::new(),
            ids: Vec::new(),
            diag_bytes: Vec::new(),
            resident: Vec::new(),
            residency: Residency::Spill { budget_bytes },
            pending: None,
            stamp: 0,
            resident_bytes: 0,
            peak_resident_bytes: 0,
        }
    }

    pub fn residency(&self) -> Residency {
        self.residency
    }

    /// Set the sweep stamp subsequent writes carry: the number of
    /// completed sweeps the written `z` state corresponds to (0 at
    /// init). Trainers set `sweep_no + 1` before each sweep, so an
    /// at-rest store has every block uniformly stamped and a resume can
    /// verify it is not mid-sweep.
    pub fn set_stamp(&mut self, stamp: u64) {
        self.stamp = stamp;
    }

    /// Number of diagonals pushed so far (== the grid size `P` once
    /// initialization finishes).
    pub fn p(&self) -> usize {
        self.blocks.len()
    }

    fn bump_resident(&mut self, bytes: u64) {
        self.resident_bytes += bytes;
        self.peak_resident_bytes = self.peak_resident_bytes.max(self.resident_bytes);
    }

    /// Append one diagonal during initialization. In-core the blocks are
    /// kept; in spill mode they are written to the store and dropped, so
    /// init peak memory stays at roughly one diagonal. The caller has
    /// already absorbed the blocks into its count matrices.
    pub fn push_diagonal(&mut self, diag: Vec<TokenBlock>, ids: Vec<u64>) -> Result<()> {
        assert_eq!(diag.len(), ids.len(), "one id per block");
        let bytes: u64 = diag.iter().map(TokenBlock::heap_bytes).sum();
        self.diag_bytes.push(bytes);
        match self.residency {
            Residency::InCore => {
                self.resident.push(true);
                self.bump_resident(bytes);
                self.blocks.push(diag);
            }
            Residency::Spill { .. } => {
                let store = self.store.as_ref().expect("spill store");
                for (b, &id) in diag.iter().zip(&ids) {
                    store.write_block(id, b, self.stamp)?;
                }
                self.resident.push(false);
                self.blocks.push(Vec::new());
            }
        }
        self.ids.push(ids);
        Ok(())
    }

    /// Append one diagonal whose blocks already live in the store (the
    /// resume path): each block is read, verified against
    /// `expected_stamp` (a mixed-stamp store was left mid-sweep by a
    /// crash and cannot be resumed bit-identically), shown to `visit`
    /// (count re-absorption), then kept or dropped per the residency.
    pub fn adopt_diagonal(
        &mut self,
        ids: Vec<u64>,
        expected_stamp: u64,
        mut visit: impl FnMut(&TokenBlock),
    ) -> Result<()> {
        let store = self.store.as_ref().expect("adopt_diagonal needs a store");
        let mut diag = Vec::with_capacity(ids.len());
        for &id in &ids {
            let b = store.read_block_verified(id, expected_stamp)?;
            visit(&b);
            diag.push(b);
        }
        let bytes: u64 = diag.iter().map(TokenBlock::heap_bytes).sum();
        self.diag_bytes.push(bytes);
        match self.residency {
            Residency::InCore => {
                self.resident.push(true);
                self.bump_resident(bytes);
                self.blocks.push(diag);
            }
            Residency::Spill { .. } => {
                self.resident.push(false);
                self.blocks.push(Vec::new());
            }
        }
        self.ids.push(ids);
        Ok(())
    }

    /// Make diagonal `l` resident: collect the in-flight prefetch if it
    /// targets `l`, otherwise load synchronously. Returns the seconds the
    /// caller stalled on IO (0 in-core, ≈0 when the prefetch finished
    /// under the sampling it overlapped).
    pub fn acquire(&mut self, l: usize) -> Result<f64> {
        if self.resident[l] {
            return Ok(0.0);
        }
        let started = Instant::now();
        if let Some(t) = self.pending.take() {
            let taken = self
                .prefetcher
                .as_mut()
                .expect("pending prefetch without a prefetcher")
                .take();
            let blocks = match taken {
                Ok(blocks) => blocks,
                Err(e) => {
                    // The response is consumed and the reservation void
                    // either way — never leave `pending` set on failure,
                    // or a retry would block on a reply that already
                    // arrived.
                    self.resident_bytes -= self.diag_bytes[t];
                    return Err(e);
                }
            };
            if t == l {
                self.blocks[l] = blocks;
                self.resident[l] = true; // bytes were counted at request
                return Ok(started.elapsed().as_secs_f64());
            }
            // A stale prefetch (schedule changed under us): the blocks
            // are clean copies of the store — discard and fall through.
            self.resident_bytes -= self.diag_bytes[t];
        }
        let store = self.store.as_ref().expect("non-resident diagonal without a store");
        let mut diag = Vec::with_capacity(self.ids[l].len());
        for &id in &self.ids[l] {
            diag.push(store.read_block(id)?);
        }
        self.blocks[l] = diag;
        self.resident[l] = true;
        self.bump_resident(self.diag_bytes[l]);
        Ok(started.elapsed().as_secs_f64())
    }

    /// Begin loading diagonal `t` on the IO thread, if the residency,
    /// budget, and in-flight state allow. The reserved bytes count as
    /// resident from this moment (the IO thread holds them).
    pub fn prefetch(&mut self, t: usize) {
        let Some(pf) = self.prefetcher.as_mut() else {
            return; // in-core, or the prefetcher was retired by keep_store
        };
        if self.resident[t] || self.pending.is_some() {
            return;
        }
        let budget = match self.residency {
            Residency::InCore => unreachable!("in-core has no prefetcher"),
            Residency::Spill { budget_bytes } => budget_bytes,
        };
        if budget > 0 && self.resident_bytes + self.diag_bytes[t] > budget {
            return; // over budget: acquire() will load synchronously
        }
        pf.request(self.ids[t].clone());
        self.pending = Some(t);
        self.bump_resident(self.diag_bytes[t]);
    }

    /// Write back diagonal `l`'s (dirty) `z` arrays and evict it. Called
    /// after the epoch barrier, so all sampling of `l` has completed.
    /// Returns the seconds spent on write-back IO (0 in-core).
    pub fn release(&mut self, l: usize) -> Result<f64> {
        if self.residency == Residency::InCore || !self.resident[l] {
            return Ok(0.0);
        }
        let started = Instant::now();
        let store = self.store.as_ref().expect("spill store");
        for (b, &id) in self.blocks[l].iter().zip(&self.ids[l]) {
            store.write_z(id, b, self.stamp)?;
        }
        self.blocks[l] = Vec::new();
        self.resident[l] = false;
        self.resident_bytes -= self.diag_bytes[l];
        Ok(started.elapsed().as_secs_f64())
    }

    /// Diagonal `l`'s blocks and ids (must be resident; see
    /// [`Self::acquire`]).
    pub fn diag_parts(&mut self, l: usize) -> (&mut [TokenBlock], &[u64]) {
        assert!(self.resident[l], "diagonal {l} is not resident");
        (&mut self.blocks[l], &self.ids[l])
    }

    /// Every diagonal is resident (always true in-core) — the
    /// precondition for whole-corpus consistency audits.
    pub fn fully_resident(&self) -> bool {
        self.resident.iter().all(|&r| r)
    }

    /// All currently-resident blocks, flattened (the whole corpus
    /// in-core).
    pub fn resident_blocks(&self) -> Vec<&TokenBlock> {
        self.blocks
            .iter()
            .zip(&self.resident)
            .filter(|(_, &r)| r)
            .flat_map(|(diag, _)| diag.iter())
            .collect()
    }

    /// Currently-resident token bytes (including in-flight prefetches).
    pub fn resident_bytes(&self) -> u64 {
        self.resident_bytes
    }

    /// High-water mark of [`Self::resident_bytes`] over the container's
    /// lifetime — what the memory-budget acceptance tests assert on.
    pub fn peak_resident_bytes(&self) -> u64 {
        self.peak_resident_bytes
    }

    /// Total token bytes across all diagonals (resident or not).
    pub fn total_bytes(&self) -> u64 {
        self.diag_bytes.iter().sum()
    }

    /// The spill directory, if this container spills.
    pub fn store_path(&self) -> Option<&Path> {
        self.store.as_deref().map(ShardStore::path)
    }

    /// Keep the spill directory on drop (resume / inspection). Retires
    /// the prefetch thread (it holds the other `Arc` clone of the
    /// store); subsequent sweeps fall back to synchronous loads.
    pub fn keep_store(&mut self) {
        if self.store.is_some() {
            if let Some(t) = self.pending.take() {
                // Collect (and discard) any in-flight load first.
                if let Some(pf) = self.prefetcher.as_mut() {
                    let _ = pf.take();
                }
                self.resident_bytes -= self.diag_bytes[t];
            }
            self.prefetcher = None; // joins the IO thread
            let store = self.store.as_mut().unwrap();
            Arc::get_mut(store)
                .expect("prefetcher joined; the store is uniquely owned")
                .keep();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn block(n: usize, seed: u64) -> TokenBlock {
        let mut rng = Rng::new(seed);
        let mut b = TokenBlock::with_capacity(n);
        for i in 0..n {
            b.docs.push(i as u32);
            b.words.push(rng.gen_range(50) as u32);
            b.z.push(rng.gen_range(8) as u32);
        }
        b
    }

    #[test]
    fn store_roundtrips_blocks_exactly() {
        let store = ShardStore::create_temp("roundtrip").unwrap();
        let b = block(1000, 1);
        store.write_block(7, &b, 0).unwrap();
        assert!(store.has_block(7));
        assert!(!store.has_block(8));
        let r = store.read_block(7).unwrap();
        assert_eq!(b.docs, r.docs);
        assert_eq!(b.words, r.words);
        assert_eq!(b.z, r.z);
    }

    #[test]
    fn write_z_rewrites_only_assignments() {
        let store = ShardStore::create_temp("writez").unwrap();
        let mut b = block(256, 2);
        store.write_block(0, &b, 0).unwrap();
        assert_eq!(store.read_block_stamped(0).unwrap().1, 0);
        for z in &mut b.z {
            *z = (*z + 1) % 8;
        }
        store.write_z(0, &b, 3).unwrap();
        let (r, stamp) = store.read_block_stamped(0).unwrap();
        assert_eq!(b.z, r.z, "z section rewritten");
        assert_eq!(b.docs, r.docs, "docs untouched");
        assert_eq!(b.words, r.words, "words untouched");
        assert_eq!(stamp, 3, "write-back commits the new sweep stamp");
    }

    #[test]
    fn reopened_store_sees_identical_state() {
        // The crash-safety primitive: drop the store (kept), reopen the
        // directory, read back bit-identical blocks.
        let dir = {
            let mut store = ShardStore::create_temp("reopen").unwrap();
            store.write_block(3, &block(100, 3), 2).unwrap();
            store.keep();
            store.path().to_path_buf()
        };
        assert!(dir.is_dir(), "kept store survives drop");
        let store = ShardStore::open(&dir).unwrap();
        let (b, stamp) = store.read_block_stamped(3).unwrap();
        assert_eq!(b, block(100, 3));
        assert_eq!(stamp, 2, "sweep stamp survives reopen");
        drop(store); // opened stores never delete
        assert!(dir.is_dir());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn temp_store_cleans_up_on_drop() {
        let dir = {
            let store = ShardStore::create_temp("cleanup").unwrap();
            store.write_block(0, &block(10, 4), 0).unwrap();
            store.path().to_path_buf()
        };
        assert!(!dir.exists(), "temp store removed its directory");
    }

    #[test]
    fn read_rejects_corrupt_files() {
        let store = ShardStore::create_temp("corrupt").unwrap();
        store.write_block(0, &block(10, 5), 0).unwrap();
        // Truncate the file below its declared token count.
        let path = store.path().join("part-00000000.blk");
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 4]).unwrap();
        let e = store.read_block(0).unwrap_err().to_string();
        assert!(e.contains("truncated"), "{e}");
        std::fs::write(&path, b"garbage!").unwrap();
        let e = store.read_block(0).unwrap_err().to_string();
        assert!(e.contains("bad header"), "{e}");
        assert!(store.read_block(99).is_err(), "missing file errors");
    }

    #[test]
    fn prefetcher_loads_in_background() {
        let store = Arc::new(ShardStore::create_temp("prefetch").unwrap());
        let (b0, b1) = (block(50, 6), block(70, 7));
        store.write_block(0, &b0, 0).unwrap();
        store.write_block(1, &b1, 0).unwrap();
        let mut pf = Prefetcher::new(Arc::clone(&store));
        pf.request(vec![1, 0]);
        let got = pf.take().unwrap();
        assert_eq!(got.len(), 2);
        assert_eq!(got[0], b1, "requested order preserved");
        assert_eq!(got[1], b0);
        pf.request(vec![42]);
        assert!(pf.take().is_err(), "missing block surfaces as an error");
    }

    #[test]
    fn parse_bytes_suffixes() {
        assert_eq!(parse_bytes("512"), Some(512));
        assert_eq!(parse_bytes("4k"), Some(4096));
        assert_eq!(parse_bytes("64M"), Some(64 << 20));
        assert_eq!(parse_bytes("2g"), Some(2 << 30));
        assert_eq!(parse_bytes(" 8m "), Some(8 << 20));
        assert_eq!(parse_bytes("x"), None);
        assert_eq!(parse_bytes(""), None);
    }

    #[test]
    fn residency_parses_and_labels() {
        assert_eq!(Residency::parse("in-core", 0), Some(Residency::InCore));
        assert_eq!(
            Residency::parse("spill", 64),
            Some(Residency::Spill { budget_bytes: 64 })
        );
        assert_eq!(Residency::parse("ooc", 0), Some(Residency::Spill { budget_bytes: 0 }));
        assert_eq!(Residency::parse("disk", 0), None);
        assert_eq!(Residency::InCore.label(), "in-core");
        assert_eq!(Residency::Spill { budget_bytes: 0 }.label(), "spill");
        assert_eq!(
            Residency::Spill { budget_bytes: 64 << 20 }.label(),
            "spill(64.00MiB)"
        );
        assert_eq!(Residency::Spill { budget_bytes: 1 }.name(), "spill");
    }

    fn two_diagonals() -> (Vec<Vec<TokenBlock>>, Vec<Vec<u64>>) {
        (
            vec![vec![block(100, 10), block(60, 11)], vec![block(80, 12), block(40, 13)]],
            vec![vec![0, 3], vec![1, 2]],
        )
    }

    #[test]
    fn in_core_container_is_always_resident() {
        let (diags, ids) = two_diagonals();
        let mut sb = ShardedBlocks::in_core();
        for (d, i) in diags.into_iter().zip(ids) {
            sb.push_diagonal(d, i).unwrap();
        }
        assert!(sb.fully_resident());
        assert_eq!(sb.resident_blocks().len(), 4);
        assert_eq!(sb.total_bytes(), 280 * BYTES_PER_TOKEN);
        assert_eq!(sb.peak_resident_bytes(), sb.total_bytes());
        assert_eq!(sb.acquire(0).unwrap(), 0.0);
        sb.prefetch(1); // no-op
        assert_eq!(sb.release(0).unwrap(), 0.0);
        assert!(sb.fully_resident(), "in-core release never evicts");
        let (blocks, pids) = sb.diag_parts(1);
        assert_eq!(blocks.len(), 2);
        assert_eq!(pids, &[1, 2]);
    }

    #[test]
    fn spill_container_bounds_residency_and_roundtrips() {
        let (diags, ids) = two_diagonals();
        let store = ShardStore::create_temp("sharded").unwrap();
        // Budget = both diagonals: prefetch allowed.
        let mut sb = ShardedBlocks::spill(store, 280 * BYTES_PER_TOKEN);
        for (d, i) in diags.into_iter().zip(ids) {
            sb.push_diagonal(d, i).unwrap();
        }
        assert!(!sb.fully_resident());
        assert_eq!(sb.resident_bytes(), 0, "init leaves nothing resident");

        // Sweep protocol: acquire 0, prefetch 1, mutate, release 0,
        // acquire 1 (collects the prefetch).
        sb.acquire(0).unwrap();
        assert_eq!(sb.resident_bytes(), 160 * BYTES_PER_TOKEN);
        sb.prefetch(1);
        assert_eq!(
            sb.resident_bytes(),
            280 * BYTES_PER_TOKEN,
            "prefetched bytes count from request time"
        );
        {
            let (blocks, _) = sb.diag_parts(0);
            for b in blocks.iter_mut() {
                for z in &mut b.z {
                    *z = 7;
                }
            }
        }
        sb.release(0).unwrap();
        assert_eq!(sb.resident_bytes(), 120 * BYTES_PER_TOKEN);
        sb.acquire(1).unwrap();
        let (blocks, _) = sb.diag_parts(1);
        assert_eq!(blocks[0], block(80, 12), "diagonal 1 round-tripped");
        sb.release(1).unwrap();
        assert_eq!(sb.resident_bytes(), 0);

        // The write-back persisted: re-acquire diagonal 0 and see z=7.
        sb.acquire(0).unwrap();
        let (blocks, _) = sb.diag_parts(0);
        assert!(blocks.iter().all(|b| b.z.iter().all(|&z| z == 7)));
        assert_eq!(sb.peak_resident_bytes(), 280 * BYTES_PER_TOKEN);
    }

    #[test]
    fn prefetch_respects_the_budget() {
        let (diags, ids) = two_diagonals();
        let store = ShardStore::create_temp("budget").unwrap();
        // Budget covers only the largest single diagonal (160 tokens):
        // prefetching while one is resident must be declined, and the
        // peak must stay within the budget.
        let budget = 160 * BYTES_PER_TOKEN;
        let mut sb = ShardedBlocks::spill(store, budget);
        for (d, i) in diags.into_iter().zip(ids) {
            sb.push_diagonal(d, i).unwrap();
        }
        for _ in 0..2 {
            for l in 0..2 {
                sb.acquire(l).unwrap();
                sb.prefetch((l + 1) % 2);
                sb.release(l).unwrap();
            }
        }
        assert!(
            sb.peak_resident_bytes() <= budget,
            "peak {} exceeded budget {budget}",
            sb.peak_resident_bytes()
        );
    }

    #[test]
    fn adopt_revisits_stored_blocks() {
        let store = ShardStore::create_temp("adopt").unwrap();
        let b = block(30, 20);
        store.write_block(5, &b, 4).unwrap();
        let mut sb = ShardedBlocks::spill(store, 0);
        let mut seen = 0u64;
        sb.adopt_diagonal(vec![5], 4, |blk| {
            seen += blk.len() as u64;
            assert_eq!(*blk, b);
        })
        .unwrap();
        assert_eq!(seen, 30);
        // A mismatched stamp (mid-sweep store) is refused.
        let e = sb.adopt_diagonal(vec![5], 9, |_| {}).unwrap_err().to_string();
        assert!(e.contains("sweep stamp 4"), "{e}");
        sb.acquire(0).unwrap();
        let (blocks, pids) = sb.diag_parts(0);
        assert_eq!(blocks[0], b);
        assert_eq!(pids, &[5]);
    }
}
