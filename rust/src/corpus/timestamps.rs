//! Timestamped corpora for Bag of Timestamps (paper §IV-C).
//!
//! BoT attaches to each document `j` a timestamp array `TS_j` of length
//! `L` whose entries are treated like words drawn from the document's
//! topic mixture. The document–timestamp matrix `DTS` therefore gets its
//! own workload matrix `R'` (rows = documents, columns = timestamps) and
//! is partitioned with exactly the same algorithms as `DW`.

use crate::corpus::bow::{BagOfWords, Entry};
use crate::util::rng::Rng;

/// A corpus plus its timestamp side: `bow` is the DW source matrix,
/// `dts` the document–timestamp matrix R' (one row per document,
/// `num_stamps` columns).
#[derive(Clone, Debug)]
pub struct TimestampedCorpus {
    pub bow: BagOfWords,
    /// Document–timestamp counts R' (each row sums to L).
    pub dts: BagOfWords,
    /// Year index (0-based from first year) per document.
    pub doc_year: Vec<u32>,
    pub num_stamps: usize,
}

/// Attach a timestamp side to a corpus: each document gets `l` timestamp
/// tokens centred on its year with ±1 jitter (clipped), modelling the
/// citation-era smearing Masada et al. use.
pub fn attach(
    bow: BagOfWords,
    doc_year: Vec<u32>,
    num_stamps: usize,
    l: usize,
    rng: &mut Rng,
) -> TimestampedCorpus {
    assert_eq!(doc_year.len(), bow.num_docs());
    assert!(num_stamps > 0 && l > 0);

    let rows: Vec<Vec<Entry>> = doc_year
        .iter()
        .map(|&year| {
            let mut counts = [0u32; 3]; // year-1, year, year+1
            for _ in 0..l {
                let r = rng.f64();
                // 70% exact year, 15% either neighbour.
                let off = if r < 0.70 {
                    1
                } else if r < 0.85 {
                    0
                } else {
                    2
                };
                let stamp = (year as i64 + off as i64 - 1)
                    .clamp(0, num_stamps as i64 - 1) as usize;
                counts[(stamp as i64 - year as i64 + 1).clamp(0, 2) as usize] += 1;
                let _ = stamp;
            }
            let mut row = Vec::new();
            for (i, &c) in counts.iter().enumerate() {
                if c == 0 {
                    continue;
                }
                let stamp =
                    (year as i64 + i as i64 - 1).clamp(0, num_stamps as i64 - 1) as u32;
                row.push(Entry {
                    word: stamp,
                    count: c,
                });
            }
            row
        })
        .collect();

    let dts = BagOfWords::from_rows(num_stamps, rows);
    TimestampedCorpus {
        bow,
        dts,
        doc_year,
        num_stamps,
    }
}

impl TimestampedCorpus {
    /// Total sampled tokens per sweep: words + timestamps.
    pub fn total_tokens(&self) -> u64 {
        self.bow.num_tokens() + self.dts.num_tokens()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::bow::BagOfWords;

    fn tiny_bow(docs: usize) -> BagOfWords {
        BagOfWords::from_triplets(
            docs,
            8,
            (0..docs as u32).map(|d| (d, d % 8, 2)),
        )
    }

    #[test]
    fn every_doc_gets_l_stamps() {
        let bow = tiny_bow(50);
        let years: Vec<u32> = (0..50).map(|d| (d % 10) as u32).collect();
        let mut rng = Rng::new(1);
        let tc = attach(bow, years, 10, 16, &mut rng);
        assert!(tc.dts.row_sums().iter().all(|&r| r == 16));
        assert_eq!(tc.dts.num_tokens(), 50 * 16);
    }

    #[test]
    fn stamps_stay_in_range_at_boundaries() {
        let bow = tiny_bow(20);
        // All docs in year 0 and year max: jitter must clip.
        let years: Vec<u32> = (0..20).map(|d| if d < 10 { 0 } else { 4 }).collect();
        let mut rng = Rng::new(2);
        let tc = attach(bow, years, 5, 8, &mut rng);
        for j in 0..20 {
            for e in tc.dts.doc(j) {
                assert!(e.word < 5);
            }
        }
    }

    #[test]
    fn mass_concentrates_on_doc_year() {
        let bow = tiny_bow(200);
        let years = vec![5u32; 200];
        let mut rng = Rng::new(3);
        let tc = attach(bow, years, 11, 16, &mut rng);
        let on_year = tc.dts.col_sum(5) as f64;
        let total = tc.dts.num_tokens() as f64;
        assert!(on_year / total > 0.6, "on-year share {}", on_year / total);
    }

    #[test]
    fn total_tokens_adds_both_sides() {
        let bow = tiny_bow(10);
        let n_words = bow.num_tokens();
        let mut rng = Rng::new(4);
        let tc = attach(bow, vec![0; 10], 3, 4, &mut rng);
        assert_eq!(tc.total_tokens(), n_words + 40);
    }
}
