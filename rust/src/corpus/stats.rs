//! Corpus statistics — reproduces the rows of the paper's Table I and adds
//! the skew measures that drive partitioning difficulty.

use crate::corpus::bow::BagOfWords;
use crate::corpus::timestamps::TimestampedCorpus;
use crate::util::stats::gini;
use crate::util::tsv::Table;

#[derive(Clone, Debug)]
pub struct CorpusStats {
    pub name: String,
    pub docs: usize,
    /// Vocabulary size (matrix width W).
    pub words: usize,
    /// Words with nonzero corpus frequency.
    pub words_used: usize,
    /// Token count N.
    pub tokens: u64,
    pub nnz: usize,
    pub mean_doc_len: f64,
    pub row_gini: f64,
    pub col_gini: f64,
    /// Timestamp columns (BoT corpora only).
    pub stamps: Option<usize>,
    pub stamp_tokens: Option<u64>,
}

impl CorpusStats {
    pub fn of(name: &str, bow: &BagOfWords) -> Self {
        let rows: Vec<f64> = bow.row_sums().iter().map(|&x| x as f64).collect();
        let cols: Vec<f64> = bow.col_sums().iter().map(|&x| x as f64).collect();
        Self {
            name: name.to_string(),
            docs: bow.num_docs(),
            words: bow.num_words(),
            words_used: bow.vocab_used(),
            tokens: bow.num_tokens(),
            nnz: bow.nnz(),
            mean_doc_len: bow.num_tokens() as f64 / bow.num_docs().max(1) as f64,
            row_gini: gini(&rows),
            col_gini: gini(&cols),
            stamps: None,
            stamp_tokens: None,
        }
    }

    pub fn of_timestamped(name: &str, tc: &TimestampedCorpus) -> Self {
        let mut s = Self::of(name, &tc.bow);
        s.stamps = Some(tc.num_stamps);
        s.stamp_tokens = Some(tc.dts.num_tokens());
        s
    }
}

/// Render a Table-I-style table for a set of corpora.
pub fn table_i(stats: &[CorpusStats]) -> Table {
    let mut header = vec!["Dataset".to_string()];
    header.extend(stats.iter().map(|s| s.name.clone()));
    let mut t = Table::new(header);

    let row = |label: &str, f: &dyn Fn(&CorpusStats) -> String| {
        let mut cells = vec![label.to_string()];
        cells.extend(stats.iter().map(|s| f(s)));
        cells
    };
    t.row(row("Documents, D", &|s| s.docs.to_string()));
    t.row(row("Unique words, W", &|s| s.words.to_string()));
    t.row(row("Words used", &|s| s.words_used.to_string()));
    t.row(row("Word instances, N", &|s| s.tokens.to_string()));
    t.row(row("Nonzero cells", &|s| s.nnz.to_string()));
    t.row(row("Mean doc length", &|s| format!("{:.1}", s.mean_doc_len)));
    t.row(row("Row gini", &|s| format!("{:.3}", s.row_gini)));
    t.row(row("Col gini", &|s| format!("{:.3}", s.col_gini)));
    t.row(row("Unique timestamps", &|s| {
        s.stamps.map(|v| v.to_string()).unwrap_or_else(|| "N/A".into())
    }));
    t.row(row("Timestamp tokens", &|s| {
        s.stamp_tokens
            .map(|v| v.to_string())
            .unwrap_or_else(|| "N/A".into())
    }));
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::bow::BagOfWords;

    #[test]
    fn stats_basic() {
        let b = BagOfWords::from_triplets(2, 3, [(0, 0, 4), (1, 1, 2)]);
        let s = CorpusStats::of("t", &b);
        assert_eq!(s.docs, 2);
        assert_eq!(s.words, 3);
        assert_eq!(s.words_used, 2);
        assert_eq!(s.tokens, 6);
        assert_eq!(s.nnz, 2);
        assert!((s.mean_doc_len - 3.0).abs() < 1e-12);
        assert!(s.stamps.is_none());
    }

    #[test]
    fn table_renders_all_rows() {
        let b = BagOfWords::from_triplets(2, 3, [(0, 0, 4), (1, 1, 2)]);
        let t = table_i(&[CorpusStats::of("x", &b)]);
        assert_eq!(t.num_rows(), 10);
        let s = t.to_aligned();
        assert!(s.contains("Documents, D"));
        assert!(s.contains("N/A"));
    }
}
