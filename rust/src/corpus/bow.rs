//! Sparse bag-of-words matrix in CSR-over-documents form.
//!
//! This is the document–word count matrix `R = (r_jw)` of the paper's
//! §III-B: `entry(j, w) = r_jw`, row workloads `RR_j = Σ_w r_jw` (document
//! lengths in tokens) and column workloads `CR_w = Σ_j r_jw` (corpus-wide
//! word frequencies). The same structure doubles as the document–timestamp
//! matrix `R'` for BoT, with timestamps in place of words.

/// One (word, count) cell of a document row.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Entry {
    pub word: u32,
    pub count: u32,
}

#[derive(Clone, Debug)]
pub struct BagOfWords {
    num_words: usize,
    /// CSR row pointers, length `num_docs + 1`.
    doc_offsets: Vec<usize>,
    /// Entries of all rows, each row sorted by word id, counts > 0.
    entries: Vec<Entry>,
    /// Column workloads `CR_w` (token count of word w across the corpus).
    col_sums: Vec<u64>,
    /// Row workloads `RR_j` (token length of document j).
    row_sums: Vec<u64>,
    /// Total token count `N`.
    num_tokens: u64,
}

impl BagOfWords {
    /// Build from (doc, word, count) triplets. Triplets may repeat
    /// (counts are summed) and arrive in any order. Zero counts are
    /// dropped.
    pub fn from_triplets(
        num_docs: usize,
        num_words: usize,
        triplets: impl IntoIterator<Item = (u32, u32, u32)>,
    ) -> Self {
        let mut rows: Vec<Vec<Entry>> = vec![Vec::new(); num_docs];
        for (d, w, c) in triplets {
            assert!((d as usize) < num_docs, "doc id {d} out of range");
            assert!((w as usize) < num_words, "word id {w} out of range");
            if c > 0 {
                rows[d as usize].push(Entry { word: w, count: c });
            }
        }
        Self::from_rows(num_words, rows)
    }

    /// Build directly from triplets already sorted by `(doc, word)` with
    /// no duplicate cells — the low-peak-memory path the UCI loader
    /// streams through. Unlike [`Self::from_triplets`] this never
    /// materializes per-document rows (`Vec<Vec<Entry>>`): the CSR
    /// arrays are laid down in one pass and the triplet buffer is the
    /// only transient, so load peak is ~20 bytes per nonzero instead of
    /// holding every entry twice (plus per-row allocation overhead).
    /// Zero counts are dropped; unsorted or duplicate input panics.
    pub fn from_sorted_triplets(
        num_docs: usize,
        num_words: usize,
        triplets: Vec<(u32, u32, u32)>,
    ) -> Self {
        let mut doc_offsets = Vec::with_capacity(num_docs + 1);
        let mut entries = Vec::with_capacity(triplets.len());
        let mut col_sums = vec![0u64; num_words];
        let mut row_sums = vec![0u64; num_docs];
        let mut num_tokens = 0u64;
        doc_offsets.push(0);
        let mut cur_doc = 0usize;
        let mut prev: Option<(u32, u32)> = None;
        for &(d, w, c) in &triplets {
            assert!((d as usize) < num_docs, "doc id {d} out of range");
            assert!((w as usize) < num_words, "word id {w} out of range");
            if let Some(p) = prev {
                assert!(p < (d, w), "triplets must be strictly sorted by (doc, word)");
            }
            prev = Some((d, w));
            while cur_doc < d as usize {
                doc_offsets.push(entries.len());
                cur_doc += 1;
            }
            if c > 0 {
                entries.push(Entry { word: w, count: c });
                col_sums[w as usize] += c as u64;
                row_sums[d as usize] += c as u64;
                num_tokens += c as u64;
            }
        }
        drop(triplets);
        while cur_doc < num_docs {
            doc_offsets.push(entries.len());
            cur_doc += 1;
        }
        Self {
            num_words,
            doc_offsets,
            entries,
            col_sums,
            row_sums,
            num_tokens,
        }
    }

    /// Build from per-document entry lists (any order within a row;
    /// duplicates summed).
    pub fn from_rows(num_words: usize, mut rows: Vec<Vec<Entry>>) -> Self {
        let mut doc_offsets = Vec::with_capacity(rows.len() + 1);
        let mut entries = Vec::new();
        let mut col_sums = vec![0u64; num_words];
        let mut row_sums = Vec::with_capacity(rows.len());
        let mut num_tokens = 0u64;

        doc_offsets.push(0);
        for row in &mut rows {
            row.sort_unstable_by_key(|e| e.word);
            let mut row_sum = 0u64;
            let mut i = 0;
            while i < row.len() {
                let word = row[i].word;
                let mut count = 0u64;
                while i < row.len() && row[i].word == word {
                    count += row[i].count as u64;
                    i += 1;
                }
                if count > 0 {
                    entries.push(Entry {
                        word,
                        count: u32::try_from(count).expect("cell count overflows u32"),
                    });
                    col_sums[word as usize] += count;
                    row_sum += count;
                }
            }
            row_sums.push(row_sum);
            num_tokens += row_sum;
            doc_offsets.push(entries.len());
        }

        Self {
            num_words,
            doc_offsets,
            entries,
            col_sums,
            row_sums,
            num_tokens,
        }
    }

    #[inline]
    pub fn num_docs(&self) -> usize {
        self.doc_offsets.len() - 1
    }

    #[inline]
    pub fn num_words(&self) -> usize {
        self.num_words
    }

    #[inline]
    pub fn num_tokens(&self) -> u64 {
        self.num_tokens
    }

    /// Number of nonzero cells.
    #[inline]
    pub fn nnz(&self) -> usize {
        self.entries.len()
    }

    /// Entries of document `j`, sorted by word id.
    #[inline]
    pub fn doc(&self, j: usize) -> &[Entry] {
        &self.entries[self.doc_offsets[j]..self.doc_offsets[j + 1]]
    }

    /// Row workload `RR_j` — token length of document j.
    #[inline]
    pub fn row_sum(&self, j: usize) -> u64 {
        self.row_sums[j]
    }

    /// Column workload `CR_w` — corpus frequency of word w.
    #[inline]
    pub fn col_sum(&self, w: usize) -> u64 {
        self.col_sums[w]
    }

    pub fn row_sums(&self) -> &[u64] {
        &self.row_sums
    }

    pub fn col_sums(&self) -> &[u64] {
        &self.col_sums
    }

    /// Number of words with nonzero corpus frequency.
    pub fn vocab_used(&self) -> usize {
        self.col_sums.iter().filter(|&&c| c > 0).count()
    }

    /// Expand document `j` into a token list (word repeated `count`
    /// times) — the unit the Gibbs sampler walks.
    pub fn doc_tokens(&self, j: usize) -> impl Iterator<Item = u32> + '_ {
        self.doc(j)
            .iter()
            .flat_map(|e| std::iter::repeat(e.word).take(e.count as usize))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> BagOfWords {
        // doc0: w0×2, w2×1; doc1: empty; doc2: w1×3
        BagOfWords::from_triplets(3, 4, [(0, 0, 2), (0, 2, 1), (2, 1, 3)])
    }

    #[test]
    fn shape_and_sums() {
        let b = sample();
        assert_eq!(b.num_docs(), 3);
        assert_eq!(b.num_words(), 4);
        assert_eq!(b.num_tokens(), 6);
        assert_eq!(b.nnz(), 3);
        assert_eq!(b.row_sums(), &[3, 0, 3]);
        assert_eq!(b.col_sums(), &[2, 3, 1, 0]);
        assert_eq!(b.vocab_used(), 3);
    }

    #[test]
    fn rows_sorted_and_deduped() {
        let b = BagOfWords::from_triplets(1, 5, [(0, 3, 1), (0, 1, 2), (0, 3, 4)]);
        let row = b.doc(0);
        assert_eq!(row.len(), 2);
        assert_eq!(row[0], Entry { word: 1, count: 2 });
        assert_eq!(row[1], Entry { word: 3, count: 5 });
    }

    #[test]
    fn zero_counts_dropped() {
        let b = BagOfWords::from_triplets(1, 2, [(0, 0, 0), (0, 1, 1)]);
        assert_eq!(b.nnz(), 1);
        assert_eq!(b.num_tokens(), 1);
    }

    #[test]
    fn empty_doc_ok() {
        let b = sample();
        assert!(b.doc(1).is_empty());
        assert_eq!(b.row_sum(1), 0);
    }

    #[test]
    fn doc_tokens_expand_counts() {
        let b = sample();
        let toks: Vec<u32> = b.doc_tokens(0).collect();
        assert_eq!(toks, vec![0, 0, 2]);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn out_of_range_word_panics() {
        BagOfWords::from_triplets(1, 2, [(0, 5, 1)]);
    }

    #[test]
    fn sorted_triplets_match_general_construction() {
        // The streaming path must produce the exact structure the
        // general path does, including empty leading/trailing docs.
        let trips = vec![(1u32, 0u32, 2u32), (1, 3, 1), (3, 1, 4)];
        let a = BagOfWords::from_sorted_triplets(5, 4, trips.clone());
        let b = BagOfWords::from_triplets(5, 4, trips);
        assert_eq!(a.num_docs(), 5);
        assert_eq!(a.num_tokens(), b.num_tokens());
        assert_eq!(a.nnz(), b.nnz());
        assert_eq!(a.row_sums(), b.row_sums());
        assert_eq!(a.col_sums(), b.col_sums());
        for j in 0..5 {
            assert_eq!(a.doc(j), b.doc(j), "doc {j}");
        }
        assert!(a.doc(0).is_empty());
        assert!(a.doc(4).is_empty());
    }

    #[test]
    fn sorted_triplets_drop_zero_counts() {
        let b = BagOfWords::from_sorted_triplets(2, 2, vec![(0, 0, 0), (1, 1, 3)]);
        assert_eq!(b.nnz(), 1);
        assert_eq!(b.num_tokens(), 3);
        assert_eq!(b.row_sum(0), 0);
    }

    #[test]
    #[should_panic(expected = "strictly sorted")]
    fn unsorted_triplets_panic() {
        BagOfWords::from_sorted_triplets(2, 2, vec![(1, 0, 1), (0, 0, 1)]);
    }

    #[test]
    #[should_panic(expected = "strictly sorted")]
    fn duplicate_sorted_triplets_panic() {
        BagOfWords::from_sorted_triplets(1, 2, vec![(0, 1, 1), (0, 1, 2)]);
    }
}
