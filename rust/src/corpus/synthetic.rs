//! Synthetic corpus generation with marginals matched to the paper's
//! datasets (Table I).
//!
//! Generation follows the LDA generative process itself — per-topic word
//! distributions with a Zipf base measure, per-document topic mixtures,
//! lognormal document lengths — because the *difficulty* of the paper's
//! load-balancing problem is exactly the skew of the row workloads
//! (document lengths) and column workloads (word frequencies) of `R`.
//! Matching those marginals reproduces the experimental conditions of
//! Tables II/III without the original UCI files; dropping the real files
//! in via [`crate::corpus::uci`] requires no other change.

use crate::corpus::bow::{BagOfWords, Entry};
use crate::corpus::timestamps::{self, TimestampedCorpus};
use crate::util::alias::AliasTable;
use crate::util::rng::Rng;

/// Generator configuration. `Profile` constructors encode the paper's
/// datasets; all knobs are public for custom corpora.
#[derive(Clone, Debug)]
pub struct Profile {
    pub name: String,
    pub num_docs: usize,
    pub vocab: usize,
    /// Target total token count N (matched in expectation).
    pub num_tokens: u64,
    /// Latent topic count of the *generator* (not the trained model).
    pub gen_topics: usize,
    /// Dirichlet concentration of per-document topic mixtures.
    pub doc_alpha: f64,
    /// Zipf exponent of the vocabulary base measure (~1 for natural text).
    pub zipf_s: f64,
    /// Zipf rank shift: models stop-word removal (the paper's datasets
    /// have stop words removed), flattening the head so the top word
    /// carries ≈0.5–1% of tokens instead of ≈10%.
    pub zipf_shift: f64,
    /// Topic-word Dirichlet concentration multiplier (smaller = spikier
    /// topics).
    pub topic_conc: f64,
    /// Lognormal sigma of document lengths (0 = all equal).
    pub len_sigma: f64,
    /// Timestamp configuration; `None` for plain LDA corpora.
    pub time: Option<TimeProfile>,
}

/// Publication-year model for BoT corpora (paper's MAS dataset).
#[derive(Clone, Debug)]
pub struct TimeProfile {
    pub first_year: u32,
    pub last_year: u32,
    /// Exponential growth rate of documents per year (CS publication
    /// volume roughly doubles every ~9 years → g ≈ 0.08).
    pub growth: f64,
    /// Timestamp array length L per document (paper §V-C: L = 16).
    pub stamps_per_doc: usize,
}

impl Profile {
    /// NIPS (Table I): D=1500, W=12419, N=1,932,365.
    pub fn nips_like() -> Self {
        Self {
            name: "nips-like".into(),
            num_docs: 1500,
            vocab: 12_419,
            num_tokens: 1_932_365,
            gen_topics: 32,
            doc_alpha: 0.2,
            zipf_s: 1.05,
            zipf_shift: 25.0,
            topic_conc: 0.05,
            len_sigma: 0.55,
            time: None,
        }
    }

    /// NYTimes (Table I): D=300,000, W=102,660, N=99,542,125.
    pub fn nytimes_like() -> Self {
        Self {
            name: "nytimes-like".into(),
            num_docs: 300_000,
            vocab: 102_660,
            num_tokens: 99_542_125,
            gen_topics: 64,
            doc_alpha: 0.15,
            zipf_s: 1.05,
            zipf_shift: 30.0,
            topic_conc: 0.02,
            len_sigma: 0.45,
            time: None,
        }
    }

    /// MAS (Table I): D=1,182,744, W=402,252 (stemmed), N=92,531,014,
    /// years 1951–2010 (WTS=60), L=16.
    pub fn mas_like() -> Self {
        Self {
            name: "mas-like".into(),
            num_docs: 1_182_744,
            vocab: 402_252,
            num_tokens: 92_531_014,
            gen_topics: 64,
            doc_alpha: 0.15,
            zipf_s: 1.08,
            zipf_shift: 30.0,
            topic_conc: 0.02,
            len_sigma: 0.35, // title+abstract lengths vary less than articles
            time: Some(TimeProfile {
                first_year: 1951,
                last_year: 2010,
                growth: 0.08,
                stamps_per_doc: 16,
            }),
        }
    }

    /// Tiny corpus for unit tests and doc examples.
    pub fn tiny() -> Self {
        Self {
            name: "tiny".into(),
            num_docs: 60,
            vocab: 200,
            num_tokens: 6_000,
            gen_topics: 4,
            doc_alpha: 0.3,
            zipf_s: 1.0,
            zipf_shift: 5.0,
            topic_conc: 0.1,
            len_sigma: 0.5,
            time: None,
        }
    }

    /// Divide document and token counts by `factor` (vocabulary is kept —
    /// subsampled corpora retain most of their vocabulary, and zero-mass
    /// columns stress the partitioners the way rare words do). Vocab is
    /// capped at N/4 to keep the matrix meaningfully dense.
    pub fn scaled(mut self, factor: usize) -> Self {
        assert!(factor >= 1);
        if factor == 1 {
            return self;
        }
        self.name = format!("{}/{}", self.name, factor);
        self.num_docs = (self.num_docs / factor).max(1);
        self.num_tokens = (self.num_tokens / factor as u64).max(1);
        self.vocab = self.vocab.min((self.num_tokens / 4).max(16) as usize);
        self
    }

    fn mean_doc_len(&self) -> f64 {
        self.num_tokens as f64 / self.num_docs as f64
    }
}

/// Generate a plain bag-of-words corpus from a profile.
pub fn generate(profile: &Profile, seed: u64) -> BagOfWords {
    let mut rng = Rng::stream(seed, 0xC0FFEE);
    let topics = build_topic_tables(profile, &mut rng);

    let k = profile.gen_topics;
    let mut theta = vec![0.0f64; k];
    let mut rows: Vec<Vec<Entry>> = Vec::with_capacity(profile.num_docs);
    let mut scratch: Vec<u32> = Vec::new();

    // Lognormal length with mean matched to N/D:
    // E[lognormal(mu, sigma)] = exp(mu + sigma^2/2)  ⇒  mu = ln(mean) - s²/2.
    let sigma = profile.len_sigma;
    let mu = profile.mean_doc_len().max(1.0).ln() - sigma * sigma / 2.0;

    for _ in 0..profile.num_docs {
        rng.dirichlet_sym(profile.doc_alpha, &mut theta);
        let len = (mu + sigma * rng.normal()).exp().round().max(1.0) as usize;

        scratch.clear();
        for _ in 0..len {
            // Cat(theta) by linear CDF walk: K is small (≤64) and theta
            // changes per document, so alias construction wouldn't pay.
            let topic = rng.categorical(&theta);
            let word = topics[topic].sample(&mut rng) as u32;
            scratch.push(word);
        }
        scratch.sort_unstable();
        let mut row: Vec<Entry> = Vec::new();
        let mut i = 0;
        while i < scratch.len() {
            let w = scratch[i];
            let mut c = 0u32;
            while i < scratch.len() && scratch[i] == w {
                c += 1;
                i += 1;
            }
            row.push(Entry { word: w, count: c });
        }
        rows.push(row);
    }

    BagOfWords::from_rows(profile.vocab, rows)
}

/// Generate a timestamped corpus (BoT experiments). Panics if the profile
/// carries no [`TimeProfile`].
pub fn generate_timestamped(profile: &Profile, seed: u64) -> TimestampedCorpus {
    let time = profile
        .time
        .clone()
        .unwrap_or_else(|| panic!("profile {:?} has no time model", profile.name));
    let bow = generate(profile, seed);
    let mut rng = Rng::stream(seed, 0x7E4A);

    let num_years = (time.last_year - time.first_year + 1) as usize;
    // Documents-per-year follows the exponential growth curve.
    let year_weights: Vec<f64> = (0..num_years)
        .map(|y| (time.growth * y as f64).exp())
        .collect();
    let year_table = AliasTable::new(&year_weights);

    let years: Vec<u32> = (0..bow.num_docs())
        .map(|_| year_table.sample(&mut rng) as u32)
        .collect();

    timestamps::attach(bow, years, num_years, time.stamps_per_doc, &mut rng)
}

fn build_topic_tables(profile: &Profile, rng: &mut Rng) -> Vec<AliasTable> {
    // Base measure: shifted Zipf over a randomly permuted vocabulary (so
    // topic supports overlap on frequent words, as in natural text). The
    // shift flattens the head the way stop-word removal does in the
    // paper's preprocessed datasets.
    let w = profile.vocab;
    let mut rank: Vec<u32> = (0..w as u32).collect();
    rng.shuffle(&mut rank);
    let base: Vec<f64> = {
        let mut b = vec![0.0; w];
        for (r, &word) in rank.iter().enumerate() {
            b[word as usize] =
                1.0 / ((r + 1) as f64 + profile.zipf_shift).powf(profile.zipf_s);
        }
        b
    };

    (0..profile.gen_topics)
        .map(|_| {
            // phi_k ~ Dirichlet(conc·W·base): standard gamma-normalize
            // construction (normalization is implicit in AliasTable). The
            // expectation of phi_k is the Zipf base measure; small
            // concentrations make individual topics spiky around it.
            let weights: Vec<f64> = base
                .iter()
                .map(|&b| {
                    let shape = (profile.topic_conc * b * w as f64).max(1e-4);
                    rng.gamma(shape).max(1e-300)
                })
                .collect();
            AliasTable::new(&weights)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::gini;

    #[test]
    fn tiny_matches_targets_in_expectation() {
        let p = Profile::tiny();
        let bow = generate(&p, 1);
        assert_eq!(bow.num_docs(), p.num_docs);
        assert_eq!(bow.num_words(), p.vocab);
        let n = bow.num_tokens() as f64;
        let target = p.num_tokens as f64;
        assert!(
            (n - target).abs() / target < 0.30,
            "tokens {n} vs target {target}"
        );
    }

    #[test]
    fn deterministic_per_seed() {
        let p = Profile::tiny();
        let a = generate(&p, 9);
        let b = generate(&p, 9);
        assert_eq!(a.num_tokens(), b.num_tokens());
        assert_eq!(a.doc(0), b.doc(0));
        let c = generate(&p, 10);
        assert_ne!(a.num_tokens(), c.num_tokens());
    }

    #[test]
    fn word_marginal_is_heavy_tailed() {
        let p = Profile::nips_like().scaled(20);
        let bow = generate(&p, 5);
        let cols: Vec<f64> = bow.col_sums().iter().map(|&c| c as f64).collect();
        let g = gini(&cols);
        // Natural-text word frequencies have Gini well above 0.6.
        assert!(g > 0.6, "column gini {g}");
    }

    #[test]
    fn doc_lengths_are_skewed() {
        let p = Profile::nips_like().scaled(20);
        let bow = generate(&p, 5);
        let rows: Vec<f64> = bow.row_sums().iter().map(|&c| c as f64).collect();
        let g = gini(&rows);
        assert!(g > 0.15, "row gini {g}"); // lognormal sigma .55 ⇒ gini ≈ .3
    }

    #[test]
    fn scaled_profile_shrinks() {
        let p = Profile::nytimes_like().scaled(100);
        assert_eq!(p.num_docs, 3000);
        assert!(p.vocab <= 102_660);
        assert_eq!(p.num_tokens, 995_421);
    }

    #[test]
    fn timestamped_corpus_shapes() {
        let mut p = Profile::tiny();
        p.time = Some(TimeProfile {
            first_year: 2000,
            last_year: 2009,
            growth: 0.1,
            stamps_per_doc: 4,
        });
        let tc = generate_timestamped(&p, 2);
        assert_eq!(tc.bow.num_docs(), p.num_docs);
        assert_eq!(tc.num_stamps, 10);
        assert_eq!(tc.dts.num_docs(), p.num_docs);
        assert_eq!(tc.dts.num_words(), 10);
        // Every doc carries exactly L timestamp tokens.
        assert!(tc.dts.row_sums().iter().all(|&r| r == 4));
    }

    #[test]
    fn growth_curve_skews_years() {
        let mut p = Profile::tiny();
        p.num_docs = 2000;
        p.time = Some(TimeProfile {
            first_year: 1951,
            last_year: 2010,
            growth: 0.08,
            stamps_per_doc: 2,
        });
        let tc = generate_timestamped(&p, 3);
        // Last decade must hold far more documents than the first.
        let first_decade: u64 = (0..10).map(|y| tc.dts.col_sum(y)).sum();
        let last_decade: u64 = (50..60).map(|y| tc.dts.col_sum(y)).sum();
        assert!(
            last_decade > 10 * first_decade.max(1),
            "first={first_decade} last={last_decade}"
        );
    }
}
