//! The diagonal-epoch parallel execution engine (Yan et al.'s algorithm,
//! the substrate the paper's partitioners feed).
//!
//! A Gibbs sweep runs as `P` *epochs*; epoch `l` samples the `P`
//! partitions of diagonal `l` in parallel. Which *worker* samples which
//! partition is decided by a [`schedule::Schedule`]: the legacy
//! [`schedule::ScheduleKind::Diagonal`] mapping pins one worker per
//! partition (`W == P`), while [`schedule::ScheduleKind::Packed`]
//! over-decomposes the grid (`P = g·W`) and LPT-packs each diagonal's
//! partitions onto `W` workers — decoupling the partition grid from the
//! core count (see `docs/scheduling.md`).
//!
//! Within an epoch tasks own disjoint document rows of `Cθ` and disjoint
//! emission rows of `Cφ` ([`shared::SharedRows`] hands out raw row
//! pointers under that invariant); the topic totals `n_k` are read from
//! an epoch-start snapshot with per-task deltas merged at the barrier.
//!
//! Because task RNG streams are keyed by `(sweep, partition)` and not by
//! worker or thread interleaving, all execution modes, schedules, and
//! worker counts produce *identical* assignments for the same plan —
//! sequential mode is both the determinism oracle for tests and the
//! low-overhead mode for single-core boxes.
//!
//! Epochs run through the [`pool::Executor`] abstraction: in-order
//! ([`pool::SequentialExec`]), per-epoch scoped threads
//! ([`pool::ThreadedExec`]), or the persistent [`pool::WorkerPool`] with
//! long-lived per-worker scratch (see `docs/executor.md`).
//!
//! On top of the static schedule sits the cost-aware adaptive layer
//! ([`adaptive`]): per-task wallclock telemetry feeds a measured
//! per-partition cost estimator, which can re-pack each diagonal between
//! sweeps ([`adaptive::BalanceMode::Adaptive`]) or be bypassed entirely
//! by within-epoch work stealing ([`adaptive::BalanceMode::Steal`]) —
//! both bit-identical to static execution, by the same RNG-keying
//! argument (see `docs/scheduling.md`).

pub mod adaptive;
pub mod cost_model;
pub mod exec;
pub mod pool;
pub mod schedule;
pub mod shared;

pub use adaptive::{BalanceMode, CostEstimator, Measured, TokenCount};
pub use exec::{ExecMode, ParallelLda};
pub use pool::{Executor, WorkerPool};
pub use schedule::{Schedule, ScheduleKind};
