//! The diagonal-epoch parallel execution engine (Yan et al.'s algorithm,
//! the substrate the paper's partitioners feed).
//!
//! A Gibbs sweep runs as `P` *epochs*; epoch `l` samples the `P`
//! partitions of diagonal `l` in parallel, one worker per partition.
//! Within an epoch workers own disjoint document rows of `Cθ` and
//! disjoint word rows of `Cφ` ([`shared::SharedRows`] hands out raw row
//! pointers under that invariant); the topic totals `n_k` are read from
//! an epoch-start snapshot with per-worker deltas merged at the barrier.
//!
//! Because worker RNG streams are keyed by (sweep, epoch, partition) and
//! not by thread interleaving, all execution modes produce *identical*
//! assignments — sequential mode is both the determinism oracle for
//! tests and the low-overhead mode for single-core boxes.
//!
//! Epochs run through the [`pool::Executor`] abstraction: in-order
//! ([`pool::SequentialExec`]), legacy per-epoch scoped threads
//! ([`pool::ThreadedExec`]), or the persistent [`pool::WorkerPool`] with
//! long-lived per-worker scratch (see `docs/executor.md`).

pub mod cost_model;
pub mod exec;
pub mod pool;
pub mod shared;

pub use exec::{ExecMode, ParallelLda};
pub use pool::{Executor, WorkerPool};
