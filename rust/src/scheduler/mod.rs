//! The diagonal-epoch parallel execution engine (Yan et al.'s algorithm,
//! the substrate the paper's partitioners feed).
//!
//! A Gibbs sweep runs as `P` *epochs*; epoch `l` samples the `P`
//! partitions of diagonal `l` in parallel, one worker per partition.
//! Within an epoch workers own disjoint document rows of `Cθ` and
//! disjoint word rows of `Cφ` ([`shared::SharedRows`] hands out raw row
//! pointers under that invariant); the topic totals `n_k` are read from
//! an epoch-start snapshot with per-worker deltas merged at the barrier.
//!
//! Because worker RNG streams are keyed by (sweep, epoch, partition) and
//! not by thread interleaving, threaded and sequential execution produce
//! *identical* assignments — sequential mode is both the determinism
//! oracle for tests and the low-overhead mode for single-core boxes.

pub mod cost_model;
pub mod exec;
pub mod shared;

pub use exec::{ExecMode, ParallelLda};
