//! Persistent worker-pool execution of diagonal epochs.
//!
//! The legacy engine re-spawned `P` OS threads per epoch with
//! `std::thread::scope` — `P²` spawns per sweep — and allocated a fresh
//! topic-delta vector, probability buffer, and reciprocal cache for each
//! worker each epoch. That fixed overhead is exactly what the paper's
//! speedup measurements must *not* contain (it measures partition
//! quality, not thread-spawn latency), and what CLDA-style long-lived
//! workers avoid.
//!
//! This module provides the shared execution abstraction:
//!
//! * [`EpochSpec`] — everything one diagonal epoch needs: shared count
//!   matrices, the epoch-start topic snapshot, hyperparameters, and the
//!   RNG keying coordinates `(seed, sweep)`.
//! * [`EpochTasks`] — the epoch's token blocks, their global partition
//!   ids, and the schedule's per-worker *task lists* over them. Under the
//!   diagonal schedule every worker holds exactly one task; under the
//!   packed schedule (see [`crate::scheduler::schedule`]) a worker may
//!   run several partitions per epoch.
//! * [`Executor`] — the trait both trainers (`ParallelLda`, the BoT
//!   trainer) drive; one call runs one diagonal epoch.
//! * [`SequentialExec`] — in-order on the calling thread (the
//!   determinism oracle), with its own reusable scratch.
//! * [`ThreadedExec`] — scoped-spawn execution (one thread per busy
//!   worker slot), kept as a baseline for the executor-overhead
//!   benchmark.
//! * [`WorkerPool`] — the persistent pool: `W` dedicated workers created
//!   once per trainer, each owning a long-lived sampling kernel (and
//!   thereby its scratch), driven by a scatter/gather barrier over
//!   channels.
//!
//! # Barrier protocol
//!
//! Each worker has a private job channel (SPSC in practice); the
//! coordinator shares one completion channel. An epoch is:
//!
//! 1. **Scatter** — the coordinator sends each worker with a non-empty
//!    task list one lifetime-erased [`Job`] describing the epoch's block
//!    array plus that worker's index list into it.
//! 2. **Sample** — the worker walks its list (or, in work-stealing mode,
//!    claims tasks from the epoch's shared atomic cursor until it is
//!    exhausted — see [`EpochTasks::steal`]); for each task it zeroes the
//!    task's delta slot, derives the task's RNG stream, runs the
//!    selected sampling kernel ([`crate::kernel`]) — a long-lived,
//!    worker-owned instance whose scratch persists across epochs — and
//!    stamps the task's measured sweep nanos into its telemetry slot.
//! 3. **Gather** — the coordinator blocks until it has received exactly
//!    one completion per submitted job. Only then does it merge deltas
//!    and advance, so every raw pointer inside a `Job` outlives its use.
//!
//! The gather barrier doubles as the out-of-core synchronization point:
//! in spill mode the trainer issues the next diagonal's load on the
//! prefetch thread just before scattering this epoch's jobs and collects
//! it after the gather, so disk IO overlaps the sample stage without any
//! additional coordination (see [`crate::corpus::shard`] and
//! `docs/out_of_core.md`).
//!
//! # Ticketed protocol
//!
//! [`Executor::run_epoch_ticketed`] replaces the gather barrier with a
//! pipelined in-order commit: each task's index is its *ticket*, workers
//! report per-task completions as they finish, and the coordinator folds
//! the contiguous prefix of ready deltas through the caller's `commit`
//! callback in strict ticket order — overlapped with the sampling tail
//! instead of serialized after it. A contained panic *revokes* the
//! ticket (the watermark stalls there until the retry re-executes the
//! task), so commit order — and therefore the result — is exactly the
//! barrier path's, bit for bit. See `docs/executor.md` § Ticketed
//! commit.
//!
//! # Determinism
//!
//! Task RNG streams are keyed by `(seed, sweep, partition)` via
//! [`task_rng`] — a pure function of the *partition identity*, never of
//! the worker that runs it, the epoch position, or thread interleaving —
//! and delta merging is integer addition (commutative), so all executors
//! produce bit-identical counts on any worker count under any schedule
//! of the same plan. The `pooled_equals_sequential` and packed-schedule
//! determinism tests in `exec.rs` / `bot/parallel.rs` pin this.
//!
//! # Fault containment
//!
//! Every executor runs tasks under a panic guard ([`run_task_guarded`]):
//! a panicking task is rolled back (shared count rows, `z` assignments,
//! delta — exactly as if it had never started) and re-executed with a
//! fresh kernel, up to [`MAX_TASK_ATTEMPTS`] attempts. Because the
//! retry derives the same `(seed, sweep, partition)` RNG stream, a
//! contained-and-retried run is bit-identical to an undisturbed one.
//! The pool additionally tracks contained panics per worker and
//! quarantines repeat offenders ([`QUARANTINE_PANICS`]): the suspect
//! thread (and any kernel scratch the panics may have torn) is replaced
//! by a fresh one in the same slot. See `docs/fault_tolerance.md`.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::gibbs::sampler::Hyper;
use crate::gibbs::tokens::TokenBlock;
use crate::kernel::{Kernel, KernelKind, TaskCtx};
use crate::obs::trace::{Event, EventKind, Tracer};
use crate::scheduler::exec::ExecMode;
use crate::scheduler::shared::SharedRows;
use crate::util::fault;
use crate::util::rng::Rng;

/// Per-task execution budget: the first attempt plus retries after
/// contained panics. Exhausting it propagates the failure ("giving up")
/// instead of looping on a deterministic crash.
pub const MAX_TASK_ATTEMPTS: u32 = 3;

/// Contained panics before the pool quarantines a worker: its thread is
/// replaced by a fresh one in the same slot (see [`WorkerPool`]).
pub const QUARANTINE_PANICS: u64 = 3;

/// The deterministic RNG stream for one partition's sweep. Identical
/// across executors, schedules, and worker counts — this is the
/// determinism anchor. `partition` is the grid-global partition id
/// ([`crate::scheduler::schedule::partition_id`]).
#[inline]
pub fn task_rng(seed: u64, sweep: usize, partition: u64) -> Rng {
    Rng::stream(seed, ((sweep as u64) << 32) | partition)
}

/// One diagonal epoch's inputs, shared by every worker of the epoch.
///
/// `doc` rows are grouped by document partition, `emit` rows by the
/// emission-side partition (words for LDA and the BoT word phase,
/// timestamps for the BoT timestamp phase). `snapshot` is the
/// epoch-start view of the `k` topic totals backing `emit`.
pub struct EpochSpec<'a> {
    pub doc: SharedRows<'a>,
    pub emit: SharedRows<'a>,
    pub snapshot: &'a [u32],
    pub h: Hyper,
    /// Trainer/phase-salted RNG seed (see [`task_rng`]).
    pub seed: u64,
    pub sweep: usize,
    /// Which sampling kernel runs the per-token hot path (see
    /// [`crate::kernel`]). Every executor worker owns a long-lived
    /// kernel instance of this kind, rebuilt only when the kind
    /// changes, so kernel scratch persists across epochs and sweeps.
    pub kernel: KernelKind,
    /// Observability context (see [`TaskObs`]); `Default` = tracing off.
    pub obs: TaskObs<'a>,
}

/// Observability context threaded through [`EpochSpec`]: an optional
/// trace recorder plus the trace coordinates the spec does not already
/// carry. Strictly observational — executors only *emit* through it, so
/// results are bit-identical with tracing on or off. The default
/// (`trace: None`) is the zero-cost path: per task, one `Option` test
/// on an already-loaded field.
#[derive(Clone, Copy, Default)]
pub struct TaskObs<'a> {
    /// Trace recorder, or `None` for the zero-cost path.
    pub trace: Option<&'a Tracer>,
    /// Diagonal epoch index within the sweep (a trace coordinate;
    /// execution never reads it).
    pub epoch: u32,
    /// Phase family: 0 = word (LDA and BoT word phase), 1 = BoT stamp.
    pub family: u8,
}

/// Emit one successful task's span — the single emission point shared
/// by every executor path, so a trace covers each scheduled task
/// exactly once (ticket = the task's index within its epoch, the
/// commit order). `dt` is the same measured duration the task's
/// `nanos` telemetry slot receives, so an analyzer recomputing
/// measured-η from spans reproduces `SweepStats::measured_eta`. A
/// stolen task additionally gets a [`EventKind::Steal`] marker.
#[inline]
pub(crate) fn trace_task(
    spec: &EpochSpec<'_>,
    lane: usize,
    ticket: usize,
    partition: u64,
    dt: u64,
    stolen: bool,
) {
    let Some(tr) = spec.obs.trace else { return };
    let ev = Event {
        kind: EventKind::Task,
        family: spec.obs.family,
        lane: lane as u16,
        sweep: spec.sweep as u32,
        epoch: spec.obs.epoch,
        ticket: ticket as u32,
        partition,
        t0_ns: tr.now().saturating_sub(dt),
        dur_ns: dt,
        arg: stolen as u64,
    };
    tr.emit(ev);
    if stolen {
        tr.emit(Event { kind: EventKind::Steal, dur_ns: 0, arg: dt, ..ev });
    }
}

/// Emit an instant event (rollback/retry) on `lane` with task
/// coordinates. No-op when tracing is off.
#[inline]
pub(crate) fn trace_instant(
    spec: &EpochSpec<'_>,
    lane: usize,
    kind: EventKind,
    ticket: usize,
    partition: u64,
    arg: u64,
) {
    let Some(tr) = spec.obs.trace else { return };
    tr.emit(Event {
        kind,
        family: spec.obs.family,
        lane: lane as u16,
        sweep: spec.sweep as u32,
        epoch: spec.obs.epoch,
        ticket: ticket as u32,
        partition,
        t0_ns: tr.now(),
        dur_ns: 0,
        arg,
    });
}

/// The lifetime-erased tracer pointer a pool [`Job`] carries
/// (null = tracing off).
#[inline]
fn trace_ptr(spec: &EpochSpec<'_>) -> *const Tracer {
    spec.obs
        .trace
        .map_or(std::ptr::null(), |t| t as *const Tracer)
}

/// One epoch's work: the diagonal's token blocks plus the schedule's
/// per-worker assignment over them. `blocks`, `ids`, and the caller's
/// delta slots are parallel arrays; `assign[w]` lists the indices worker
/// `w` runs. Every index must appear exactly once across all workers
/// (enforced by every executor — see `check_tasks`) — the partitions of
/// one diagonal are pairwise row/column-disjoint, so any such
/// assignment is conflict-free.
pub struct EpochTasks<'a> {
    /// The epoch's token blocks (one per partition of the diagonal).
    pub blocks: &'a mut [TokenBlock],
    /// Global partition id of each block — the RNG key (see [`task_rng`]).
    pub ids: &'a [u64],
    /// Per-worker task lists: indices into `blocks`/`ids`/`deltas`.
    pub assign: &'a [Vec<u32>],
    /// Per-task telemetry slots, parallel to `blocks`: whichever worker
    /// runs task `i` stamps its measured sweep nanos into `nanos[i]`
    /// (exclusive under the same ownership rule as the delta slot).
    /// Zeroed by the executor; feeds the [`crate::scheduler::adaptive`]
    /// cost estimators.
    pub nanos: &'a mut [u64],
    /// Per-worker-slot busy nanos for the epoch (length == `assign`
    /// length), zeroed and filled by the executor: the wallclock each
    /// worker slot actually spent sampling, under stealing as well as
    /// static assignment.
    pub worker_nanos: &'a mut [u64],
    /// Work stealing: when set, `assign` still pins the schedule
    /// invariant (every task exactly once) but execution ignores list
    /// membership — workers claim tasks from a shared per-epoch cursor
    /// over `blocks` (an atomic fetch-add), so an idle worker absorbs a
    /// slow one's backlog. Bit-identical to static execution because
    /// task RNG streams and delta slots are per-partition.
    pub steal: bool,
}

/// Executes diagonal epochs. One call = one epoch: each task `i` sweeps
/// `tasks.blocks[i]` and leaves its signed topic-total delta in
/// `deltas[i]` (length `h.k`, zeroed by the executor). The caller merges
/// deltas at the barrier; one slot per *task*, not per worker, so merge
/// order and worker assignment never affect results.
pub trait Executor {
    fn run_epoch(
        &mut self,
        spec: &EpochSpec<'_>,
        tasks: EpochTasks<'_>,
        deltas: &mut [Vec<i64>],
    );

    /// One diagonal epoch under the *ticketed* protocol: the epoch's
    /// barrier is replaced by a pipelined in-order commit. Each task's
    /// index is its ticket; the executor invokes `commit(ticket, delta,
    /// in_flight)` exactly once per task, in strictly ascending ticket
    /// order, only after that task sampled successfully (post-retry) —
    /// overlapped with the sampling tail wherever the executor can.
    /// `in_flight` is the number of tasks not yet sampled at commit
    /// time: `> 0` means the fold ran in the shadow of sampling
    /// (run-ahead), `0` means sampling had drained and the fold was
    /// blocking — the caller buckets its timers accordingly.
    ///
    /// `overlap` is invoked exactly once, immediately after the epoch's
    /// work is dispatched (at the start, for the sequential executor):
    /// the trainer's hook for spill release/prefetch IO that should run
    /// in the shadow of sampling.
    ///
    /// Ascending ticket order is the barrier path's merge order, and
    /// task RNG streams are per-partition ([`task_rng`]), so a ticketed
    /// epoch is bit-identical to a barrier epoch — the protocol changes
    /// *when* deltas fold, never what they fold to. Telemetry contracts
    /// (`nanos`, `worker_nanos`, retries) are identical to
    /// [`Executor::run_epoch`].
    ///
    /// The default implementation is the degenerate pipeline — run the
    /// barrier epoch, then commit every ticket in order with zero
    /// overlap — which is exactly right for in-order executors.
    fn run_epoch_ticketed(
        &mut self,
        spec: &EpochSpec<'_>,
        tasks: EpochTasks<'_>,
        deltas: &mut [Vec<i64>],
        overlap: &mut dyn FnMut(),
        commit: &mut dyn FnMut(usize, &[i64], usize),
    ) {
        overlap();
        self.run_epoch(spec, tasks, deltas);
        for (t, delta) in deltas.iter().enumerate() {
            commit(t, delta, 0);
        }
    }

    /// Task re-executions performed after contained panics, over this
    /// executor's lifetime. Zero on a fault-free run; the trainers
    /// surface per-sweep increments in their telemetry (see
    /// `SweepStats::task_retries`).
    fn retries(&self) -> u64 {
        0
    }
}

/// The barrier merge shared by the trainers: fold every task's signed
/// delta into the authoritative topic totals *and* the double-buffered
/// snapshot (which becomes the next epoch's read view — no re-clone).
/// Integer addition commutes, so merge order never affects results.
pub fn merge_deltas(totals: &mut [u32], snapshot: &mut [u32], deltas: &[Vec<i64>]) {
    for delta in deltas {
        for (t, &d) in delta.iter().enumerate() {
            let v = totals[t] as i64 + d;
            debug_assert!(v >= 0, "topic total went negative");
            totals[t] = v as u32;
            snapshot[t] = v as u32;
        }
    }
}

/// The ticketed commit step: fold one task's signed delta into the
/// authoritative topic totals *only*. Unlike [`merge_deltas`] it leaves
/// the epoch-start snapshot untouched — under run-ahead the snapshot is
/// still being read by concurrently sampling tasks of the same epoch,
/// and the trainer republishes it once per epoch after the last commit.
pub fn commit_delta(totals: &mut [u32], delta: &[i64]) {
    for (t, &d) in delta.iter().enumerate() {
        let v = totals[t] as i64 + d;
        debug_assert!(v >= 0, "topic total went negative");
        totals[t] = v as u32;
    }
}

/// The single-threaded committer state for one ticketed epoch: which
/// tickets have sampled successfully, and the watermark below which
/// every ticket is committed. Ticket `t` is the task's index within the
/// epoch — the barrier path's merge order — so draining the contiguous
/// ready prefix in watermark order reproduces the barrier result
/// bit for bit. A task whose panic was contained is simply *not* marked
/// ready (its ticket is revoked): the watermark stalls at it, nothing
/// after it commits, and the eventual successful retry re-arms the
/// ticket with the identical delta (same `(seed, sweep, partition)` RNG
/// stream).
struct TicketCommitter {
    /// Per-ticket "sampled successfully, delta ready to fold" flags.
    ready: Vec<bool>,
    /// Next ticket to commit; everything below is folded.
    watermark: usize,
    /// Tickets marked ready so far (committed or awaiting the watermark).
    sampled: usize,
}

impl TicketCommitter {
    fn new(n: usize) -> Self {
        Self { ready: vec![false; n], watermark: 0, sampled: 0 }
    }

    /// Mark ticket `t`'s task as sampled successfully.
    fn mark_ready(&mut self, t: usize) {
        debug_assert!(!self.ready[t], "ticket {t} completed twice");
        self.ready[t] = true;
        self.sampled += 1;
    }

    /// The watermark ticket, if its delta is ready to fold.
    fn next_committable(&self) -> Option<usize> {
        (self.watermark < self.ready.len() && self.ready[self.watermark])
            .then_some(self.watermark)
    }

    /// Record that [`Self::next_committable`]'s ticket was committed.
    fn advance(&mut self) {
        self.watermark += 1;
    }

    /// Tasks not yet sampled — the `in_flight` the commit callback sees.
    fn in_flight(&self) -> usize {
        self.ready.len() - self.sampled
    }

    /// Every ticket committed (the epoch's exit invariant).
    fn finished(&self) -> bool {
        self.watermark == self.ready.len()
    }
}

/// Validation of the schedule invariant: the assignment is a partition
/// of the task indices (each exactly once, all in bounds), and the
/// parallel arrays agree in length. Unconditional — the threaded and
/// pooled executors index raw pointers off this assignment, so a bad
/// `EpochTasks` from safe code must fail here, not corrupt memory; the
/// check is O(P) per epoch, negligible next to sampling.
pub(crate) fn check_tasks(tasks: &EpochTasks<'_>, deltas: &[Vec<i64>]) {
    let n = tasks.blocks.len();
    assert_eq!(n, tasks.ids.len(), "one id per block");
    assert_eq!(n, deltas.len(), "one delta slot per block");
    assert_eq!(n, tasks.nanos.len(), "one nanos slot per block");
    assert_eq!(
        tasks.assign.len(),
        tasks.worker_nanos.len(),
        "one busy slot per worker"
    );
    if n <= 128 {
        // Bitmask fast path: preserves the zero-per-epoch-allocation
        // property for every realistic grid.
        let mut seen: u128 = 0;
        let mut count = 0usize;
        for list in tasks.assign {
            for &i in list {
                assert!((i as usize) < n, "task index {i} out of bounds");
                let bit = 1u128 << i;
                assert!(seen & bit == 0, "task {i} assigned to more than one worker");
                seen |= bit;
                count += 1;
            }
        }
        assert_eq!(count, n, "schedule must cover every task of the epoch");
    } else {
        let mut seen = vec![false; n];
        for list in tasks.assign {
            for &i in list {
                let slot = seen
                    .get_mut(i as usize)
                    .unwrap_or_else(|| panic!("task index {i} out of bounds"));
                assert!(!*slot, "task {i} assigned to more than one worker");
                *slot = true;
            }
        }
        assert!(
            seen.iter().all(|&s| s),
            "schedule must cover every task of the epoch"
        );
    }
}

/// The task body shared by all executors: zero the task's delta slot,
/// derive the partition's RNG stream, hand the task to the sampling
/// kernel. The kernel owns its scratch (see [`crate::kernel`]); the
/// diagonal non-conflict invariant makes the shared row access
/// race-free. Returns the task's measured sweep nanos — the telemetry
/// the worker stamps into the task's `nanos` slot and the
/// [`crate::scheduler::adaptive::Measured`] estimator learns from.
/// `pub(crate)` because the distributed layer reuses it verbatim: a
/// remote worker (`crate::dist::worker`) runs the same body on its
/// shipped partition, and the coordinator's local-fallback path runs it
/// in-process — both therefore share the failpoint sites and the
/// `(seed, sweep, partition)` RNG-stream keying that make distributed
/// replay bit-identical.
pub(crate) fn run_task(
    spec: &EpochSpec<'_>,
    partition: u64,
    block: &mut TokenBlock,
    delta: &mut [i64],
    kernel: &mut dyn Kernel,
) -> u64 {
    // Failpoint: a deterministic injected worker crash at this exact
    // (sweep, partition) coordinate — compiled to nothing without the
    // `failpoints` feature (see `crate::util::fault`). Firing *before*
    // the first token makes the containment rollback exact.
    if fault::fire(fault::sites::TASK, [spec.seed, spec.sweep as u64, partition]).is_some() {
        panic!(
            "injected fault: worker panic at sweep {}, partition {partition}",
            spec.sweep
        );
    }
    debug_assert_eq!(delta.len(), spec.h.k);
    let started = Instant::now();
    delta.fill(0);
    let mut rng = task_rng(spec.seed, spec.sweep, partition);
    let ctx = TaskCtx {
        doc: spec.doc,
        emit: spec.emit,
        snapshot: spec.snapshot,
        h: spec.h,
    };
    kernel.sweep_task(&ctx, block, delta, &mut rng);
    // Failpoint: a deterministic crash *after* the kernel finished but
    // before the task's result is handed to the committer — the worst
    // spot for the ticketed protocol, which must revoke the ticket and
    // re-execute instead of committing a rolled-back delta. Still inside
    // the caller's panic guard, so containment rolls the task back
    // exactly as for a mid-sampling crash.
    if fault::fire(fault::sites::COMMIT, [spec.seed, spec.sweep as u64, partition]).is_some() {
        panic!(
            "injected fault: pre-commit crash at sweep {}, partition {partition}",
            spec.sweep
        );
    }
    started.elapsed().as_nanos() as u64
}

/// [`run_task`] under a panic guard — the containment half of the retry
/// protocol. The block's `z` is snapshotted into `backup` (a reusable
/// scratch vector) before sampling; if the kernel panics, every count
/// move it already applied is reversed ([`roll_back_task`]), the delta
/// slot is re-zeroed, and `Err` asks the caller to retry. Because the
/// shared state is then exactly as if the task had never started, the
/// retry — which derives the same `(seed, sweep, partition)` RNG
/// stream — is bit-identical to an undisturbed execution.
///
/// The rollback is exact for panics that fire before the first token
/// (the injected-fault case, and any precondition assert); for a panic
/// in the middle of a token's resample the in-flight token's decrement
/// may not yet have a matching increment, so containment of organic
/// mid-token crashes is best-effort (debug builds audit totals at the
/// next merge via `merge_deltas`' non-negativity assert).
fn run_task_guarded(
    spec: &EpochSpec<'_>,
    partition: u64,
    block: &mut TokenBlock,
    delta: &mut [i64],
    kernel: &mut dyn Kernel,
    backup: &mut Vec<u32>,
) -> Result<u64, ()> {
    backup.clear();
    backup.extend_from_slice(&block.z);
    let result = catch_unwind(AssertUnwindSafe(|| {
        run_task(spec, partition, block, delta, kernel)
    }));
    match result {
        Ok(dt) => Ok(dt),
        Err(_) => {
            roll_back_task(spec, block, delta, backup);
            Err(())
        }
    }
}

/// Undo a partially-applied task: for every token whose `z` differs
/// from the pre-task snapshot, reverse the count moves the collapsed
/// Gibbs update made (−1 on the new topic, +1 on the old one, in both
/// the document row and the emission row), restore the snapshot, and
/// re-zero the delta slot.
fn roll_back_task(
    spec: &EpochSpec<'_>,
    block: &mut TokenBlock,
    delta: &mut [i64],
    backup: &[u32],
) {
    debug_assert_eq!(backup.len(), block.z.len());
    for i in 0..block.z.len() {
        let old = backup[i];
        let new = block.z[i];
        if new == old {
            continue;
        }
        let d = block.docs[i] as usize;
        let w = block.words[i] as usize;
        // SAFETY: the panicked task's doc/emission rows are exclusively
        // its claimer's until the epoch barrier (diagonal non-conflict
        // invariant), and `old`/`new` are topics drawn from `0..k`.
        unsafe {
            let dp = spec.doc.row_ptr(d);
            *dp.add(new as usize) -= 1.0;
            *dp.add(old as usize) += 1.0;
            let ep = spec.emit.row_ptr(w);
            *ep.add(new as usize) -= 1.0;
            *ep.add(old as usize) += 1.0;
        }
        block.z[i] = old;
    }
    delta.fill(0);
}

/// Re-execute a contained-panic task on the calling thread, building a
/// fresh kernel per attempt (the panic may have torn the old one's
/// scratch). `retries` is bumped once per re-execution. Panics — with
/// "giving up" in the message — once the task has consumed its whole
/// [`MAX_TASK_ATTEMPTS`] budget, so a deterministic crash surfaces
/// instead of looping.
///
/// `lane`/`ticket` attribute the trace: each attempt emits a
/// [`EventKind::Retry`] instant, a contained failure a
/// [`EventKind::Rollback`], and the eventual success the task's one
/// span — the calling thread is the lane's sole producer here (workers
/// have joined/parked), so the SPSC contract holds.
fn retry_task(
    spec: &EpochSpec<'_>,
    lane: usize,
    ticket: usize,
    partition: u64,
    block: &mut TokenBlock,
    delta: &mut [i64],
    retries: &mut u64,
) -> u64 {
    let mut backup = Vec::new();
    let mut attempts = 1u32; // the contained failure that got us here
    loop {
        *retries += 1;
        trace_instant(spec, lane, EventKind::Retry, ticket, partition, attempts as u64);
        let mut kernel = spec.kernel.build();
        match run_task_guarded(spec, partition, block, delta, kernel.as_mut(), &mut backup) {
            Ok(dt) => {
                trace_task(spec, lane, ticket, partition, dt, false);
                return dt;
            }
            Err(()) => {
                trace_instant(
                    spec,
                    lane,
                    EventKind::Rollback,
                    ticket,
                    partition,
                    attempts as u64,
                );
                attempts += 1;
                assert!(
                    attempts < MAX_TASK_ATTEMPTS,
                    "task for partition {partition} panicked \
                     {MAX_TASK_ATTEMPTS} times; giving up"
                );
            }
        }
    }
}

/// A worker's long-lived kernel instance: rebuilt only when the
/// requested kind changes (e.g. the trainer switched kernels between
/// sweeps), so kernel scratch persists across epochs and sweeps and the
/// steady-state hot path performs no per-epoch allocation.
#[derive(Default)]
struct KernelSlot(Option<Box<dyn Kernel>>);

impl KernelSlot {
    fn get(&mut self, kind: KernelKind) -> &mut dyn Kernel {
        if self.0.as_ref().map(|k| k.kind()) != Some(kind) {
            self.0 = Some(kind.build());
        }
        self.0.as_mut().unwrap().as_mut()
    }
}

/// In-order execution on the calling thread. The determinism oracle for
/// the parallel modes, and the zero-overhead mode for single-core boxes;
/// owns its kernel (and thereby its scratch) so repeated sweeps allocate
/// nothing. Runs tasks worker-list by worker-list (attributing busy time
/// to the worker slot the schedule assigned) — equivalent to any other
/// order, since task RNG streams and delta slots are per-partition; for
/// the same reason the `steal` flag changes nothing here and is ignored.
#[derive(Default)]
pub struct SequentialExec {
    kernel: KernelSlot,
    /// Reusable `z` snapshot for the panic guard (see
    /// [`run_task_guarded`]); grows to the largest block and stays.
    backup: Vec<u32>,
    retries: u64,
}

impl Executor for SequentialExec {
    fn run_epoch(
        &mut self,
        spec: &EpochSpec<'_>,
        tasks: EpochTasks<'_>,
        deltas: &mut [Vec<i64>],
    ) {
        check_tasks(&tasks, deltas);
        tasks.nanos.fill(0);
        tasks.worker_nanos.fill(0);
        for (w, list) in tasks.assign.iter().enumerate() {
            let mut busy = 0u64;
            for &i in list {
                let i = i as usize;
                let kernel = self.kernel.get(spec.kernel);
                let dt = match run_task_guarded(
                    spec,
                    tasks.ids[i],
                    &mut tasks.blocks[i],
                    &mut deltas[i],
                    kernel,
                    &mut self.backup,
                ) {
                    Ok(dt) => {
                        trace_task(spec, w, i, tasks.ids[i], dt, false);
                        dt
                    }
                    Err(()) => {
                        trace_instant(spec, w, EventKind::Rollback, i, tasks.ids[i], 1);
                        // The panic may have torn the kernel's scratch;
                        // drop it so the next get() rebuilds from scratch.
                        self.kernel = KernelSlot::default();
                        retry_task(
                            spec,
                            w,
                            i,
                            tasks.ids[i],
                            &mut tasks.blocks[i],
                            &mut deltas[i],
                            &mut self.retries,
                        )
                    }
                };
                tasks.nanos[i] = dt;
                busy += dt;
            }
            tasks.worker_nanos[w] = busy;
        }
    }

    fn retries(&self) -> u64 {
        self.retries
    }
}

/// A `Send` raw-pointer wrapper for handing the epoch's task arrays to
/// scoped worker threads; the schedule invariant (each index owned by
/// exactly one worker — under stealing, by exactly one *claimer* via the
/// unique atomic-cursor index) makes the aliasing sound. `busy` has one
/// slot per worker slot, written only by that slot's thread.
struct TaskArrays {
    blocks: *mut TokenBlock,
    deltas: *mut Vec<i64>,
    nanos: *mut u64,
    busy: *mut u64,
}
unsafe impl Send for TaskArrays {}

/// Scoped execution: one OS thread *spawned* per busy worker slot per
/// epoch, with per-spawn kernel (scratch) construction. Kept as the
/// baseline the executor-overhead benchmark compares [`WorkerPool`]
/// against.
#[derive(Default)]
pub struct ThreadedExec {
    retries: u64,
}

impl Executor for ThreadedExec {
    fn run_epoch(
        &mut self,
        spec: &EpochSpec<'_>,
        tasks: EpochTasks<'_>,
        deltas: &mut [Vec<i64>],
    ) {
        check_tasks(&tasks, deltas);
        tasks.nanos.fill(0);
        tasks.worker_nanos.fill(0);
        let ids = tasks.ids;
        let n = tasks.blocks.len();
        let blocks_ptr = tasks.blocks.as_mut_ptr();
        let deltas_ptr = deltas.as_mut_ptr();
        let nanos_ptr = tasks.nanos.as_mut_ptr();
        let busy_ptr = tasks.worker_nanos.as_mut_ptr();
        // Contained-panic flags, one per task: a panicking task is rolled
        // back in place by its thread, flagged here, and re-executed on
        // the calling thread after the scope joins (index order, so the
        // retry pass is deterministic).
        let failed: Vec<AtomicBool> = (0..n).map(|_| AtomicBool::new(false)).collect();
        let failed = &failed;
        if tasks.steal {
            // Shared per-epoch queue: the next unclaimed task index. A
            // fetch-add hands each task to exactly one thread, so the
            // exclusivity invariant holds dynamically instead of via the
            // static lists.
            let cursor = AtomicUsize::new(0);
            let cursor = &cursor;
            let assign = tasks.assign;
            std::thread::scope(|s| {
                for w in 0..tasks.assign.len().min(n) {
                    let arrays = TaskArrays {
                        blocks: blocks_ptr,
                        deltas: deltas_ptr,
                        nanos: nanos_ptr,
                        busy: busy_ptr,
                    };
                    s.spawn(move || {
                        let mut kernel = spec.kernel.build();
                        let mut backup = Vec::new();
                        let mut busy = 0u64;
                        loop {
                            let i = cursor.fetch_add(1, Ordering::Relaxed);
                            if i >= n {
                                break;
                            }
                            // SAFETY: the fetch-add yields index `i` to
                            // this thread alone; the scope join sequences
                            // all other access.
                            let block = unsafe { &mut *arrays.blocks.add(i) };
                            let delta = unsafe { (*arrays.deltas.add(i)).as_mut_slice() };
                            match run_task_guarded(
                                spec,
                                ids[i],
                                block,
                                delta,
                                kernel.as_mut(),
                                &mut backup,
                            ) {
                                Ok(dt) => {
                                    unsafe { *arrays.nanos.add(i) = dt };
                                    busy += dt;
                                    if spec.obs.trace.is_some() {
                                        let stolen = !assign[w].contains(&(i as u32));
                                        trace_task(spec, w, i, ids[i], dt, stolen);
                                    }
                                }
                                Err(()) => {
                                    trace_instant(
                                        spec,
                                        w,
                                        EventKind::Rollback,
                                        i,
                                        ids[i],
                                        1,
                                    );
                                    failed[i].store(true, Ordering::Relaxed);
                                    // Scratch may be torn; rebuild before
                                    // the next claimed task.
                                    kernel = spec.kernel.build();
                                }
                            }
                        }
                        // SAFETY: slot `w` is this thread's alone.
                        unsafe { *arrays.busy.add(w) = busy };
                    });
                }
            });
        } else {
            std::thread::scope(|s| {
                for (w, list) in tasks.assign.iter().enumerate() {
                    if list.is_empty() {
                        continue;
                    }
                    let arrays = TaskArrays {
                        blocks: blocks_ptr,
                        deltas: deltas_ptr,
                        nanos: nanos_ptr,
                        busy: busy_ptr,
                    };
                    s.spawn(move || {
                        let mut kernel = spec.kernel.build();
                        let mut backup = Vec::new();
                        let mut busy = 0u64;
                        for &i in list {
                            let i = i as usize;
                            // SAFETY: `check_tasks` invariant — index
                            // `i` belongs to this worker alone, so the
                            // block, delta, and nanos slots are
                            // exclusively ours until the scope joins.
                            let block = unsafe { &mut *arrays.blocks.add(i) };
                            let delta = unsafe { (*arrays.deltas.add(i)).as_mut_slice() };
                            match run_task_guarded(
                                spec,
                                ids[i],
                                block,
                                delta,
                                kernel.as_mut(),
                                &mut backup,
                            ) {
                                Ok(dt) => {
                                    unsafe { *arrays.nanos.add(i) = dt };
                                    busy += dt;
                                    trace_task(spec, w, i, ids[i], dt, false);
                                }
                                Err(()) => {
                                    trace_instant(
                                        spec,
                                        w,
                                        EventKind::Rollback,
                                        i,
                                        ids[i],
                                        1,
                                    );
                                    failed[i].store(true, Ordering::Relaxed);
                                    kernel = spec.kernel.build();
                                }
                            }
                        }
                        // SAFETY: slot `w` is this thread's alone.
                        unsafe { *arrays.busy.add(w) = busy };
                    });
                }
            });
        }
        // Retry pass: re-execute contained-panic tasks on the calling
        // thread with fresh kernels. The retry's busy time is attributed
        // to the worker slot whose static list holds the task (slot 0
        // for an unlisted stolen task), preserving the telemetry
        // conservation invariant sum(nanos) == sum(worker_nanos).
        for i in 0..n {
            if !failed[i].load(Ordering::Relaxed) {
                continue;
            }
            let w = tasks
                .assign
                .iter()
                .position(|l| l.contains(&(i as u32)))
                .unwrap_or(0);
            let dt = retry_task(
                spec,
                w,
                i,
                tasks.ids[i],
                &mut tasks.blocks[i],
                &mut deltas[i],
                &mut self.retries,
            );
            tasks.nanos[i] = dt;
            tasks.worker_nanos[w] += dt;
        }
    }

    fn run_epoch_ticketed(
        &mut self,
        spec: &EpochSpec<'_>,
        tasks: EpochTasks<'_>,
        deltas: &mut [Vec<i64>],
        overlap: &mut dyn FnMut(),
        commit: &mut dyn FnMut(usize, &[i64], usize),
    ) {
        check_tasks(&tasks, deltas);
        tasks.nanos.fill(0);
        tasks.worker_nanos.fill(0);
        let ids = tasks.ids;
        let n = tasks.blocks.len();
        let blocks_ptr = tasks.blocks.as_mut_ptr();
        let deltas_ptr = deltas.as_mut_ptr();
        let nanos_ptr = tasks.nanos.as_mut_ptr();
        let busy_ptr = tasks.worker_nanos.as_mut_ptr();
        let mut committer = TicketCommitter::new(n);
        let mut failed = vec![false; n];
        // Per-task completion channel: `(ticket, sampled_ok)`. Each send
        // happens-after its worker's writes to the task's delta and
        // nanos slots, so receiving a ticket licenses the committer to
        // read them while the other threads keep sampling.
        let (done_tx, done_rx) = channel::<(usize, bool)>();
        let cursor = AtomicUsize::new(0);
        std::thread::scope(|s| {
            let cursor = &cursor;
            let spawned = if tasks.steal {
                tasks.assign.len().min(n)
            } else {
                tasks.assign.len()
            };
            for (w, list) in tasks.assign.iter().enumerate().take(spawned) {
                if !tasks.steal && list.is_empty() {
                    continue;
                }
                let arrays = TaskArrays {
                    blocks: blocks_ptr,
                    deltas: deltas_ptr,
                    nanos: nanos_ptr,
                    busy: busy_ptr,
                };
                let done = done_tx.clone();
                let steal = tasks.steal;
                s.spawn(move || {
                    let mut kernel = spec.kernel.build();
                    let mut backup = Vec::new();
                    let mut busy = 0u64;
                    let mut body = |i: usize| {
                        // SAFETY: index `i` is exclusively this thread's
                        // — by the `check_tasks` invariant in static
                        // mode, by the unique fetch-add in stealing mode
                        // — until its completion message below is
                        // received.
                        let block = unsafe { &mut *arrays.blocks.add(i) };
                        let delta = unsafe { (*arrays.deltas.add(i)).as_mut_slice() };
                        let ok = match run_task_guarded(
                            spec,
                            ids[i],
                            block,
                            delta,
                            kernel.as_mut(),
                            &mut backup,
                        ) {
                            Ok(dt) => {
                                unsafe { *arrays.nanos.add(i) = dt };
                                busy += dt;
                                if spec.obs.trace.is_some() {
                                    let stolen =
                                        steal && !list.contains(&(i as u32));
                                    trace_task(spec, w, i, ids[i], dt, stolen);
                                }
                                true
                            }
                            Err(()) => {
                                trace_instant(
                                    spec,
                                    w,
                                    EventKind::Rollback,
                                    i,
                                    ids[i],
                                    1,
                                );
                                // Contained and rolled back; scratch may
                                // be torn — rebuild before the next task.
                                kernel = spec.kernel.build();
                                false
                            }
                        };
                        let _ = done.send((i, ok));
                    };
                    if steal {
                        loop {
                            let i = cursor.fetch_add(1, Ordering::Relaxed);
                            if i >= n {
                                break;
                            }
                            body(i);
                        }
                    } else {
                        for &i in list {
                            body(i as usize);
                        }
                    }
                    // SAFETY: slot `w` is this thread's alone.
                    unsafe { *arrays.busy.add(w) = busy };
                });
            }
            drop(done_tx);
            // Dispatch done — the caller's overlapped IO (spill
            // release/prefetch) runs now, in the shadow of the sampling
            // the threads just started.
            overlap();
            // Committer loop: exactly one message per task. Fold the
            // contiguous ready prefix as tickets arrive; a failed task's
            // ticket is revoked — the watermark stalls there until the
            // post-join retry pass re-arms it.
            for _ in 0..n {
                let (t, ok) = done_rx.recv().expect("a worker thread died mid-epoch");
                if ok {
                    committer.mark_ready(t);
                    while let Some(c) = committer.next_committable() {
                        // SAFETY: ticket `c`'s completion message has
                        // been received, and its claimer's last write to
                        // the delta slot happens-before that send.
                        let delta = unsafe { (*deltas_ptr.add(c)).as_slice() };
                        commit(c, delta, committer.in_flight());
                        committer.advance();
                    }
                } else {
                    failed[t] = true;
                }
            }
        });
        // Retry pass, exactly as in the barrier path — except each
        // re-executed task also re-arms its revoked ticket, so the
        // stalled commits drain here in ticket order (the retry derives
        // the same `(seed, sweep, partition)` RNG stream, so the delta
        // it commits is the one an undisturbed run would have).
        for i in 0..n {
            if !failed[i] {
                continue;
            }
            let w = tasks
                .assign
                .iter()
                .position(|l| l.contains(&(i as u32)))
                .unwrap_or(0);
            let dt = retry_task(
                spec,
                w,
                i,
                tasks.ids[i],
                &mut tasks.blocks[i],
                &mut deltas[i],
                &mut self.retries,
            );
            tasks.nanos[i] = dt;
            tasks.worker_nanos[w] += dt;
            committer.mark_ready(i);
            while let Some(c) = committer.next_committable() {
                commit(c, &deltas[c], committer.in_flight());
                committer.advance();
            }
        }
        assert!(committer.finished(), "ticketed epoch left uncommitted tickets");
    }

    fn retries(&self) -> u64 {
        self.retries
    }
}

/// A lifetime-erased epoch assignment for one pool worker: the epoch's
/// task arrays plus this worker's index list. All pointers are guaranteed
/// valid (and the tasks they reach exclusively owned) until the
/// coordinator has received this job's completion signal.
struct Job {
    blocks: *mut TokenBlock,
    ids: *const u64,
    deltas: *mut Vec<i64>,
    /// Per-task telemetry slots, parallel to `blocks` (see
    /// [`EpochTasks::nanos`]).
    nanos: *mut u64,
    assign: *const u32,
    assign_len: usize,
    /// Work-stealing queue: the epoch's shared next-unclaimed-task
    /// cursor, or null for static execution over `assign`.
    queue: *const AtomicUsize,
    /// Task count of the epoch (the stealing cursor's exclusive bound).
    n_tasks: usize,
    doc: *mut f32,
    /// Row count of `doc` (debug bounds parity with `SharedRows::row_ptr`).
    doc_rows: usize,
    emit: *mut f32,
    /// Row count of `emit`.
    emit_rows: usize,
    snapshot: *const u32,
    h: Hyper,
    seed: u64,
    sweep: usize,
    kernel: KernelKind,
    worker: usize,
    /// Ticketed protocol: send a [`Done::Task`] message after every
    /// task (before the job's own [`Done::Job`] completion), so the
    /// coordinator can commit tickets while the job is still sampling.
    per_task: bool,
    /// Trace recorder (null = tracing off) plus the epoch/family trace
    /// coordinates — the lifetime-erased form of [`TaskObs`]. Valid
    /// until the job's completion signal, like every other pointer here.
    trace: *const Tracer,
    epoch: u32,
    family: u8,
}

// SAFETY: Job transfers *exclusive logical ownership* of the worker's
// assigned blocks, delta slots, and telemetry slots to exactly one worker
// for the duration of one epoch — statically via `assign`, or dynamically
// via the unique indices the shared atomic cursor hands out — and the
// coordinator's gather barrier sequences all other access. The snapshot,
// index list, and cursor (`AtomicUsize` is `Sync`) are safe to share.
unsafe impl Send for Job {}

/// One pool completion message.
enum Done {
    /// Job-level completion — the gather unit, one per submitted
    /// [`Job`]: the worker slot, the job outcome, and the busy nanos of
    /// the job's *successful* tasks. `Some(failed)` is a
    /// normally-completed job — `failed` lists the task indices whose
    /// panics were contained and rolled back (empty on a clean job);
    /// `None` is a job-level panic outside every per-task guard, which
    /// the coordinator escalates.
    Job {
        worker: usize,
        outcome: Option<Vec<u32>>,
        busy: u64,
    },
    /// Per-task progress under the ticketed protocol (sent only when
    /// the job was dispatched with [`Job::per_task`]): task `task`
    /// finished sampling successfully (`ok`) or panicked and was rolled
    /// back (`!ok`, a revoked ticket). The send happens-after the
    /// worker's writes to the task's delta and nanos slots.
    Task { task: usize, ok: bool },
}

fn worker_loop(rx: Receiver<Job>, done: Sender<Done>) {
    // Long-lived kernel (and thereby scratch): built on the first epoch,
    // reused forever after — rebuilt only if the trainer switches kernel
    // kinds between sweeps, or a contained panic may have torn its
    // scratch mid-update.
    let mut kernel = KernelSlot::default();
    let mut backup = Vec::new();
    loop {
        // Queue-wait telemetry: how long this worker idled for its next
        // job. One timestamp per dispatch — negligible against an
        // epoch's sampling — and only *emitted* when the job traces.
        let waited = Instant::now();
        let Ok(job) = rx.recv() else { break };
        let wait_ns = waited.elapsed().as_nanos() as u64;
        let k = job.h.k;
        // Catch panics outside the per-task guard (kernel construction,
        // a failed invariant in this loop itself) so they surface as a
        // coordinator panic instead of a deadlocked gather barrier.
        let result = catch_unwind(AssertUnwindSafe(|| {
            // SAFETY: see `Job` — exclusive ownership until the done
            // signal below is observed. Rebuilding an `EpochSpec` routes
            // the pooled path through the same `run_task` body (and
            // `SharedRows` bounds checks) as the other executors.
            let snapshot = unsafe { std::slice::from_raw_parts(job.snapshot, k) };
            let spec = EpochSpec {
                doc: unsafe { SharedRows::from_raw(job.doc, job.doc_rows, k) },
                emit: unsafe { SharedRows::from_raw(job.emit, job.emit_rows, k) },
                snapshot,
                h: job.h,
                seed: job.seed,
                sweep: job.sweep,
                kernel: job.kernel,
                obs: TaskObs {
                    // SAFETY: the tracer (when set) is owned by the
                    // trainer driving the gather barrier, so it outlives
                    // the job like every other Job pointer.
                    trace: unsafe { job.trace.as_ref() },
                    epoch: job.epoch,
                    family: job.family,
                },
            };
            if let Some(tr) = spec.obs.trace {
                tr.emit(Event {
                    kind: EventKind::QueueWait,
                    family: job.family,
                    lane: job.worker as u16,
                    sweep: job.sweep as u32,
                    epoch: job.epoch,
                    ticket: 0,
                    partition: 0,
                    t0_ns: tr.now().saturating_sub(wait_ns),
                    dur_ns: wait_ns,
                    arg: 0,
                });
            }
            let mut busy = 0u64;
            let mut failed: Vec<u32> = Vec::new();
            let assign_list =
                unsafe { std::slice::from_raw_parts(job.assign, job.assign_len) };
            let mut body = |i: usize| {
                // SAFETY: index `i` is exclusively this worker's — by
                // the `check_tasks` invariant in static mode, by the
                // unique fetch-add in stealing mode.
                let block = unsafe { &mut *job.blocks.add(i) };
                let delta = unsafe { (*job.deltas.add(i)).as_mut_slice() };
                let id = unsafe { *job.ids.add(i) };
                let kr = kernel.get(job.kernel);
                let ok = match run_task_guarded(&spec, id, block, delta, kr, &mut backup) {
                    Ok(dt) => {
                        unsafe { *job.nanos.add(i) = dt };
                        busy += dt;
                        if spec.obs.trace.is_some() {
                            let stolen = !job.queue.is_null()
                                && !assign_list.contains(&(i as u32));
                            trace_task(&spec, job.worker, i, id, dt, stolen);
                        }
                        true
                    }
                    Err(()) => {
                        trace_instant(
                            &spec,
                            job.worker,
                            EventKind::Rollback,
                            i,
                            id,
                            1,
                        );
                        // Contained and rolled back; the coordinator
                        // re-dispatches. The panic may have torn the
                        // kernel's scratch — rebuild before the next task.
                        kernel = KernelSlot::default();
                        failed.push(i as u32);
                        false
                    }
                };
                if job.per_task {
                    // Ticketed protocol: stream the ticket to the
                    // committer while the rest of the job keeps
                    // sampling. A send error means the coordinator is
                    // gone; the final job message below will notice.
                    let _ = done.send(Done::Task { task: i, ok });
                }
            };
            if job.queue.is_null() {
                for &i in assign_list {
                    body(i as usize);
                }
            } else {
                // SAFETY: the cursor outlives the epoch (it lives in the
                // pool, which blocks on the gather barrier).
                let queue = unsafe { &*job.queue };
                loop {
                    let i = queue.fetch_add(1, Ordering::Relaxed);
                    if i >= job.n_tasks {
                        break;
                    }
                    body(i);
                }
            }
            (busy, failed)
        }));
        let msg: Done = match result {
            Ok((busy, failed)) => Done::Job { worker: job.worker, outcome: Some(failed), busy },
            Err(_) => {
                kernel = KernelSlot::default();
                Done::Job { worker: job.worker, outcome: None, busy: 0 }
            }
        };
        if done.send(msg).is_err() {
            break; // coordinator gone
        }
    }
}

/// A persistent pool of dedicated epoch workers.
///
/// Created once per trainer and reused for every epoch of every sweep:
/// no thread spawns, no scratch allocation, and no topic-snapshot clone
/// on the steady-state path. Workers block on their job channel between
/// epochs, so an idle pool costs nothing but memory.
pub struct WorkerPool {
    senders: Vec<Sender<Job>>,
    /// Kept so [`Self::respawn`] can wire replacement workers into the
    /// shared completion channel.
    done_tx: Sender<Done>,
    done_rx: Receiver<Done>,
    handles: Vec<JoinHandle<()>>,
    epochs_run: u64,
    /// Contained panics per worker slot since that worker's thread was
    /// (re)spawned — the quarantine trigger (see [`QUARANTINE_PANICS`]).
    panics: Vec<u64>,
    /// Worker threads replaced by quarantine over the pool's lifetime.
    respawns: u64,
    /// Task re-executions after contained panics (see
    /// [`Executor::retries`]).
    retries: u64,
    /// The shared work-stealing cursor (see [`EpochTasks::steal`]),
    /// reset before each stealing epoch. Lives in the pool so its
    /// address is valid for exactly as long as the workers are — the
    /// gather barrier inside [`Executor::run_epoch`] guarantees no
    /// worker touches it after the epoch returns.
    steal_cursor: AtomicUsize,
}

impl WorkerPool {
    /// Spawn `workers` dedicated threads. Beyond this constructor the
    /// pool creates a thread only when quarantine replaces one (see
    /// [`Self::respawn`]); every fault-free epoch reuses the originals.
    pub fn new(workers: usize) -> Self {
        assert!(workers > 0, "pool needs at least one worker");
        let (done_tx, done_rx) = channel();
        let mut senders = Vec::with_capacity(workers);
        let mut handles = Vec::with_capacity(workers);
        for _ in 0..workers {
            let (tx, rx) = channel::<Job>();
            let done = done_tx.clone();
            handles.push(std::thread::spawn(move || worker_loop(rx, done)));
            senders.push(tx);
        }
        Self {
            senders,
            done_tx,
            done_rx,
            handles,
            epochs_run: 0,
            panics: vec![0; workers],
            respawns: 0,
            retries: 0,
            steal_cursor: AtomicUsize::new(0),
        }
    }

    /// Number of pool worker slots (constant for the pool's lifetime —
    /// quarantine replaces a slot's thread but never changes the count).
    pub fn workers(&self) -> usize {
        self.senders.len()
    }

    /// Total diagonal epochs this pool has executed. Monotone over the
    /// pool's lifetime; tests use it to prove the same pool served every
    /// sweep.
    pub fn epochs_run(&self) -> u64 {
        self.epochs_run
    }

    /// Worker threads replaced by quarantine (see [`QUARANTINE_PANICS`]).
    /// Zero on a fault-free run.
    pub fn respawns(&self) -> u64 {
        self.respawns
    }

    /// Replace worker `w`'s thread with a fresh one on the same slot: a
    /// new job channel and a new thread wired into the shared completion
    /// channel. The old thread — and any kernel scratch the panics that
    /// got it quarantined may have torn — sees its job channel close,
    /// exits its receive loop, and is joined here (it is idle at this
    /// point: quarantine runs strictly after the gather barrier).
    fn respawn(&mut self, w: usize) {
        let (tx, rx) = channel::<Job>();
        let done = self.done_tx.clone();
        let fresh = std::thread::spawn(move || worker_loop(rx, done));
        self.senders[w] = tx; // drops the old sender; old thread exits
        let old = std::mem::replace(&mut self.handles[w], fresh);
        let _ = old.join();
        self.panics[w] = 0;
        self.respawns += 1;
    }

    /// Receive the next *job-level* completion. Only valid when no
    /// outstanding job was dispatched with `per_task` (the barrier path
    /// and the ticketed retry rounds): a stray per-task message here
    /// would mean the gather accounting is broken, so it panics.
    fn recv_job(&self) -> (usize, Option<Vec<u32>>, u64) {
        match self.done_rx.recv().expect("pool worker died") {
            Done::Job { worker, outcome, busy } => (worker, outcome, busy),
            Done::Task { task, .. } => {
                panic!("unexpected per-task message (task {task}) outside a ticketed gather")
            }
        }
    }
}

impl Executor for WorkerPool {
    fn run_epoch(
        &mut self,
        spec: &EpochSpec<'_>,
        tasks: EpochTasks<'_>,
        deltas: &mut [Vec<i64>],
    ) {
        check_tasks(&tasks, deltas);
        assert!(
            tasks.assign.len() <= self.senders.len(),
            "schedule uses {} worker slots but the pool has {} workers",
            tasks.assign.len(),
            self.senders.len()
        );
        tasks.nanos.fill(0);
        tasks.worker_nanos.fill(0);
        let n = tasks.blocks.len();
        // Scatter: one job per worker with a non-empty task list — or,
        // when stealing, one job per worker slot that could claim a task
        // (all of them compete over the shared cursor).
        let queue: *const AtomicUsize = if tasks.steal {
            self.steal_cursor.store(0, Ordering::Relaxed);
            &self.steal_cursor
        } else {
            std::ptr::null()
        };
        let blocks_ptr = tasks.blocks.as_mut_ptr();
        let deltas_ptr = deltas.as_mut_ptr();
        let nanos_ptr = tasks.nanos.as_mut_ptr();
        let mut submitted = 0usize;
        for (w, list) in tasks.assign.iter().enumerate() {
            let busy_slot = if tasks.steal { w < n } else { !list.is_empty() };
            if !busy_slot {
                continue;
            }
            let job = Job {
                blocks: blocks_ptr,
                ids: tasks.ids.as_ptr(),
                deltas: deltas_ptr,
                nanos: nanos_ptr,
                assign: list.as_ptr(),
                assign_len: list.len(),
                queue,
                n_tasks: n,
                doc: spec.doc.base_ptr(),
                doc_rows: spec.doc.rows(),
                emit: spec.emit.base_ptr(),
                emit_rows: spec.emit.rows(),
                snapshot: spec.snapshot.as_ptr(),
                h: spec.h,
                seed: spec.seed,
                sweep: spec.sweep,
                kernel: spec.kernel,
                worker: w,
                per_task: false,
                trace: trace_ptr(spec),
                epoch: spec.obs.epoch,
                family: spec.obs.family,
            };
            self.senders[w].send(job).expect("pool worker died");
            submitted += 1;
        }
        // Gather barrier: exactly one completion per submitted job. After
        // this loop no worker holds any pointer from this epoch.
        let mut job_panicked = false;
        let mut failed: Vec<u32> = Vec::new();
        for _ in 0..submitted {
            let (w, outcome, busy) = self.recv_job();
            tasks.worker_nanos[w] += busy;
            match outcome {
                Some(f) => {
                    self.panics[w] += f.len() as u64;
                    failed.extend_from_slice(&f);
                }
                None => job_panicked = true,
            }
        }
        assert!(!job_panicked, "a pool worker panicked during the epoch");
        // Retry rounds: contained-panic tasks were rolled back in place
        // by their workers; re-dispatch them — sorted, because gather
        // order is racy — as one static job to the healthiest worker
        // (fewest contained panics, ties to the lowest slot: a
        // deterministic choice, though results never depend on it — the
        // retry derives the same (seed, sweep, partition) RNG streams,
        // so a retried epoch is bit-identical to an undisturbed one).
        let mut round = 1u32;
        while !failed.is_empty() {
            assert!(
                round < MAX_TASK_ATTEMPTS,
                "tasks {failed:?} panicked {MAX_TASK_ATTEMPTS} times; giving up"
            );
            failed.sort_unstable();
            if let Some(tr) = spec.obs.trace {
                // Retry markers land on the coordinator lane — the
                // retry job itself emits its Task spans from the target
                // worker's lane, like any other job.
                for &i in &failed {
                    trace_instant(
                        spec,
                        tr.coord_lane() as usize,
                        EventKind::Retry,
                        i as usize,
                        tasks.ids[i as usize],
                        round as u64,
                    );
                }
            }
            let target = (0..self.senders.len())
                .min_by_key(|&w| (self.panics[w], w))
                .expect("pool has workers");
            self.retries += failed.len() as u64;
            let job = Job {
                blocks: blocks_ptr,
                ids: tasks.ids.as_ptr(),
                deltas: deltas_ptr,
                nanos: nanos_ptr,
                assign: failed.as_ptr(),
                assign_len: failed.len(),
                queue: std::ptr::null(),
                n_tasks: n,
                doc: spec.doc.base_ptr(),
                doc_rows: spec.doc.rows(),
                emit: spec.emit.base_ptr(),
                emit_rows: spec.emit.rows(),
                snapshot: spec.snapshot.as_ptr(),
                h: spec.h,
                seed: spec.seed,
                sweep: spec.sweep,
                kernel: spec.kernel,
                worker: target,
                per_task: false,
                trace: trace_ptr(spec),
                epoch: spec.obs.epoch,
                family: spec.obs.family,
            };
            self.senders[target].send(job).expect("pool worker died");
            // `failed` must stay alive and unmodified until this recv
            // returns: the worker reads `assign` through a raw pointer.
            let (w, outcome, busy) = self.recv_job();
            tasks.worker_nanos[w] += busy;
            match outcome {
                Some(f) => {
                    self.panics[w] += f.len() as u64;
                    failed = f;
                }
                None => panic!("a pool worker panicked during the epoch"),
            }
            round += 1;
        }
        // Quarantine: replace any worker whose contained panics crossed
        // the threshold. Strictly after the barrier, so every worker is
        // idle and the join inside respawn cannot block on epoch work.
        for w in 0..self.senders.len() {
            if self.panics[w] >= QUARANTINE_PANICS {
                self.respawn(w);
            }
        }
        self.epochs_run += 1;
    }

    fn run_epoch_ticketed(
        &mut self,
        spec: &EpochSpec<'_>,
        tasks: EpochTasks<'_>,
        deltas: &mut [Vec<i64>],
        overlap: &mut dyn FnMut(),
        commit: &mut dyn FnMut(usize, &[i64], usize),
    ) {
        check_tasks(&tasks, deltas);
        assert!(
            tasks.assign.len() <= self.senders.len(),
            "schedule uses {} worker slots but the pool has {} workers",
            tasks.assign.len(),
            self.senders.len()
        );
        tasks.nanos.fill(0);
        tasks.worker_nanos.fill(0);
        let n = tasks.blocks.len();
        // Scatter, exactly as the barrier path — but with per-task
        // completion messages switched on.
        let queue: *const AtomicUsize = if tasks.steal {
            self.steal_cursor.store(0, Ordering::Relaxed);
            &self.steal_cursor
        } else {
            std::ptr::null()
        };
        let blocks_ptr = tasks.blocks.as_mut_ptr();
        let deltas_ptr = deltas.as_mut_ptr();
        let nanos_ptr = tasks.nanos.as_mut_ptr();
        let mut submitted = 0usize;
        for (w, list) in tasks.assign.iter().enumerate() {
            let busy_slot = if tasks.steal { w < n } else { !list.is_empty() };
            if !busy_slot {
                continue;
            }
            let job = Job {
                blocks: blocks_ptr,
                ids: tasks.ids.as_ptr(),
                deltas: deltas_ptr,
                nanos: nanos_ptr,
                assign: list.as_ptr(),
                assign_len: list.len(),
                queue,
                n_tasks: n,
                doc: spec.doc.base_ptr(),
                doc_rows: spec.doc.rows(),
                emit: spec.emit.base_ptr(),
                emit_rows: spec.emit.rows(),
                snapshot: spec.snapshot.as_ptr(),
                h: spec.h,
                seed: spec.seed,
                sweep: spec.sweep,
                kernel: spec.kernel,
                worker: w,
                per_task: true,
                trace: trace_ptr(spec),
                epoch: spec.obs.epoch,
                family: spec.obs.family,
            };
            self.senders[w].send(job).expect("pool worker died");
            submitted += 1;
        }
        // Dispatch done — the caller's overlapped IO (spill
        // release/prefetch) runs now, in the shadow of sampling.
        overlap();
        // Streaming gather: per-task tickets interleave with job
        // completions on the shared channel; fold the contiguous ready
        // prefix as it forms, so commit work hides inside the epoch's
        // sampling tail instead of serializing after it.
        let mut committer = TicketCommitter::new(n);
        let mut job_panicked = false;
        let mut failed: Vec<u32> = Vec::new();
        let mut jobs_done = 0usize;
        while jobs_done < submitted {
            match self.done_rx.recv().expect("pool worker died") {
                Done::Task { task, ok } => {
                    if ok {
                        committer.mark_ready(task);
                        while let Some(c) = committer.next_committable() {
                            // SAFETY: ticket `c`'s completion message
                            // has been received; its claimer's last
                            // write to the delta slot happens-before
                            // that send, and a claimed slot is never
                            // touched again.
                            let delta = unsafe { (*deltas_ptr.add(c)).as_slice() };
                            commit(c, delta, committer.in_flight());
                            committer.advance();
                        }
                    }
                    // `!ok`: the ticket is revoked — the watermark
                    // stalls there until a retry round re-arms it.
                }
                Done::Job { worker, outcome, busy } => {
                    tasks.worker_nanos[worker] += busy;
                    match outcome {
                        Some(f) => {
                            self.panics[worker] += f.len() as u64;
                            failed.extend_from_slice(&f);
                        }
                        None => job_panicked = true,
                    }
                    jobs_done += 1;
                }
            }
        }
        assert!(!job_panicked, "a pool worker panicked during the epoch");
        // Retry rounds, as in the barrier path (job-level completions
        // only — the retry job runs with `per_task` off). Every task a
        // round recovers re-arms its revoked ticket; the retry derives
        // the same `(seed, sweep, partition)` RNG stream, so the delta
        // it commits is the one an undisturbed run would have, and the
        // watermark drains in canonical ticket order regardless of how
        // many rounds it takes.
        let mut round = 1u32;
        while !failed.is_empty() {
            assert!(
                round < MAX_TASK_ATTEMPTS,
                "tasks {failed:?} panicked {MAX_TASK_ATTEMPTS} times; giving up"
            );
            failed.sort_unstable();
            if let Some(tr) = spec.obs.trace {
                // Retry markers land on the coordinator lane — the
                // retry job itself emits its Task spans from the target
                // worker's lane, like any other job.
                for &i in &failed {
                    trace_instant(
                        spec,
                        tr.coord_lane() as usize,
                        EventKind::Retry,
                        i as usize,
                        tasks.ids[i as usize],
                        round as u64,
                    );
                }
            }
            let target = (0..self.senders.len())
                .min_by_key(|&w| (self.panics[w], w))
                .expect("pool has workers");
            self.retries += failed.len() as u64;
            let job = Job {
                blocks: tasks.blocks.as_mut_ptr(),
                ids: tasks.ids.as_ptr(),
                deltas: deltas.as_mut_ptr(),
                nanos: tasks.nanos.as_mut_ptr(),
                assign: failed.as_ptr(),
                assign_len: failed.len(),
                queue: std::ptr::null(),
                n_tasks: n,
                doc: spec.doc.base_ptr(),
                doc_rows: spec.doc.rows(),
                emit: spec.emit.base_ptr(),
                emit_rows: spec.emit.rows(),
                snapshot: spec.snapshot.as_ptr(),
                h: spec.h,
                seed: spec.seed,
                sweep: spec.sweep,
                kernel: spec.kernel,
                worker: target,
                per_task: false,
                trace: trace_ptr(spec),
                epoch: spec.obs.epoch,
                family: spec.obs.family,
            };
            self.senders[target].send(job).expect("pool worker died");
            // `failed` must stay alive and unmodified until this recv
            // returns: the worker reads `assign` through a raw pointer.
            let (w, outcome, busy) = self.recv_job();
            tasks.worker_nanos[w] += busy;
            let still = match outcome {
                Some(f) => f,
                None => panic!("a pool worker panicked during the epoch"),
            };
            self.panics[w] += still.len() as u64;
            // Re-arm the tickets this round recovered, then drain the
            // watermark (the retry worker is idle now, so direct delta
            // reads are race-free).
            for &i in &failed {
                if !still.contains(&i) {
                    committer.mark_ready(i as usize);
                }
            }
            while let Some(c) = committer.next_committable() {
                commit(c, &deltas[c], committer.in_flight());
                committer.advance();
            }
            failed = still;
            round += 1;
        }
        assert!(committer.finished(), "ticketed epoch left uncommitted tickets");
        for w in 0..self.senders.len() {
            if self.panics[w] >= QUARANTINE_PANICS {
                self.respawn(w);
            }
        }
        self.epochs_run += 1;
    }

    fn retries(&self) -> u64 {
        self.retries
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        // Disconnect the job channels; workers fall out of their recv
        // loop and exit.
        self.senders.clear();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Per-trainer executor cache: the stateless modes live inline, the pool
/// is created lazily on the first `Pooled` epoch and then reused for the
/// trainer's lifetime (including across BoT's two phases, which share
/// the schedule's worker count and `K`).
pub struct EngineCache {
    workers: usize,
    seq: SequentialExec,
    thr: ThreadedExec,
    pool: Option<WorkerPool>,
}

impl EngineCache {
    pub fn new(workers: usize) -> Self {
        Self {
            workers,
            seq: SequentialExec::default(),
            thr: ThreadedExec::default(),
            pool: None,
        }
    }

    /// The executor for `mode`, constructing the persistent pool on
    /// first use.
    pub fn get(&mut self, mode: ExecMode) -> &mut dyn Executor {
        let workers = self.workers;
        match mode {
            ExecMode::Sequential => &mut self.seq,
            ExecMode::Threaded => &mut self.thr,
            ExecMode::Pooled => self.pool.get_or_insert_with(|| WorkerPool::new(workers)),
        }
    }

    /// The persistent pool, if a `Pooled` epoch has run.
    pub fn pool(&self) -> Option<&WorkerPool> {
        self.pool.as_ref()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gibbs::counts::LdaCounts;
    use crate::partition::scheme::Cell;
    use crate::scheduler::schedule::identity_assign;

    /// Two disjoint partitions (disjoint doc AND word groups), like one
    /// diagonal of a 2×2 plan.
    fn diagonal_fixture(k: usize, seed: u64) -> (Vec<TokenBlock>, LdaCounts, Hyper) {
        let mut rng = Rng::new(seed);
        let cells0 = [
            Cell { doc: 0, word: 0, count: 30 },
            Cell { doc: 1, word: 1, count: 20 },
        ];
        let cells1 = [
            Cell { doc: 2, word: 2, count: 25 },
            Cell { doc: 3, word: 3, count: 15 },
        ];
        let blocks = vec![
            TokenBlock::from_cells(&cells0, k, &mut rng),
            TokenBlock::from_cells(&cells1, k, &mut rng),
        ];
        let mut counts = LdaCounts::zeros(4, 4, k);
        for b in &blocks {
            counts.absorb(b);
        }
        (blocks, counts, Hyper::new(k, 0.5, 0.1, 4))
    }

    fn run_case(
        mode: ExecMode,
        kernel: KernelKind,
        epochs: usize,
        assign_of: impl Fn(usize) -> Vec<Vec<u32>>,
        workers: usize,
        steal: bool,
        seed: u64,
    ) -> (Vec<TokenBlock>, LdaCounts) {
        let k = 4;
        let (mut blocks, mut counts, h) = diagonal_fixture(k, 7);
        let ids = [0u64, 1];
        let mut engines = EngineCache::new(workers);
        let mut deltas = vec![vec![0i64; k]; 2];
        let mut nanos = vec![0u64; 2];
        let mut snapshot = counts.topic.clone();
        for e in 0..epochs {
            let assign = assign_of(e);
            let mut worker_nanos = vec![0u64; assign.len()];
            let spec = EpochSpec {
                doc: SharedRows::new(&mut counts.doc_topic, k),
                emit: SharedRows::new(&mut counts.word_topic, k),
                snapshot: &snapshot,
                h,
                seed,
                sweep: e,
                kernel,
                obs: TaskObs::default(),
            };
            let tasks = EpochTasks {
                blocks: &mut blocks,
                ids: &ids,
                assign: &assign,
                nanos: &mut nanos,
                worker_nanos: &mut worker_nanos,
                steal,
            };
            engines.get(mode).run_epoch(&spec, tasks, &mut deltas);
            // Telemetry conservation: every task's nanos is stamped by
            // exactly one claimer, so per-worker busy sums to the
            // per-task total in every mode.
            let task_total: u64 = nanos.iter().sum();
            let busy_total: u64 = worker_nanos.iter().sum();
            assert_eq!(task_total, busy_total, "{mode:?} steal={steal}");
            merge_deltas(&mut counts.topic, &mut snapshot, &deltas);
        }
        (blocks, counts)
    }

    fn run_kernel_assignment_stealing(
        mode: ExecMode,
        kernel: KernelKind,
        epochs: usize,
        assign_of: impl Fn(usize) -> Vec<Vec<u32>>,
        workers: usize,
        steal: bool,
    ) -> (Vec<TokenBlock>, LdaCounts) {
        run_case(mode, kernel, epochs, assign_of, workers, steal, 99)
    }

    fn run_kernel_assignment(
        mode: ExecMode,
        kernel: KernelKind,
        epochs: usize,
        assign_of: impl Fn(usize) -> Vec<Vec<u32>>,
        workers: usize,
    ) -> (Vec<TokenBlock>, LdaCounts) {
        run_case(mode, kernel, epochs, assign_of, workers, false, 99)
    }

    fn run_assignment(
        mode: ExecMode,
        epochs: usize,
        assign_of: impl Fn(usize) -> Vec<Vec<u32>>,
        workers: usize,
    ) -> (Vec<TokenBlock>, LdaCounts) {
        run_kernel_assignment(mode, KernelKind::Dense, epochs, assign_of, workers)
    }

    fn run_mode(mode: ExecMode, epochs: usize) -> (Vec<TokenBlock>, LdaCounts) {
        run_assignment(mode, epochs, |_| identity_assign(2), 2)
    }

    /// Ticketed-protocol mirror of `run_case`: drives the same epochs
    /// through `run_epoch_ticketed`, folding each ticket's delta into
    /// the topic totals via `commit_delta` and republishing the
    /// snapshot once per epoch — the trainer-side ticketed protocol.
    /// Also pins the executor contract: `overlap` fires exactly once
    /// per epoch, tickets commit in strictly ascending order, and the
    /// final ticket commits with nothing left in flight.
    fn run_case_ticketed(
        mode: ExecMode,
        kernel: KernelKind,
        epochs: usize,
        assign_of: impl Fn(usize) -> Vec<Vec<u32>>,
        workers: usize,
        steal: bool,
        seed: u64,
    ) -> (Vec<TokenBlock>, LdaCounts) {
        let k = 4;
        let (mut blocks, mut counts, h) = diagonal_fixture(k, 7);
        let n = blocks.len();
        let ids = [0u64, 1];
        let mut engines = EngineCache::new(workers);
        let mut deltas = vec![vec![0i64; k]; n];
        let mut nanos = vec![0u64; n];
        let mut snapshot = counts.topic.clone();
        for e in 0..epochs {
            let assign = assign_of(e);
            let mut worker_nanos = vec![0u64; assign.len()];
            let spec = EpochSpec {
                doc: SharedRows::new(&mut counts.doc_topic, k),
                emit: SharedRows::new(&mut counts.word_topic, k),
                snapshot: &snapshot,
                h,
                seed,
                sweep: e,
                kernel,
                obs: TaskObs::default(),
            };
            let tasks = EpochTasks {
                blocks: &mut blocks,
                ids: &ids,
                assign: &assign,
                nanos: &mut nanos,
                worker_nanos: &mut worker_nanos,
                steal,
            };
            let mut overlaps = 0u32;
            let mut next_ticket = 0usize;
            let topic = &mut counts.topic;
            engines.get(mode).run_epoch_ticketed(
                &spec,
                tasks,
                &mut deltas,
                &mut || overlaps += 1,
                &mut |t, delta, in_flight| {
                    assert_eq!(t, next_ticket, "tickets commit in strict order");
                    next_ticket = t + 1;
                    assert!(in_flight < n, "in_flight counts only unsampled tasks");
                    if t + 1 == n {
                        assert_eq!(in_flight, 0, "last ticket commits after drain");
                    }
                    commit_delta(topic, delta);
                },
            );
            assert_eq!(overlaps, 1, "overlap hook fires exactly once");
            assert_eq!(next_ticket, n, "every ticket committed");
            let task_total: u64 = nanos.iter().sum();
            let busy_total: u64 = worker_nanos.iter().sum();
            assert_eq!(task_total, busy_total, "{mode:?} ticketed steal={steal}");
            snapshot.copy_from_slice(&counts.topic);
        }
        (blocks, counts)
    }

    #[test]
    fn ticketed_matches_barrier_for_every_mode_and_kernel() {
        // The ticketed protocol changes when deltas fold, never what
        // they fold to: for each kernel, every executor under the
        // ticketed protocol (static and stealing, plus a packed task
        // list) matches the barrier Sequential oracle bit for bit.
        for kernel in KernelKind::all() {
            let (bs, cs) =
                run_case(ExecMode::Sequential, kernel, 3, |_| identity_assign(2), 2, false, 99);
            for mode in [ExecMode::Sequential, ExecMode::Threaded, ExecMode::Pooled] {
                for steal in [false, true] {
                    let (b, c) = run_case_ticketed(
                        mode,
                        kernel,
                        3,
                        |_| identity_assign(2),
                        2,
                        steal,
                        99,
                    );
                    for (x, y) in bs.iter().zip(b.iter()) {
                        assert_eq!(x.z, y.z, "{kernel:?} {mode:?} steal={steal}");
                    }
                    assert_eq!(cs.doc_topic, c.doc_topic, "{kernel:?} {mode:?} steal={steal}");
                    assert_eq!(cs.word_topic, c.word_topic, "{kernel:?} {mode:?} steal={steal}");
                    assert_eq!(cs.topic, c.topic, "{kernel:?} {mode:?} steal={steal}");
                }
            }
            // A packed task list (both tasks on one worker) changes
            // nothing under the ticketed protocol either.
            let (bp, cp) =
                run_case_ticketed(ExecMode::Pooled, kernel, 3, |_| vec![vec![0, 1]], 1, false, 99);
            for (x, y) in bs.iter().zip(bp.iter()) {
                assert_eq!(x.z, y.z, "{kernel:?} ticketed packed");
            }
            assert_eq!(cs.topic, cp.topic, "{kernel:?} ticketed packed");
            let refs: Vec<&TokenBlock> = bp.iter().collect();
            assert!(cp.check_consistency(&refs).is_ok(), "{kernel:?}");
        }
    }

    #[test]
    fn all_executors_agree_bit_for_bit() {
        let (bs, cs) = run_mode(ExecMode::Sequential, 4);
        let (bt, ct) = run_mode(ExecMode::Threaded, 4);
        let (bp, cp) = run_mode(ExecMode::Pooled, 4);
        for (a, b) in bs.iter().zip(bt.iter()) {
            assert_eq!(a.z, b.z);
        }
        for (a, b) in bs.iter().zip(bp.iter()) {
            assert_eq!(a.z, b.z);
        }
        assert_eq!(cs.doc_topic, ct.doc_topic);
        assert_eq!(cs.doc_topic, cp.doc_topic);
        assert_eq!(cs.word_topic, cp.word_topic);
        assert_eq!(cs.topic, cp.topic);
        assert_eq!(cs.topic, ct.topic);
    }

    #[test]
    fn all_executors_agree_for_every_kernel() {
        // The executor bit-identity guarantee is kernel-independent:
        // for each kernel kind, Sequential/Threaded/Pooled and packed
        // task lists produce identical assignments and counts.
        for kernel in KernelKind::all() {
            let (bs, cs) =
                run_kernel_assignment(ExecMode::Sequential, kernel, 3, |_| identity_assign(2), 2);
            for mode in [ExecMode::Threaded, ExecMode::Pooled] {
                let (b, c) = run_kernel_assignment(mode, kernel, 3, |_| identity_assign(2), 2);
                for (x, y) in bs.iter().zip(b.iter()) {
                    assert_eq!(x.z, y.z, "{:?} {mode:?}", kernel);
                }
                assert_eq!(cs.doc_topic, c.doc_topic, "{:?} {mode:?}", kernel);
                assert_eq!(cs.word_topic, c.word_topic, "{:?} {mode:?}", kernel);
                assert_eq!(cs.topic, c.topic, "{:?} {mode:?}", kernel);
            }
            // Packing both tasks onto one worker changes nothing.
            let (bp, cp) =
                run_kernel_assignment(ExecMode::Pooled, kernel, 3, |_| vec![vec![0, 1]], 1);
            for (x, y) in bs.iter().zip(bp.iter()) {
                assert_eq!(x.z, y.z, "{:?} packed", kernel);
            }
            assert_eq!(cs.topic, cp.topic, "{:?} packed", kernel);
            let refs: Vec<&TokenBlock> = bp.iter().collect();
            assert!(cp.check_consistency(&refs).is_ok(), "{:?}", kernel);
        }
    }

    #[test]
    fn kernels_can_be_switched_between_epochs() {
        // A KernelSlot rebuilds only on kind changes; switching kinds
        // between epochs must keep counts consistent.
        let seq = [KernelKind::Dense, KernelKind::Sparse, KernelKind::Alias, KernelKind::Sparse];
        let k = 4;
        let (mut blocks, mut counts, h) = diagonal_fixture(k, 19);
        let ids = [0u64, 1];
        let assign = identity_assign(2);
        let mut engines = EngineCache::new(2);
        let mut deltas = vec![vec![0i64; k]; 2];
        let mut nanos = vec![0u64; 2];
        let mut worker_nanos = vec![0u64; 2];
        let mut snapshot = counts.topic.clone();
        for (e, &kernel) in seq.iter().enumerate() {
            let spec = EpochSpec {
                doc: SharedRows::new(&mut counts.doc_topic, k),
                emit: SharedRows::new(&mut counts.word_topic, k),
                snapshot: &snapshot,
                h,
                seed: 23,
                sweep: e,
                kernel,
                obs: TaskObs::default(),
            };
            let tasks = EpochTasks {
                blocks: &mut blocks,
                ids: &ids,
                assign: &assign,
                nanos: &mut nanos,
                worker_nanos: &mut worker_nanos,
                steal: false,
            };
            engines.get(ExecMode::Pooled).run_epoch(&spec, tasks, &mut deltas);
            merge_deltas(&mut counts.topic, &mut snapshot, &deltas);
        }
        let refs: Vec<&TokenBlock> = blocks.iter().collect();
        assert!(counts.check_consistency(&refs).is_ok());
    }

    #[test]
    fn packed_task_lists_agree_with_one_task_per_worker() {
        // Both tasks on one worker (a packed task list) must equal the
        // one-task-per-worker layout bit for bit, in every mode.
        let (b0, c0) = run_mode(ExecMode::Sequential, 3);
        for mode in [ExecMode::Sequential, ExecMode::Threaded, ExecMode::Pooled] {
            let (b1, c1) = run_assignment(mode, 3, |_| vec![vec![0, 1]], 1);
            for (a, b) in b0.iter().zip(b1.iter()) {
                assert_eq!(a.z, b.z);
            }
            assert_eq!(c0.doc_topic, c1.doc_topic);
            assert_eq!(c0.word_topic, c1.word_topic);
            assert_eq!(c0.topic, c1.topic);
        }
        // Even alternating layouts between epochs changes nothing.
        let alternating = |e: usize| {
            if e % 2 == 0 {
                vec![vec![1, 0], vec![]]
            } else {
                identity_assign(2)
            }
        };
        let (b2, c2) = run_assignment(ExecMode::Pooled, 3, alternating, 2);
        for (a, b) in b0.iter().zip(b2.iter()) {
            assert_eq!(a.z, b.z);
        }
        assert_eq!(c0.topic, c2.topic);
    }

    #[test]
    #[should_panic(expected = "more than one worker")]
    fn duplicate_assignment_is_rejected() {
        let _ = run_assignment(ExecMode::Sequential, 1, |_| vec![vec![0, 0], vec![1]], 2);
    }

    #[test]
    #[should_panic(expected = "must cover every task")]
    fn incomplete_assignment_is_rejected() {
        let _ = run_assignment(ExecMode::Sequential, 1, |_| vec![vec![0], vec![]], 2);
    }

    #[test]
    #[should_panic(expected = "out of bounds")]
    fn out_of_bounds_assignment_is_rejected() {
        let _ = run_assignment(ExecMode::Sequential, 1, |_| vec![vec![0], vec![1, 7]], 2);
    }

    #[test]
    fn counts_stay_consistent_after_pooled_epochs() {
        let (blocks, counts) = run_mode(ExecMode::Pooled, 3);
        let refs: Vec<&TokenBlock> = blocks.iter().collect();
        assert!(counts.check_consistency(&refs).is_ok());
    }

    #[test]
    fn pool_counts_epochs_and_fault_free_runs_never_respawn() {
        let k = 4;
        let (mut blocks, mut counts, h) = diagonal_fixture(k, 11);
        let ids = [0u64, 1];
        let assign = identity_assign(2);
        let mut engines = EngineCache::new(2);
        let mut deltas = vec![vec![0i64; k]; 2];
        let mut nanos = vec![0u64; 2];
        let mut worker_nanos = vec![0u64; 2];
        let snapshot = counts.topic.clone();
        for e in 0..5 {
            let spec = EpochSpec {
                doc: SharedRows::new(&mut counts.doc_topic, k),
                emit: SharedRows::new(&mut counts.word_topic, k),
                snapshot: &snapshot,
                h,
                seed: 1,
                sweep: e,
                kernel: KernelKind::Dense,
                obs: TaskObs::default(),
            };
            let tasks = EpochTasks {
                blocks: &mut blocks,
                ids: &ids,
                assign: &assign,
                nanos: &mut nanos,
                worker_nanos: &mut worker_nanos,
                steal: false,
            };
            engines.get(ExecMode::Pooled).run_epoch(&spec, tasks, &mut deltas);
        }
        let pool = engines.pool().expect("pool materialized");
        assert_eq!(pool.workers(), 2);
        assert_eq!(pool.epochs_run(), 5);
        assert_eq!(pool.respawns(), 0, "no faults, no respawns");
        assert_eq!(pool.retries(), 0, "no faults, no retries");
    }

    #[test]
    fn sequential_mode_creates_no_pool() {
        let _ = run_mode(ExecMode::Sequential, 1);
        let engines = EngineCache::new(2);
        assert!(engines.pool().is_none());
    }

    #[test]
    fn pool_runs_narrow_epochs() {
        // A pool sized for W workers must accept an epoch that uses fewer
        // slots (empty task lists) without deadlocking.
        let k = 4;
        let (mut blocks, mut counts, h) = diagonal_fixture(k, 13);
        blocks.truncate(1);
        let ids = [0u64];
        let assign = [vec![0u32], Vec::new(), Vec::new()];
        let mut pool = WorkerPool::new(3);
        let mut deltas = vec![vec![0i64; k]];
        let mut nanos = vec![0u64; 1];
        let mut worker_nanos = vec![0u64; 3];
        let snapshot = counts.topic.clone();
        let spec = EpochSpec {
            doc: SharedRows::new(&mut counts.doc_topic, k),
            emit: SharedRows::new(&mut counts.word_topic, k),
            snapshot: &snapshot,
            h,
            seed: 5,
            sweep: 0,
            kernel: KernelKind::Dense,
            obs: TaskObs::default(),
        };
        let tasks = EpochTasks {
            blocks: &mut blocks,
            ids: &ids,
            assign: &assign,
            nanos: &mut nanos,
            worker_nanos: &mut worker_nanos,
            steal: false,
        };
        pool.run_epoch(&spec, tasks, &mut deltas);
        assert_eq!(pool.epochs_run(), 1);
        assert_eq!(deltas[0].iter().sum::<i64>(), 0, "deltas conserve tokens");
        assert_eq!(worker_nanos[1], 0, "idle slot reports no busy time");
        assert_eq!(worker_nanos[0], nanos[0], "busy slot owns the task's nanos");
    }

    #[test]
    fn stealing_agrees_with_static_in_every_mode() {
        // The stealing acceptance at executor level: for each kernel,
        // every executor with steal=true matches the static Sequential
        // oracle bit for bit, under both the identity layout and a
        // deliberately lopsided one (all tasks hinted onto worker 0,
        // which stealing redistributes at runtime).
        for kernel in KernelKind::all() {
            let (bs, cs) =
                run_kernel_assignment(ExecMode::Sequential, kernel, 3, |_| identity_assign(2), 2);
            for mode in [ExecMode::Sequential, ExecMode::Threaded, ExecMode::Pooled] {
                for assign_of in [
                    (|_: usize| identity_assign(2)) as fn(usize) -> Vec<Vec<u32>>,
                    |_: usize| vec![vec![0u32, 1], Vec::new()],
                ] {
                    let (b, c) = run_kernel_assignment_stealing(
                        mode, kernel, 3, assign_of, 2, true,
                    );
                    for (x, y) in bs.iter().zip(b.iter()) {
                        assert_eq!(x.z, y.z, "{kernel:?} {mode:?} steal");
                    }
                    assert_eq!(cs.doc_topic, c.doc_topic, "{kernel:?} {mode:?} steal");
                    assert_eq!(cs.word_topic, c.word_topic, "{kernel:?} {mode:?} steal");
                    assert_eq!(cs.topic, c.topic, "{kernel:?} {mode:?} steal");
                }
            }
        }
    }

    #[test]
    fn stealing_pool_runs_narrow_epochs() {
        // Stealing with more worker slots than tasks: only the first
        // `n` slots receive jobs; no deadlock, full coverage.
        let k = 4;
        let (mut blocks, mut counts, h) = diagonal_fixture(k, 17);
        let ids = [0u64, 1];
        let assign = [vec![0u32, 1], Vec::new(), Vec::new(), Vec::new()];
        let mut pool = WorkerPool::new(4);
        let mut deltas = vec![vec![0i64; k]; 2];
        let mut nanos = vec![0u64; 2];
        let mut worker_nanos = vec![0u64; 4];
        let snapshot = counts.topic.clone();
        let spec = EpochSpec {
            doc: SharedRows::new(&mut counts.doc_topic, k),
            emit: SharedRows::new(&mut counts.word_topic, k),
            snapshot: &snapshot,
            h,
            seed: 9,
            sweep: 0,
            kernel: KernelKind::Dense,
            obs: TaskObs::default(),
        };
        let tasks = EpochTasks {
            blocks: &mut blocks,
            ids: &ids,
            assign: &assign,
            nanos: &mut nanos,
            worker_nanos: &mut worker_nanos,
            steal: true,
        };
        pool.run_epoch(&spec, tasks, &mut deltas);
        assert_eq!(pool.epochs_run(), 1);
        assert!(nanos.iter().all(|&ns| ns > 0), "every task measured");
        assert_eq!(
            worker_nanos.iter().sum::<u64>(),
            nanos.iter().sum::<u64>(),
            "busy time conserves task time"
        );
    }

    /// Deterministic fault injection (see `crate::util::fault`). Fault
    /// keys lead with the epoch seed, and these tests use distinctive
    /// seeds, so the fault-free tests above (seeds 99, 23, …) can never
    /// consume an armed fault even though they run concurrently.
    #[cfg(feature = "failpoints")]
    mod fault_injection {
        use super::*;
        use crate::util::fault::{install, sites, Fault, FaultKind};

        /// One injected worker panic per epoch, at a chosen partition:
        /// every executor must contain it, roll the task back, retry it
        /// on the same RNG stream, and land bit-identical to the
        /// undisturbed Sequential oracle.
        #[test]
        fn injected_worker_panics_retry_bit_identically() {
            const SEED: u64 = 0xFA17_0001;
            let ident = |_: usize| identity_assign(2);
            let (bs, cs) =
                run_case(ExecMode::Sequential, KernelKind::Dense, 3, ident, 2, false, SEED);
            for mode in [ExecMode::Sequential, ExecMode::Threaded, ExecMode::Pooled] {
                let guard = install(vec![
                    Fault { site: "task", key: [SEED, 0, 0], kind: FaultKind::Panic },
                    Fault { site: "task", key: [SEED, 1, 1], kind: FaultKind::Panic },
                    Fault { site: "task", key: [SEED, 2, 0], kind: FaultKind::Panic },
                ]);
                let (b, c) = run_case(mode, KernelKind::Dense, 3, ident, 2, false, SEED);
                drop(guard);
                for (x, y) in bs.iter().zip(b.iter()) {
                    assert_eq!(x.z, y.z, "{mode:?}");
                }
                assert_eq!(cs.doc_topic, c.doc_topic, "{mode:?}");
                assert_eq!(cs.word_topic, c.word_topic, "{mode:?}");
                assert_eq!(cs.topic, c.topic, "{mode:?}");
            }
        }

        /// A crash *after* sampling but *before* commit (the `commit`
        /// failpoint), under the ticketed protocol: the contained panic
        /// revokes the ticket, the watermark stalls, nothing after the
        /// revoked ticket commits early, and the retry re-executes on
        /// the same RNG stream — bit-identical to the undisturbed
        /// barrier Sequential oracle. Mixed with a plain start-of-task
        /// crash to cover both fault surfaces in one run.
        #[test]
        fn precommit_crash_revokes_ticket_and_retries_bit_identically() {
            const SEED: u64 = 0xFA17_0031;
            let ident = |_: usize| identity_assign(2);
            let (bs, cs) =
                run_case(ExecMode::Sequential, KernelKind::Dense, 3, ident, 2, false, SEED);
            for mode in [ExecMode::Sequential, ExecMode::Threaded, ExecMode::Pooled] {
                let guard = install(vec![
                    Fault { site: sites::COMMIT, key: [SEED, 0, 0], kind: FaultKind::Panic },
                    Fault { site: sites::COMMIT, key: [SEED, 1, 1], kind: FaultKind::Panic },
                    Fault { site: sites::TASK, key: [SEED, 2, 0], kind: FaultKind::Panic },
                ]);
                let (b, c) = run_case_ticketed(mode, KernelKind::Dense, 3, ident, 2, false, SEED);
                drop(guard);
                for (x, y) in bs.iter().zip(b.iter()) {
                    assert_eq!(x.z, y.z, "{mode:?}");
                }
                assert_eq!(cs.doc_topic, c.doc_topic, "{mode:?}");
                assert_eq!(cs.word_topic, c.word_topic, "{mode:?}");
                assert_eq!(cs.topic, c.topic, "{mode:?}");
            }
        }

        #[test]
        #[should_panic(expected = "giving up")]
        fn a_task_that_always_panics_exhausts_its_budget() {
            const SEED: u64 = 0xFA17_0002;
            let fault = Fault { site: "task", key: [SEED, 0, 0], kind: FaultKind::Panic };
            let _guard = install(vec![fault; MAX_TASK_ATTEMPTS as usize]);
            let _ = run_case(
                ExecMode::Sequential,
                KernelKind::Dense,
                1,
                |_| identity_assign(2),
                2,
                false,
                SEED,
            );
        }

        fn run_pool_epochs(seed: u64, epochs: usize) -> (Vec<TokenBlock>, LdaCounts, WorkerPool) {
            let k = 4;
            let (mut blocks, mut counts, h) = diagonal_fixture(k, 11);
            let ids = [0u64, 1];
            let assign = identity_assign(2);
            let mut pool = WorkerPool::new(2);
            let mut deltas = vec![vec![0i64; k]; 2];
            let mut nanos = vec![0u64; 2];
            let mut worker_nanos = vec![0u64; 2];
            let mut snapshot = counts.topic.clone();
            for e in 0..epochs {
                let spec = EpochSpec {
                    doc: SharedRows::new(&mut counts.doc_topic, k),
                    emit: SharedRows::new(&mut counts.word_topic, k),
                    snapshot: &snapshot,
                    h,
                    seed,
                    sweep: e,
                    kernel: KernelKind::Dense,
                    obs: TaskObs::default(),
                };
                let tasks = EpochTasks {
                    blocks: &mut blocks,
                    ids: &ids,
                    assign: &assign,
                    nanos: &mut nanos,
                    worker_nanos: &mut worker_nanos,
                    steal: false,
                };
                pool.run_epoch(&spec, tasks, &mut deltas);
                let task_total: u64 = nanos.iter().sum();
                let busy_total: u64 = worker_nanos.iter().sum();
                assert_eq!(task_total, busy_total, "telemetry conserved under retry");
                merge_deltas(&mut counts.topic, &mut snapshot, &deltas);
            }
            (blocks, counts, pool)
        }

        /// Worker 0's task panics on three consecutive sweeps: each panic
        /// is contained, retried on the healthier worker, and counted;
        /// after [`QUARANTINE_PANICS`] the offender's thread is replaced
        /// in place. Results still match the fault-free run exactly.
        #[test]
        fn pool_quarantines_and_respawns_a_repeat_offender() {
            const SEED: u64 = 0xFA17_0003;
            let (ob, oc, opool) = run_pool_epochs(SEED, 4);
            assert_eq!(opool.retries(), 0);
            assert_eq!(opool.respawns(), 0);
            let guard = install(vec![
                Fault { site: "task", key: [SEED, 0, 0], kind: FaultKind::Panic },
                Fault { site: "task", key: [SEED, 1, 0], kind: FaultKind::Panic },
                Fault { site: "task", key: [SEED, 2, 0], kind: FaultKind::Panic },
            ]);
            let (b, c, pool) = run_pool_epochs(SEED, 4);
            drop(guard);
            assert_eq!(pool.retries(), 3, "one re-execution per injected panic");
            assert_eq!(pool.respawns(), 1, "worker 0 crossed QUARANTINE_PANICS");
            assert_eq!(pool.workers(), 2, "slot count never changes");
            assert_eq!(pool.epochs_run(), 4);
            for (x, y) in ob.iter().zip(b.iter()) {
                assert_eq!(x.z, y.z);
            }
            assert_eq!(oc.doc_topic, c.doc_topic);
            assert_eq!(oc.word_topic, c.word_topic);
            assert_eq!(oc.topic, c.topic);
            let refs: Vec<&TokenBlock> = b.iter().collect();
            assert!(c.check_consistency(&refs).is_ok());
        }
    }
}
