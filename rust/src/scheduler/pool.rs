//! Persistent worker-pool execution of diagonal epochs.
//!
//! The legacy engine re-spawned `P` OS threads per epoch with
//! `std::thread::scope` — `P²` spawns per sweep — and allocated a fresh
//! topic-delta vector, probability buffer, and reciprocal cache for each
//! worker each epoch. That fixed overhead is exactly what the paper's
//! speedup measurements must *not* contain (it measures partition
//! quality, not thread-spawn latency), and what CLDA-style long-lived
//! workers avoid.
//!
//! This module provides the shared execution abstraction:
//!
//! * [`EpochSpec`] — everything one diagonal epoch needs: shared count
//!   matrices, the epoch-start topic snapshot, hyperparameters, and the
//!   RNG keying coordinates `(seed, sweep, epoch)`.
//! * [`Executor`] — the trait both trainers (`ParallelLda`, the BoT
//!   trainer) drive; one call runs one diagonal epoch.
//! * [`SequentialExec`] — in-order on the calling thread (the
//!   determinism oracle), with its own reusable scratch.
//! * [`ThreadedExec`] — the legacy scoped-spawn execution, kept as a
//!   baseline for the executor-overhead benchmark.
//! * [`WorkerPool`] — the persistent pool: `P` dedicated workers created
//!   once per trainer, each owning long-lived scratch (`probs`, `inv`,
//!   and its delta slot is coordinator-owned but reused), driven by a
//!   scatter/gather barrier over channels.
//!
//! # Barrier protocol
//!
//! Each worker has a private job channel (SPSC in practice); the
//! coordinator shares one completion channel. An epoch is:
//!
//! 1. **Scatter** — the coordinator sends worker `m` a lifetime-erased
//!    [`Job`] describing partition `m` of the running diagonal.
//! 2. **Sample** — each worker zeroes its delta slot, rebuilds its
//!    reciprocal cache from the snapshot, and runs the partition kernel
//!    with its persistent scratch buffers.
//! 3. **Gather** — the coordinator blocks until it has received exactly
//!    one completion per submitted job. Only then does it merge deltas
//!    and advance, so every raw pointer inside a `Job` outlives its use.
//!
//! # Determinism
//!
//! Worker RNG streams are keyed by `(seed, sweep, epoch, worker)` via
//! [`worker_rng`] — a pure function of the schedule position, never of
//! thread interleaving — and delta merging is integer addition
//! (commutative), so all three executors produce bit-identical counts.
//! The `pooled_equals_sequential` tests in `exec.rs` / `bot/parallel.rs`
//! pin this.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;

use crate::gibbs::sampler::{self, Hyper};
use crate::gibbs::tokens::TokenBlock;
use crate::scheduler::exec::ExecMode;
use crate::scheduler::shared::SharedRows;
use crate::util::rng::Rng;

/// The deterministic per-worker RNG stream for a schedule position.
/// Identical across executors — this is the determinism anchor.
#[inline]
pub fn worker_rng(seed: u64, sweep: usize, epoch: usize, worker: usize) -> Rng {
    Rng::stream(
        seed,
        ((sweep as u64) << 24) | ((epoch as u64) << 12) | worker as u64,
    )
}

/// One diagonal epoch's inputs, shared by every worker of the epoch.
///
/// `doc` rows are grouped by document partition, `emit` rows by the
/// emission-side partition (words for LDA and the BoT word phase,
/// timestamps for the BoT timestamp phase). `snapshot` is the
/// epoch-start view of the `k` topic totals backing `emit`.
pub struct EpochSpec<'a> {
    pub doc: SharedRows<'a>,
    pub emit: SharedRows<'a>,
    pub snapshot: &'a [u32],
    pub h: Hyper,
    /// Trainer/phase-salted RNG seed (see [`worker_rng`]).
    pub seed: u64,
    pub sweep: usize,
    pub epoch: usize,
}

/// Executes diagonal epochs. One call = one epoch: worker `m` sweeps
/// `diag[m]` and leaves its signed topic-total delta in `deltas[m]`
/// (length `h.k`, zeroed by the executor). The caller merges deltas at
/// the barrier.
pub trait Executor {
    fn run_epoch(
        &mut self,
        spec: &EpochSpec<'_>,
        diag: &mut [TokenBlock],
        deltas: &mut [Vec<i64>],
    );
}

/// The barrier merge shared by the trainers: fold every worker's signed
/// delta into the authoritative topic totals *and* the double-buffered
/// snapshot (which becomes the next epoch's read view — no re-clone).
/// Integer addition commutes, so merge order never affects results.
pub fn merge_deltas(totals: &mut [u32], snapshot: &mut [u32], deltas: &[Vec<i64>]) {
    for delta in deltas {
        for (t, &d) in delta.iter().enumerate() {
            let v = totals[t] as i64 + d;
            debug_assert!(v >= 0, "topic total went negative");
            totals[t] = v as u32;
            snapshot[t] = v as u32;
        }
    }
}

/// The worker body shared by all executors: zero the delta slot, derive
/// the positional RNG stream, run the partition kernel with the given
/// scratch.
fn run_worker(
    spec: &EpochSpec<'_>,
    m: usize,
    block: &mut TokenBlock,
    delta: &mut [i64],
    probs: &mut Vec<f32>,
    inv: &mut Vec<f32>,
) {
    debug_assert_eq!(delta.len(), spec.h.k);
    delta.fill(0);
    let mut rng = worker_rng(spec.seed, spec.sweep, spec.epoch, m);
    sampler::sweep_partition(
        block,
        // SAFETY: the diagonal non-conflict invariant — block `m`'s
        // tokens all lie in partition `(m, (m+l) mod P)`, so its doc
        // rows and emission rows are disjoint from every other worker's
        // for the duration of the epoch (PartitionMap construction).
        |d| unsafe { spec.doc.row_ptr(d) },
        |w| unsafe { spec.emit.row_ptr(w) },
        spec.snapshot,
        delta,
        &spec.h,
        &mut rng,
        probs,
        inv,
    );
}

/// In-order execution on the calling thread. The determinism oracle for
/// the parallel modes, and the zero-overhead mode for single-core boxes;
/// owns its scratch so repeated sweeps allocate nothing.
#[derive(Default)]
pub struct SequentialExec {
    probs: Vec<f32>,
    inv: Vec<f32>,
}

impl Executor for SequentialExec {
    fn run_epoch(
        &mut self,
        spec: &EpochSpec<'_>,
        diag: &mut [TokenBlock],
        deltas: &mut [Vec<i64>],
    ) {
        for (m, (block, delta)) in diag.iter_mut().zip(deltas.iter_mut()).enumerate() {
            run_worker(spec, m, block, delta, &mut self.probs, &mut self.inv);
        }
    }
}

/// Legacy execution: one scoped OS thread spawned per partition per
/// epoch, with per-spawn scratch allocation. Kept as the baseline the
/// executor-overhead benchmark compares [`WorkerPool`] against.
#[derive(Default)]
pub struct ThreadedExec;

impl Executor for ThreadedExec {
    fn run_epoch(
        &mut self,
        spec: &EpochSpec<'_>,
        diag: &mut [TokenBlock],
        deltas: &mut [Vec<i64>],
    ) {
        std::thread::scope(|s| {
            for (m, (block, delta)) in diag.iter_mut().zip(deltas.iter_mut()).enumerate() {
                s.spawn(move || {
                    let mut probs = Vec::new();
                    let mut inv = Vec::new();
                    run_worker(spec, m, block, delta, &mut probs, &mut inv);
                });
            }
        });
    }
}

/// A lifetime-erased epoch assignment for one pool worker. All pointers
/// are guaranteed valid (and the rows they reach exclusively owned) until
/// the coordinator has received this job's completion signal.
struct Job {
    block: *mut TokenBlock,
    doc: *mut f32,
    /// Row count of `doc` (debug bounds parity with `SharedRows::row_ptr`).
    doc_rows: usize,
    emit: *mut f32,
    /// Row count of `emit`.
    emit_rows: usize,
    snapshot: *const u32,
    delta: *mut i64,
    h: Hyper,
    seed: u64,
    sweep: usize,
    epoch: usize,
    worker: usize,
}

// SAFETY: Job transfers *exclusive logical ownership* of `block`, the
// delta slot, and the job's row groups to exactly one worker for the
// duration of one epoch; the coordinator's gather barrier sequences all
// other access. The snapshot is read-only for the epoch.
unsafe impl Send for Job {}

fn worker_loop(rx: Receiver<Job>, done: Sender<(usize, bool)>) {
    // Long-lived scratch: sized on first epoch, reused forever after.
    let mut probs: Vec<f32> = Vec::new();
    let mut inv: Vec<f32> = Vec::new();
    while let Ok(job) = rx.recv() {
        let k = job.h.k;
        // Catch panics so a failed debug assertion surfaces as a
        // coordinator panic instead of a deadlocked gather barrier.
        let ok = catch_unwind(AssertUnwindSafe(|| {
            // SAFETY: see `Job` — exclusive ownership until the done
            // signal below is observed. Rebuilding an `EpochSpec` routes
            // the pooled path through the same `run_worker` body (and
            // `SharedRows` bounds checks) as the other executors.
            let block = unsafe { &mut *job.block };
            let snapshot = unsafe { std::slice::from_raw_parts(job.snapshot, k) };
            let delta = unsafe { std::slice::from_raw_parts_mut(job.delta, k) };
            let spec = EpochSpec {
                doc: unsafe { SharedRows::from_raw(job.doc, job.doc_rows, k) },
                emit: unsafe { SharedRows::from_raw(job.emit, job.emit_rows, k) },
                snapshot,
                h: job.h,
                seed: job.seed,
                sweep: job.sweep,
                epoch: job.epoch,
            };
            run_worker(&spec, job.worker, block, delta, &mut probs, &mut inv);
        }))
        .is_ok();
        if done.send((job.worker, ok)).is_err() {
            break; // coordinator gone
        }
    }
}

/// A persistent pool of dedicated epoch workers.
///
/// Created once per trainer and reused for every epoch of every sweep:
/// no thread spawns, no scratch allocation, and no topic-snapshot clone
/// on the steady-state path. Workers block on their job channel between
/// epochs, so an idle pool costs nothing but memory.
pub struct WorkerPool {
    senders: Vec<Sender<Job>>,
    done_rx: Receiver<(usize, bool)>,
    handles: Vec<JoinHandle<()>>,
    epochs_run: u64,
}

impl WorkerPool {
    /// Spawn `workers` dedicated threads. This is the only place the
    /// pool creates threads — every subsequent epoch reuses them.
    pub fn new(workers: usize) -> Self {
        assert!(workers > 0, "pool needs at least one worker");
        let (done_tx, done_rx) = channel();
        let mut senders = Vec::with_capacity(workers);
        let mut handles = Vec::with_capacity(workers);
        for _ in 0..workers {
            let (tx, rx) = channel::<Job>();
            let done = done_tx.clone();
            handles.push(std::thread::spawn(move || worker_loop(rx, done)));
            senders.push(tx);
        }
        Self {
            senders,
            done_rx,
            handles,
            epochs_run: 0,
        }
    }

    /// Number of live pool workers (constant for the pool's lifetime —
    /// the pool never respawns).
    pub fn workers(&self) -> usize {
        self.senders.len()
    }

    /// Total diagonal epochs this pool has executed. Monotone over the
    /// pool's lifetime; tests use it to prove the same pool served every
    /// sweep.
    pub fn epochs_run(&self) -> u64 {
        self.epochs_run
    }
}

impl Executor for WorkerPool {
    fn run_epoch(
        &mut self,
        spec: &EpochSpec<'_>,
        diag: &mut [TokenBlock],
        deltas: &mut [Vec<i64>],
    ) {
        let n = diag.len();
        assert!(
            n <= self.senders.len(),
            "diagonal has {n} partitions but the pool has {} workers",
            self.senders.len()
        );
        assert_eq!(n, deltas.len(), "one delta slot per partition");
        // Scatter.
        for (m, (block, delta)) in diag.iter_mut().zip(deltas.iter_mut()).enumerate() {
            debug_assert_eq!(delta.len(), spec.h.k);
            let job = Job {
                block: block as *mut TokenBlock,
                doc: spec.doc.base_ptr(),
                doc_rows: spec.doc.rows(),
                emit: spec.emit.base_ptr(),
                emit_rows: spec.emit.rows(),
                snapshot: spec.snapshot.as_ptr(),
                delta: delta.as_mut_ptr(),
                h: spec.h,
                seed: spec.seed,
                sweep: spec.sweep,
                epoch: spec.epoch,
                worker: m,
            };
            self.senders[m].send(job).expect("pool worker died");
        }
        // Gather barrier: exactly one completion per submitted job. After
        // this loop no worker holds any pointer from this epoch.
        let mut panicked = false;
        for _ in 0..n {
            let (_, ok) = self.done_rx.recv().expect("pool worker died");
            panicked |= !ok;
        }
        assert!(!panicked, "a pool worker panicked during the epoch");
        self.epochs_run += 1;
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        // Disconnect the job channels; workers fall out of their recv
        // loop and exit.
        self.senders.clear();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Per-trainer executor cache: the stateless modes live inline, the pool
/// is created lazily on the first `Pooled` epoch and then reused for the
/// trainer's lifetime (including across BoT's two phases, which share
/// `P` and `K`).
pub struct EngineCache {
    workers: usize,
    seq: SequentialExec,
    thr: ThreadedExec,
    pool: Option<WorkerPool>,
}

impl EngineCache {
    pub fn new(workers: usize) -> Self {
        Self {
            workers,
            seq: SequentialExec::default(),
            thr: ThreadedExec,
            pool: None,
        }
    }

    /// The executor for `mode`, constructing the persistent pool on
    /// first use.
    pub fn get(&mut self, mode: ExecMode) -> &mut dyn Executor {
        let workers = self.workers;
        match mode {
            ExecMode::Sequential => &mut self.seq,
            ExecMode::Threaded => &mut self.thr,
            ExecMode::Pooled => self.pool.get_or_insert_with(|| WorkerPool::new(workers)),
        }
    }

    /// The persistent pool, if a `Pooled` epoch has run.
    pub fn pool(&self) -> Option<&WorkerPool> {
        self.pool.as_ref()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gibbs::counts::LdaCounts;
    use crate::partition::scheme::Cell;

    /// Two disjoint partitions (disjoint doc AND word groups), like one
    /// diagonal of a 2×2 plan.
    fn diagonal_fixture(k: usize, seed: u64) -> (Vec<TokenBlock>, LdaCounts, Hyper) {
        let mut rng = Rng::new(seed);
        let cells0 = [
            Cell { doc: 0, word: 0, count: 30 },
            Cell { doc: 1, word: 1, count: 20 },
        ];
        let cells1 = [
            Cell { doc: 2, word: 2, count: 25 },
            Cell { doc: 3, word: 3, count: 15 },
        ];
        let blocks = vec![
            TokenBlock::from_cells(&cells0, k, &mut rng),
            TokenBlock::from_cells(&cells1, k, &mut rng),
        ];
        let mut counts = LdaCounts::zeros(4, 4, k);
        for b in &blocks {
            counts.absorb(b);
        }
        (blocks, counts, Hyper::new(k, 0.5, 0.1, 4))
    }

    fn run_mode(mode: ExecMode, epochs: usize) -> (Vec<TokenBlock>, LdaCounts) {
        let k = 4;
        let (mut blocks, mut counts, h) = diagonal_fixture(k, 7);
        let mut engines = EngineCache::new(2);
        let mut deltas = vec![vec![0i64; k]; 2];
        let mut snapshot = counts.topic.clone();
        for e in 0..epochs {
            let spec = EpochSpec {
                doc: SharedRows::new(&mut counts.doc_topic, k),
                emit: SharedRows::new(&mut counts.word_topic, k),
                snapshot: &snapshot,
                h,
                seed: 99,
                sweep: 0,
                epoch: e,
            };
            engines.get(mode).run_epoch(&spec, &mut blocks, &mut deltas);
            merge_deltas(&mut counts.topic, &mut snapshot, &deltas);
        }
        (blocks, counts)
    }

    #[test]
    fn all_executors_agree_bit_for_bit() {
        let (bs, cs) = run_mode(ExecMode::Sequential, 4);
        let (bt, ct) = run_mode(ExecMode::Threaded, 4);
        let (bp, cp) = run_mode(ExecMode::Pooled, 4);
        for (a, b) in bs.iter().zip(bt.iter()) {
            assert_eq!(a.z, b.z);
        }
        for (a, b) in bs.iter().zip(bp.iter()) {
            assert_eq!(a.z, b.z);
        }
        assert_eq!(cs.doc_topic, ct.doc_topic);
        assert_eq!(cs.doc_topic, cp.doc_topic);
        assert_eq!(cs.word_topic, cp.word_topic);
        assert_eq!(cs.topic, cp.topic);
        assert_eq!(cs.topic, ct.topic);
    }

    #[test]
    fn counts_stay_consistent_after_pooled_epochs() {
        let (blocks, counts) = run_mode(ExecMode::Pooled, 3);
        let refs: Vec<&TokenBlock> = blocks.iter().collect();
        assert!(counts.check_consistency(&refs).is_ok());
    }

    #[test]
    fn pool_counts_epochs_and_never_respawns() {
        let k = 4;
        let (mut blocks, mut counts, h) = diagonal_fixture(k, 11);
        let mut engines = EngineCache::new(2);
        let mut deltas = vec![vec![0i64; k]; 2];
        let snapshot = counts.topic.clone();
        for e in 0..5 {
            let spec = EpochSpec {
                doc: SharedRows::new(&mut counts.doc_topic, k),
                emit: SharedRows::new(&mut counts.word_topic, k),
                snapshot: &snapshot,
                h,
                seed: 1,
                sweep: e,
                epoch: 0,
            };
            engines
                .get(ExecMode::Pooled)
                .run_epoch(&spec, &mut blocks, &mut deltas);
        }
        let pool = engines.pool().expect("pool materialized");
        assert_eq!(pool.workers(), 2);
        assert_eq!(pool.epochs_run(), 5);
    }

    #[test]
    fn sequential_mode_creates_no_pool() {
        let _ = run_mode(ExecMode::Sequential, 1);
        let engines = EngineCache::new(2);
        assert!(engines.pool().is_none());
    }

    #[test]
    fn pool_runs_narrow_diagonals() {
        // A pool sized for P workers must accept a diagonal with fewer
        // partitions (e.g. ragged plans) without deadlocking.
        let k = 4;
        let (mut blocks, mut counts, h) = diagonal_fixture(k, 13);
        blocks.truncate(1);
        let mut pool = WorkerPool::new(3);
        let mut deltas = vec![vec![0i64; k]];
        let snapshot = counts.topic.clone();
        let spec = EpochSpec {
            doc: SharedRows::new(&mut counts.doc_topic, k),
            emit: SharedRows::new(&mut counts.word_topic, k),
            snapshot: &snapshot,
            h,
            seed: 5,
            sweep: 0,
            epoch: 0,
        };
        pool.run_epoch(&spec, &mut blocks, &mut deltas);
        assert_eq!(pool.epochs_run(), 1);
        assert_eq!(deltas[0].iter().sum::<i64>(), 0, "deltas conserve tokens");
    }
}
