//! The parallel LDA trainer: diagonal epochs over a partition plan.

use std::time::Instant;

use crate::corpus::bow::BagOfWords;
use crate::gibbs::counts::LdaCounts;
use crate::gibbs::perplexity;
use crate::gibbs::sampler::{self, Hyper};
use crate::gibbs::tokens::TokenBlock;
use crate::partition::scheme::PartitionMap;
use crate::partition::Plan;
use crate::scheduler::shared::SharedRows;
use crate::util::rng::Rng;

/// Threaded = one OS thread per partition of the running diagonal;
/// Sequential = same schedule executed in-order on the calling thread
/// (identical results — worker RNG streams are keyed by position, not by
/// interleaving).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecMode {
    Threaded,
    Sequential,
}

/// Per-sweep timing/cost telemetry.
#[derive(Clone, Debug, Default)]
pub struct SweepStats {
    /// Wall time of each epoch (seconds).
    pub epoch_secs: Vec<f64>,
    /// Max worker token count per epoch (the paper's epoch cost).
    pub epoch_max_tokens: Vec<u64>,
    /// Sum of all workers' token counts (serial-equivalent work).
    pub total_tokens: u64,
}

impl SweepStats {
    /// Eq. 1-style measured cost: Σ_l max_m tokens(m, l).
    pub fn measured_cost(&self) -> u64 {
        self.epoch_max_tokens.iter().sum()
    }
}

/// Parallel partitioned collapsed-Gibbs LDA (Yan et al.'s algorithm over
/// the paper's partition plans).
pub struct ParallelLda {
    pub h: Hyper,
    pub counts: LdaCounts,
    pub p: usize,
    /// Token blocks, diagonal-major: `blocks[l][m]` is partition
    /// `(m, (m+l) mod P)`.
    blocks: Vec<Vec<TokenBlock>>,
    seed: u64,
    sweeps_done: usize,
}

impl ParallelLda {
    /// Random-initialize assignments under a partition plan.
    pub fn init(
        bow: &BagOfWords,
        plan: &Plan,
        k: usize,
        alpha: f32,
        beta: f32,
        seed: u64,
    ) -> Self {
        let p = plan.p;
        let map = PartitionMap::build(bow, plan);
        let mut rng = Rng::stream(seed, 0x1417);
        let mut blocks: Vec<Vec<TokenBlock>> = Vec::with_capacity(p);
        for l in 0..p {
            let mut diag = Vec::with_capacity(p);
            for (m, n) in map.diagonal(l) {
                diag.push(TokenBlock::from_cells(map.cells(m, n), k, &mut rng));
            }
            blocks.push(diag);
        }
        let mut counts = LdaCounts::zeros(bow.num_docs(), bow.num_words(), k);
        for diag in &blocks {
            for b in diag {
                counts.absorb(b);
            }
        }
        Self {
            h: Hyper::new(k, alpha, beta, bow.num_words()),
            counts,
            p,
            blocks,
            seed,
            sweeps_done: 0,
        }
    }

    /// One full Gibbs sweep = `P` diagonal epochs with barriers.
    pub fn sweep(&mut self, mode: ExecMode) -> SweepStats {
        let p = self.p;
        let k = self.h.k;
        let sweep_no = self.sweeps_done;
        let mut stats = SweepStats::default();

        for l in 0..p {
            let snapshot = self.counts.topic.clone();
            let epoch_started = Instant::now();
            let diag = &mut self.blocks[l];
            stats
                .epoch_max_tokens
                .push(diag.iter().map(|b| b.len() as u64).max().unwrap_or(0));
            stats.total_tokens += diag.iter().map(|b| b.len() as u64).sum::<u64>();

            let doc_rows = SharedRows::new(&mut self.counts.doc_topic, k);
            let word_rows = SharedRows::new(&mut self.counts.word_topic, k);
            let h = self.h;
            let seed = self.seed;

            let run_worker = |m: usize, block: &mut TokenBlock, snapshot: &[u32]| {
                let mut delta = vec![0i64; k];
                let mut probs = Vec::new();
                // Deterministic stream per (sweep, epoch, worker).
                let mut rng = Rng::stream(
                    seed ^ 0x50AB_71C5,
                    ((sweep_no as u64) << 24) | ((l as u64) << 12) | m as u64,
                );
                sampler::sweep_partition(
                    block,
                    // SAFETY: the block's tokens all lie in partition
                    // (m, (m+l) mod P); doc rows ∈ J_m and word rows ∈
                    // V_{(m+l) mod P}, disjoint across the diagonal's
                    // workers (PartitionMap construction).
                    |d| unsafe { doc_rows.row_ptr(d) },
                    |w| unsafe { word_rows.row_ptr(w) },
                    snapshot,
                    &mut delta,
                    &h,
                    &mut rng,
                    &mut probs,
                );
                delta
            };

            let deltas: Vec<Vec<i64>> = match mode {
                ExecMode::Sequential => diag
                    .iter_mut()
                    .enumerate()
                    .map(|(m, block)| run_worker(m, block, &snapshot))
                    .collect(),
                ExecMode::Threaded => std::thread::scope(|s| {
                    let handles: Vec<_> = diag
                        .iter_mut()
                        .enumerate()
                        .map(|(m, block)| {
                            let snapshot = &snapshot;
                            let run_worker = &run_worker;
                            s.spawn(move || run_worker(m, block, snapshot))
                        })
                        .collect();
                    handles.into_iter().map(|h| h.join().unwrap()).collect()
                }),
            };

            // Barrier: reconcile topic totals.
            for delta in deltas {
                for (t, d) in delta.into_iter().enumerate() {
                    let v = self.counts.topic[t] as i64 + d;
                    debug_assert!(v >= 0, "topic total went negative");
                    self.counts.topic[t] = v as u32;
                }
            }
            stats.epoch_secs.push(epoch_started.elapsed().as_secs_f64());
        }

        self.sweeps_done += 1;
        stats
    }

    /// Run `iters` sweeps; record perplexity every `eval_every` (0 = only
    /// at the end if `eval_every != 0`... never).
    pub fn train(
        &mut self,
        bow: &BagOfWords,
        iters: usize,
        eval_every: usize,
        mode: ExecMode,
    ) -> Vec<(usize, f64)> {
        let mut curve = Vec::new();
        for it in 1..=iters {
            self.sweep(mode);
            if eval_every > 0 && (it % eval_every == 0 || it == iters) {
                curve.push((it, self.perplexity(bow)));
            }
        }
        curve
    }

    pub fn perplexity(&self, bow: &BagOfWords) -> f64 {
        perplexity::perplexity(bow, &self.counts, &self.h)
    }

    /// Borrow all token blocks (test/diagnostic use).
    pub fn all_blocks(&self) -> Vec<&TokenBlock> {
        self.blocks.iter().flatten().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::synthetic::{generate, Profile};
    use crate::partition::{partition, Algorithm};

    fn setup(p: usize, seed: u64) -> (BagOfWords, ParallelLda) {
        let bow = generate(&Profile::tiny(), seed);
        let plan = partition(&bow, p, Algorithm::A3 { restarts: 3 }, seed);
        let lda = ParallelLda::init(&bow, &plan, 8, 0.5, 0.1, seed);
        (bow, lda)
    }

    #[test]
    fn init_absorbs_every_token() {
        let (bow, lda) = setup(4, 31);
        assert_eq!(lda.counts.total(), bow.num_tokens());
        assert!(lda
            .counts
            .check_consistency(&lda.all_blocks())
            .is_ok());
    }

    #[test]
    fn sweep_preserves_invariants() {
        let (bow, mut lda) = setup(3, 32);
        for _ in 0..5 {
            let stats = lda.sweep(ExecMode::Sequential);
            assert_eq!(stats.total_tokens, bow.num_tokens());
            assert_eq!(stats.epoch_secs.len(), 3);
        }
        assert_eq!(lda.counts.total(), bow.num_tokens());
        assert!(lda.counts.check_consistency(&lda.all_blocks()).is_ok());
    }

    #[test]
    fn threaded_equals_sequential() {
        let (_bow, mut a) = setup(4, 33);
        let (_bow2, mut b) = setup(4, 33);
        for _ in 0..3 {
            a.sweep(ExecMode::Threaded);
            b.sweep(ExecMode::Sequential);
        }
        assert_eq!(a.counts.doc_topic, b.counts.doc_topic);
        assert_eq!(a.counts.word_topic, b.counts.word_topic);
        assert_eq!(a.counts.topic, b.counts.topic);
    }

    #[test]
    fn parallel_training_reduces_perplexity() {
        let (bow, mut lda) = setup(4, 34);
        let p0 = lda.perplexity(&bow);
        let curve = lda.train(&bow, 30, 30, ExecMode::Sequential);
        let p_end = curve.last().unwrap().1;
        assert!(p_end < p0 * 0.9, "{p0} → {p_end}");
    }

    #[test]
    fn parallel_close_to_serial_perplexity() {
        // Table IV's claim in miniature: parallel and serial converge to
        // approximately the same training perplexity.
        let bow = generate(&Profile::tiny(), 35);
        let plan = partition(&bow, 4, Algorithm::A3 { restarts: 3 }, 35);
        let mut par = ParallelLda::init(&bow, &plan, 8, 0.5, 0.1, 35);
        let mut ser = crate::gibbs::serial::SerialLda::init(&bow, 8, 0.5, 0.1, 35);
        par.train(&bow, 40, 0, ExecMode::Sequential);
        ser.train(&bow, 40, 0);
        let pp = par.perplexity(&bow);
        let ps = ser.perplexity(&bow);
        let rel = (pp - ps).abs() / ps;
        assert!(rel < 0.05, "parallel {pp} vs serial {ps} (rel {rel})");
    }

    #[test]
    fn measured_cost_matches_plan_cost() {
        let bow = generate(&Profile::tiny(), 36);
        let plan = partition(&bow, 5, Algorithm::A1, 36);
        let mut lda = ParallelLda::init(&bow, &plan, 4, 0.5, 0.1, 36);
        let stats = lda.sweep(ExecMode::Sequential);
        assert_eq!(stats.measured_cost() as f64, plan.cost);
    }
}
