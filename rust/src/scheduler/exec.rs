//! The parallel LDA trainer: diagonal epochs over a partition plan.

use std::time::Instant;

use crate::corpus::bow::BagOfWords;
use crate::gibbs::counts::LdaCounts;
use crate::gibbs::perplexity;
use crate::gibbs::sampler::Hyper;
use crate::gibbs::tokens::TokenBlock;
use crate::partition::scheme::PartitionMap;
use crate::partition::Plan;
use crate::scheduler::pool::{merge_deltas, EngineCache, EpochSpec, WorkerPool};
use crate::scheduler::shared::SharedRows;
use crate::util::rng::Rng;

/// How diagonal epochs execute (see [`crate::scheduler::pool`]):
///
/// * `Sequential` — in-order on the calling thread; the determinism
///   oracle and the zero-overhead mode for single-core boxes.
/// * `Threaded` — legacy scoped execution: one OS thread *spawned* per
///   partition per epoch (`P²` spawns per sweep).
/// * `Pooled` — persistent worker pool created once per trainer; epochs
///   are scatter/gathered over channels with per-worker scratch reuse.
///
/// All three produce identical results — worker RNG streams are keyed by
/// schedule position `(sweep, epoch, worker)`, not by interleaving.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecMode {
    Threaded,
    Sequential,
    Pooled,
}

impl ExecMode {
    /// Parse a CLI/config spelling.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "sequential" | "seq" => Some(Self::Sequential),
            "threaded" | "threads" => Some(Self::Threaded),
            "pooled" | "pool" => Some(Self::Pooled),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Self::Threaded => "threaded",
            Self::Sequential => "sequential",
            Self::Pooled => "pooled",
        }
    }
}

/// Per-sweep timing/cost telemetry.
#[derive(Clone, Debug, Default)]
pub struct SweepStats {
    /// Wall time of each epoch (seconds).
    pub epoch_secs: Vec<f64>,
    /// Max worker token count per epoch (the paper's epoch cost).
    pub epoch_max_tokens: Vec<u64>,
    /// Sum of all workers' token counts (serial-equivalent work).
    pub total_tokens: u64,
}

impl SweepStats {
    /// Eq. 1-style measured cost: Σ_l max_m tokens(m, l).
    pub fn measured_cost(&self) -> u64 {
        self.epoch_max_tokens.iter().sum()
    }
}

/// Parallel partitioned collapsed-Gibbs LDA (Yan et al.'s algorithm over
/// the paper's partition plans).
pub struct ParallelLda {
    pub h: Hyper,
    pub counts: LdaCounts,
    pub p: usize,
    /// Token blocks, diagonal-major: `blocks[l][m]` is partition
    /// `(m, (m+l) mod P)`.
    blocks: Vec<Vec<TokenBlock>>,
    seed: u64,
    sweeps_done: usize,
    /// Executor state; the persistent worker pool (if `Pooled` mode is
    /// used) lives here for the trainer's lifetime.
    engines: EngineCache,
    /// Double-buffered epoch-start view of `counts.topic`: merged deltas
    /// are applied to both, so no epoch ever clones the topic totals.
    snapshot: Vec<u32>,
    /// Per-worker signed topic deltas, zeroed and rewritten each epoch.
    deltas: Vec<Vec<i64>>,
}

impl ParallelLda {
    /// Random-initialize assignments under a partition plan.
    pub fn init(
        bow: &BagOfWords,
        plan: &Plan,
        k: usize,
        alpha: f32,
        beta: f32,
        seed: u64,
    ) -> Self {
        let p = plan.p;
        let map = PartitionMap::build(bow, plan);
        let mut rng = Rng::stream(seed, 0x1417);
        let mut blocks: Vec<Vec<TokenBlock>> = Vec::with_capacity(p);
        for l in 0..p {
            let mut diag = Vec::with_capacity(p);
            for (m, n) in map.diagonal(l) {
                diag.push(TokenBlock::from_cells(map.cells(m, n), k, &mut rng));
            }
            blocks.push(diag);
        }
        let mut counts = LdaCounts::zeros(bow.num_docs(), bow.num_words(), k);
        for diag in &blocks {
            for b in diag {
                counts.absorb(b);
            }
        }
        Self {
            h: Hyper::new(k, alpha, beta, bow.num_words()),
            counts,
            p,
            blocks,
            seed,
            sweeps_done: 0,
            engines: EngineCache::new(p),
            snapshot: vec![0; k],
            deltas: vec![vec![0i64; k]; p],
        }
    }

    /// One full Gibbs sweep = `P` diagonal epochs with barriers.
    ///
    /// Epochs dispatch through the [`crate::scheduler::pool::Executor`]
    /// selected by `mode`; the topic snapshot is double-buffered and the
    /// per-worker delta slots are reused, so the steady-state hot path
    /// performs no per-epoch heap allocation in `Sequential` and
    /// `Pooled` modes.
    pub fn sweep(&mut self, mode: ExecMode) -> SweepStats {
        let p = self.p;
        let k = self.h.k;
        let sweep_no = self.sweeps_done;
        let mut stats = SweepStats::default();

        // Bring the persistent snapshot buffer up to date once per sweep
        // (k u32s — cheap); per-epoch it is maintained by the merge below.
        self.snapshot.copy_from_slice(&self.counts.topic);

        for l in 0..p {
            let epoch_started = Instant::now();
            let diag = &mut self.blocks[l];
            stats
                .epoch_max_tokens
                .push(diag.iter().map(|b| b.len() as u64).max().unwrap_or(0));
            stats.total_tokens += diag.iter().map(|b| b.len() as u64).sum::<u64>();
            let n = diag.len();

            let spec = EpochSpec {
                doc: SharedRows::new(&mut self.counts.doc_topic, k),
                emit: SharedRows::new(&mut self.counts.word_topic, k),
                snapshot: &self.snapshot,
                h: self.h,
                seed: self.seed ^ 0x50AB_71C5,
                sweep: sweep_no,
                epoch: l,
            };
            self.engines
                .get(mode)
                .run_epoch(&spec, diag, &mut self.deltas[..n]);

            // Barrier: reconcile topic totals into both the authoritative
            // counts and the snapshot buffer for the next epoch.
            merge_deltas(&mut self.counts.topic, &mut self.snapshot, &self.deltas[..n]);
            stats.epoch_secs.push(epoch_started.elapsed().as_secs_f64());
        }

        self.sweeps_done += 1;
        stats
    }

    /// The persistent worker pool, if any `Pooled`-mode sweep has run on
    /// this trainer (created on first use, then reused for every epoch).
    pub fn pool(&self) -> Option<&WorkerPool> {
        self.engines.pool()
    }

    /// Run `iters` sweeps, returning the perplexity curve as
    /// `(iteration, perplexity)` pairs.
    ///
    /// `eval_every` is the evaluation cadence: perplexity is recorded
    /// every `eval_every` sweeps and always after the final sweep.
    /// `eval_every == 0` disables perplexity evaluation entirely (the
    /// returned curve is empty) — useful when only the trained counts
    /// matter, since each evaluation costs a full corpus pass.
    pub fn train(
        &mut self,
        bow: &BagOfWords,
        iters: usize,
        eval_every: usize,
        mode: ExecMode,
    ) -> Vec<(usize, f64)> {
        let mut curve = Vec::new();
        for it in 1..=iters {
            self.sweep(mode);
            if eval_every > 0 && (it % eval_every == 0 || it == iters) {
                curve.push((it, self.perplexity(bow)));
            }
        }
        curve
    }

    pub fn perplexity(&self, bow: &BagOfWords) -> f64 {
        perplexity::perplexity(bow, &self.counts, &self.h)
    }

    /// Borrow all token blocks (test/diagnostic use).
    pub fn all_blocks(&self) -> Vec<&TokenBlock> {
        self.blocks.iter().flatten().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::synthetic::{generate, Profile};
    use crate::partition::{partition, Algorithm};

    fn setup(p: usize, seed: u64) -> (BagOfWords, ParallelLda) {
        let bow = generate(&Profile::tiny(), seed);
        let plan = partition(&bow, p, Algorithm::A3 { restarts: 3 }, seed);
        let lda = ParallelLda::init(&bow, &plan, 8, 0.5, 0.1, seed);
        (bow, lda)
    }

    #[test]
    fn init_absorbs_every_token() {
        let (bow, lda) = setup(4, 31);
        assert_eq!(lda.counts.total(), bow.num_tokens());
        assert!(lda
            .counts
            .check_consistency(&lda.all_blocks())
            .is_ok());
    }

    #[test]
    fn sweep_preserves_invariants() {
        let (bow, mut lda) = setup(3, 32);
        for _ in 0..5 {
            let stats = lda.sweep(ExecMode::Sequential);
            assert_eq!(stats.total_tokens, bow.num_tokens());
            assert_eq!(stats.epoch_secs.len(), 3);
        }
        assert_eq!(lda.counts.total(), bow.num_tokens());
        assert!(lda.counts.check_consistency(&lda.all_blocks()).is_ok());
    }

    #[test]
    fn threaded_equals_sequential() {
        let (_bow, mut a) = setup(4, 33);
        let (_bow2, mut b) = setup(4, 33);
        for _ in 0..3 {
            a.sweep(ExecMode::Threaded);
            b.sweep(ExecMode::Sequential);
        }
        assert_eq!(a.counts.doc_topic, b.counts.doc_topic);
        assert_eq!(a.counts.word_topic, b.counts.word_topic);
        assert_eq!(a.counts.topic, b.counts.topic);
    }

    #[test]
    fn pooled_equals_sequential() {
        let (_bow, mut a) = setup(4, 37);
        let (_bow2, mut b) = setup(4, 37);
        for _ in 0..3 {
            a.sweep(ExecMode::Pooled);
            b.sweep(ExecMode::Sequential);
        }
        assert_eq!(a.counts.doc_topic, b.counts.doc_topic);
        assert_eq!(a.counts.word_topic, b.counts.word_topic);
        assert_eq!(a.counts.topic, b.counts.topic);
    }

    #[test]
    fn pool_is_reused_across_sweeps() {
        let (_bow, mut lda) = setup(4, 38);
        assert!(lda.pool().is_none(), "no pool before the first pooled sweep");
        lda.sweep(ExecMode::Pooled);
        let (workers, epochs) = {
            let pool = lda.pool().expect("pool created on first pooled sweep");
            (pool.workers(), pool.epochs_run())
        };
        assert_eq!(workers, 4);
        assert_eq!(epochs, 4, "P epochs per sweep");
        for _ in 0..3 {
            lda.sweep(ExecMode::Pooled);
        }
        let pool = lda.pool().unwrap();
        // Same pool object served every sweep: worker count stable, epoch
        // counter monotone — no teardown/respawn between sweeps.
        assert_eq!(pool.workers(), 4);
        assert_eq!(pool.epochs_run(), 16);
    }

    #[test]
    fn modes_can_be_mixed_across_sweeps() {
        // RNG streams are keyed by schedule position, so a trainer may
        // switch executors between sweeps without changing results.
        let (_bow, mut a) = setup(3, 39);
        let (_bow2, mut b) = setup(3, 39);
        a.sweep(ExecMode::Pooled);
        a.sweep(ExecMode::Sequential);
        a.sweep(ExecMode::Threaded);
        for _ in 0..3 {
            b.sweep(ExecMode::Sequential);
        }
        assert_eq!(a.counts.doc_topic, b.counts.doc_topic);
        assert_eq!(a.counts.word_topic, b.counts.word_topic);
        assert_eq!(a.counts.topic, b.counts.topic);
    }

    #[test]
    fn pooled_sweep_preserves_invariants() {
        let (bow, mut lda) = setup(3, 40);
        for _ in 0..4 {
            let stats = lda.sweep(ExecMode::Pooled);
            assert_eq!(stats.total_tokens, bow.num_tokens());
        }
        assert_eq!(lda.counts.total(), bow.num_tokens());
        assert!(lda.counts.check_consistency(&lda.all_blocks()).is_ok());
    }

    #[test]
    fn parallel_training_reduces_perplexity() {
        let (bow, mut lda) = setup(4, 34);
        let p0 = lda.perplexity(&bow);
        let curve = lda.train(&bow, 30, 30, ExecMode::Sequential);
        let p_end = curve.last().unwrap().1;
        assert!(p_end < p0 * 0.9, "{p0} → {p_end}");
    }

    #[test]
    fn parallel_close_to_serial_perplexity() {
        // Table IV's claim in miniature: parallel and serial converge to
        // approximately the same training perplexity.
        let bow = generate(&Profile::tiny(), 35);
        let plan = partition(&bow, 4, Algorithm::A3 { restarts: 3 }, 35);
        let mut par = ParallelLda::init(&bow, &plan, 8, 0.5, 0.1, 35);
        let mut ser = crate::gibbs::serial::SerialLda::init(&bow, 8, 0.5, 0.1, 35);
        par.train(&bow, 40, 0, ExecMode::Sequential);
        ser.train(&bow, 40, 0);
        let pp = par.perplexity(&bow);
        let ps = ser.perplexity(&bow);
        let rel = (pp - ps).abs() / ps;
        assert!(rel < 0.05, "parallel {pp} vs serial {ps} (rel {rel})");
    }

    #[test]
    fn exec_mode_parses_cli_spellings() {
        assert_eq!(ExecMode::parse("sequential"), Some(ExecMode::Sequential));
        assert_eq!(ExecMode::parse("threads"), Some(ExecMode::Threaded));
        assert_eq!(ExecMode::parse("pooled"), Some(ExecMode::Pooled));
        assert_eq!(ExecMode::parse("pool"), Some(ExecMode::Pooled));
        assert_eq!(ExecMode::parse("gpu"), None);
        assert_eq!(ExecMode::Pooled.name(), "pooled");
    }

    #[test]
    fn measured_cost_matches_plan_cost() {
        let bow = generate(&Profile::tiny(), 36);
        let plan = partition(&bow, 5, Algorithm::A1, 36);
        let mut lda = ParallelLda::init(&bow, &plan, 4, 0.5, 0.1, 36);
        let stats = lda.sweep(ExecMode::Sequential);
        assert_eq!(stats.measured_cost() as f64, plan.cost);
    }
}
